//! `lint_atomics` — the atomics-ordering discipline lint.
//!
//! Scans every `.rs` file under `rust/src` and requires each
//! `Ordering::{SeqCst, AcqRel, Acquire, Release, Relaxed}` site to
//! carry an `// ordering:` justification comment, either trailing on
//! the same line or within the three preceding lines (so one comment
//! can cover a multi-line `compare_exchange` pair). Undocumented
//! sites — including every bare `SeqCst` and `Relaxed` — fail the
//! build with a `path:line` listing. Standalone memory fences
//! (`fence(...)` / `compiler_fence(...)` call sites) are held to the
//! same rule even when the ordering token is imported rather than
//! path-qualified: a fence is *pure* ordering, so an unjustified one
//! is the worst offender of all. `#[cfg(test)]` modules are exempt:
//! test scaffolding asserts behaviour, it does not ship ordering
//! decisions.
//!
//! Self-contained by design (no syn/proc-macro in the offline crate
//! set): a line scanner with a brace-depth tracker for the test-module
//! exemption. Comment-only lines are skipped, so prose *about*
//! orderings does not need annotating.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

const VARIANTS: [&str; 5] = ["SeqCst", "AcqRel", "Acquire", "Release", "Relaxed"];

/// How many preceding lines an `// ordering:` comment may sit above
/// the site it justifies.
const WINDOW: usize = 3;

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Does `line` contain an atomic-ordering use site? Returns the
/// variant name. Assembled at runtime so this scanner never matches
/// its own source.
fn ordering_site(line: &str, needle: &str) -> Option<&'static str> {
    let mut rest = line;
    while let Some(pos) = rest.find(needle) {
        let after = &rest[pos + needle.len()..];
        for v in VARIANTS {
            if after.starts_with(v) {
                return Some(v);
            }
        }
        rest = after;
    }
    None
}

/// Net brace depth of a line, ignoring everything after a line
/// comment. Braces inside string literals are counted as-is — format
/// strings keep them balanced, which is all the test-module exemption
/// needs.
fn brace_delta(line: &str) -> i64 {
    let code = match line.find("//") {
        Some(pos) => &line[..pos],
        None => line,
    };
    let mut d = 0i64;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

struct FileReport {
    sites: usize,
    violations: Vec<(usize, &'static str)>,
}

fn scan(src: &str, needle: &str, fence: &str, marker: &str) -> FileReport {
    let mut report = FileReport { sites: 0, violations: Vec::new() };
    let mut depth = 0i64;
    // Depth at which a #[cfg(test)] item opened; we are exempt until
    // depth returns below it.
    let mut skip_below: Option<i64> = None;
    let mut pending_cfg_test = false;
    let lines: Vec<&str> = src.lines().collect();
    for (idx, &line) in lines.iter().enumerate() {
        let trimmed = line.trim_start();
        if let Some(entry) = skip_below {
            depth += brace_delta(line);
            if depth <= entry {
                skip_below = None;
            }
            continue;
        }
        if trimmed.starts_with("#[cfg(test)]") {
            pending_cfg_test = true;
            continue;
        }
        if pending_cfg_test {
            if line.contains('{') {
                let entry = depth;
                depth += brace_delta(line);
                pending_cfg_test = false;
                if depth > entry {
                    skip_below = Some(entry);
                }
                continue;
            }
            if trimmed.ends_with(';') {
                // `#[cfg(test)] use ...;` — a braceless item.
                pending_cfg_test = false;
            }
            continue;
        }
        depth += brace_delta(line);
        // Prose about orderings (doc comments, rationale text) is not
        // a use site.
        if trimmed.starts_with("//") {
            continue;
        }
        // A `fence(...)` call with its ordering token imported (no
        // `Ordering::` on the line) would otherwise slip the net.
        let variant = match ordering_site(line, needle) {
            Some(v) => v,
            None if line.contains(fence) => "fence",
            None => continue,
        };
        report.sites += 1;
        let annotated = line.contains(marker)
            || lines[idx.saturating_sub(WINDOW)..idx]
                .iter()
                .any(|prev| prev.contains(marker));
        if !annotated {
            report.violations.push((idx + 1, variant));
        }
    }
    report
}

fn main() -> ExitCode {
    // Built at runtime so the scanner's own source never matches.
    let needle: String = ["Ordering", "::"].concat();
    let fence: String = ["fence", "("].concat();
    let marker: String = ["// ", "ordering:"].concat();
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    let mut files = Vec::new();
    rust_files(&root, &mut files);
    files.sort();
    assert!(
        !files.is_empty(),
        "lint_atomics found no sources under {}",
        root.display()
    );

    let mut total_sites = 0usize;
    let mut total_files = 0usize;
    let mut failed = false;
    for path in &files {
        let Ok(src) = std::fs::read_to_string(path) else {
            eprintln!("lint_atomics: unreadable file {}", path.display());
            failed = true;
            continue;
        };
        let report = scan(&src, &needle, &fence, &marker);
        if report.sites > 0 {
            total_files += 1;
            total_sites += report.sites;
        }
        let shown = path.strip_prefix(&root).unwrap_or(path);
        for (lineno, variant) in &report.violations {
            eprintln!(
                "{}:{lineno}: undocumented {needle}{variant} — add an \
                 `{marker} <why this order suffices>` comment",
                shown.display()
            );
            failed = true;
        }
    }
    if failed {
        eprintln!("lint_atomics: FAILED");
        return ExitCode::FAILURE;
    }
    println!(
        "lint_atomics: {total_sites} ordering sites across {total_files} \
         files, all annotated"
    );
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needle() -> String {
        ["Ordering", "::"].concat()
    }
    fn fence() -> String {
        ["fence", "("].concat()
    }
    fn marker() -> String {
        ["// ", "ordering:"].concat()
    }
    fn scan_src(src: &str) -> FileReport {
        scan(src, &needle(), &fence(), &marker())
    }

    #[test]
    fn trailing_annotation_passes() {
        let src = format!(
            "fn f() {{\n    x.load({}Acquire); {} pairs with store\n}}\n",
            needle(),
            marker()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 1);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn preceding_window_covers_multiline_cas() {
        let src = format!(
            "fn f() {{\n    {} CAS publish\n    x.compare_exchange(a, b,\n        \
             {}AcqRel,\n        {}Acquire);\n}}\n",
            marker(),
            needle(),
            needle()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 2);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
    }

    #[test]
    fn unannotated_site_is_flagged_with_line() {
        let src =
            format!("fn f() {{\n    x.store(1, {}SeqCst);\n}}\n", needle());
        let r = scan_src(&src);
        assert_eq!(r.violations, vec![(2, "SeqCst")]);
    }

    #[test]
    fn cfg_test_modules_are_exempt() {
        let src = format!(
            "fn f() {{\n    x.load({n}Relaxed); {m} stats\n}}\n#[cfg(test)]\n\
             mod tests {{\n    fn t() {{\n        x.load({n}SeqCst);\n    }}\n}}\n",
            n = needle(),
            m = marker()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 1, "test-module site must not be counted");
        assert!(r.violations.is_empty());
    }

    #[test]
    fn code_resumes_after_test_module() {
        let src = format!(
            "#[cfg(test)]\nmod tests {{\n    fn t() {{}}\n}}\n\
             fn g() {{\n    x.load({}Relaxed);\n}}\n",
            needle()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 1);
        assert_eq!(r.violations.len(), 1, "post-module code is linted again");
    }

    #[test]
    fn comment_prose_is_not_a_site() {
        let src = format!(
            "// {}SeqCst everywhere in this protocol, see below\nfn f() {{}}\n",
            needle()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 0);
    }

    #[test]
    fn bare_fence_requires_annotation() {
        // Ordering token imported, so the `Ordering::` needle misses;
        // the fence needle must still demand justification.
        let src = format!(
            "fn f() {{\n    std::sync::atomic::{}SeqCst);\n}}\n",
            fence()
        );
        let r = scan_src(&src);
        assert_eq!(r.violations, vec![(2, "fence")]);
        let ok = format!(
            "fn g() {{\n    {} pairs with the waiter-side fence\n    \
             std::sync::atomic::{}SeqCst);\n}}\n",
            marker(),
            fence()
        );
        let r = scan_src(&ok);
        assert_eq!(r.sites, 1);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn fence_with_inline_ordering_counts_once() {
        let src = format!(
            "fn f() {{\n    {}{}SeqCst); {} publish barrier\n}}\n",
            fence(),
            needle(),
            marker()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 1, "one line, one site");
        assert!(r.violations.is_empty());
    }

    #[test]
    fn cmp_ordering_is_not_an_atomic_site() {
        let src = format!(
            "fn f() {{\n    let _ = std::cmp::{}Equal;\n}}\n",
            needle()
        );
        let r = scan_src(&src);
        assert_eq!(r.sites, 0, "cmp::Ordering variants are not atomics");
    }
}
