//! # ouroboros-tpu
//!
//! Reproduction of **“Dynamic Memory Management on GPUs with SYCL”**
//! (Standish, 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * [`ouroboros`] — the six Ouroboros allocator variants (page, chunk,
//!   and the virtualized array/list versions of each), implemented with
//!   real lock-free atomics;
//! * [`simt`] — the SIMT device simulator substituting for the paper's
//!   GPUs (warps, votes, contention & cycle model);
//! * [`backend`] — toolchain semantic models (CUDA, deoptimised CUDA,
//!   oneAPI SYCL on NVIDIA/Xe, AdaptiveCpp);
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (the benchmark's data phase + the batch alloc planner);
//! * [`coordinator`] — the paper's benchmark driver, plus the allocation
//!   service (request router + warp-shaped batcher);
//! * [`harness`] — regenerates every figure of the paper's evaluation;
//! * [`check`] — correctness tooling: the protocol model checker, the
//!   `OURO_SAN` shadow-heap sanitizer, the `OURO_LIN` history recorder
//!   + linearizability checker, and the ranked-lock deadlock detector.
//!
//! See DESIGN.md for the substitution map and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod backend;
pub mod check;
pub mod coordinator;
pub mod harness;
pub mod ouroboros;
pub mod runtime;
pub mod simt;
pub mod util;
