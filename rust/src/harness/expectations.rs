//! Programmatic checks of the paper's qualitative claims.
//!
//! Absolute numbers are not comparable across substrates (DESIGN.md §3),
//! but the *shape* of the results is the reproduction target. Each check
//! here encodes one sentence of the paper's §4/§5 and is evaluated
//! against measured `FigureResult`s — used by the integration tests and
//! summarised into EXPERIMENTS.md.

use super::figures::{FigureResult, Series};

#[derive(Debug, Clone)]
pub struct Claim {
    pub id: &'static str,
    pub text: &'static str,
    pub holds: bool,
    pub detail: String,
}

fn series<'a>(v: &'a [Series], backend: &str) -> Option<&'a Series> {
    v.iter().find(|s| s.backend == backend)
}

/// Mean us/alloc over a series' points (subsequent-iterations metric).
fn series_mean(s: &Series) -> f64 {
    let xs: Vec<f64> = s.points.iter().map(|p| p.alloc_us).collect();
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// §5: "within a factor of 2 performance of the original code for the
/// faster page-based algorithms" — oneAPI page time in (1.2x, 3x) of
/// CUDA.
pub fn check_page_gap(fig1: &FigureResult) -> Claim {
    let cuda = series(&fig1.right, "cuda").map(series_mean).unwrap_or(0.0);
    let sycl = series(&fig1.right, "sycl-nv").map(series_mean).unwrap_or(0.0);
    let ratio = sycl / cuda.max(1e-12);
    Claim {
        id: "page-2x",
        text: "SYCL page allocator ≈ half the performance of CUDA",
        holds: (1.2..3.0).contains(&ratio),
        detail: format!("sycl/cuda time ratio = {ratio:.2} (paper ≈ 2)"),
    }
}

/// §5: chunk allocators "within statistical noise" of CUDA under oneAPI.
pub fn check_chunk_parity(fig2: &FigureResult) -> Claim {
    let cuda = series(&fig2.right, "cuda").map(series_mean).unwrap_or(0.0);
    let sycl = series(&fig2.right, "sycl-nv").map(series_mean).unwrap_or(0.0);
    let ratio = sycl / cuda.max(1e-12);
    Claim {
        id: "chunk-parity",
        text: "SYCL chunk allocator within noise of CUDA",
        holds: (0.8..1.45).contains(&ratio),
        detail: format!("sycl/cuda time ratio = {ratio:.2} (paper ≈ 1)"),
    }
}

/// §4.1: deoptimising the CUDA code "only seem to make it more
/// performant, if anything".
pub fn check_deopt_not_slower(fig1: &FigureResult) -> Claim {
    let cuda = series(&fig1.right, "cuda").map(series_mean).unwrap_or(0.0);
    let deopt = series(&fig1.right, "cuda-deopt").map(series_mean).unwrap_or(0.0);
    let ratio = deopt / cuda.max(1e-12);
    Claim {
        id: "deopt-fast",
        text: "deoptimised CUDA no slower than optimised (paper: if \
               anything faster)",
        holds: ratio < 1.35,
        detail: format!("deopt/cuda time ratio = {ratio:.2}"),
    }
}

/// §4.2 (Fig 2 left): chunk allocation cost grows with allocation size
/// (walking the linked list of chunk queues).
pub fn check_chunk_size_walk(fig2: &FigureResult) -> Claim {
    let holds = fig2.left.iter().all(|s| {
        let first = s.points.first().map(|p| p.alloc_us).unwrap_or(0.0);
        let last = s.points.last().map(|p| p.alloc_us).unwrap_or(0.0);
        last > first
    });
    Claim {
        id: "chunk-walk",
        text: "chunk alloc time grows with allocation size (queue-list \
               walk)",
        holds,
        detail: "all series monotone endpoints".into(),
    }
}

/// Right panels: latency grows with thread count (contention).
pub fn check_contention_growth(fig: &FigureResult) -> Claim {
    let holds = fig.right.iter().all(|s| {
        let lo = s.points.first().map(|p| p.alloc_us).unwrap_or(0.0);
        let hi = s.points.last().map(|p| p.alloc_us).unwrap_or(0.0);
        hi > lo // total phase time must grow with simultaneous allocations
    });
    Claim {
        id: format!("contention-fig{}", fig.fig).leak(),
        text: "total allocation time grows with simultaneous allocations",
        holds,
        detail: "first vs last thread-sweep point per series".into(),
    }
}

/// §4/§5: AdaptiveCpp struggles as thread count grows (timeouts).
pub fn check_acpp_timeouts(fig: &FigureResult) -> Claim {
    let acpp = series(&fig.right, "acpp");
    let holds = acpp
        .map(|s| {
            let hi_half = &s.points[s.points.len() / 2..];
            hi_half.iter().any(|p| p.timed_out)
                && !s.points.first().map(|p| p.timed_out).unwrap_or(true)
        })
        .unwrap_or(false);
    Claim {
        id: "acpp-timeout",
        text: "AdaptiveCpp times out at high thread counts, fine at low",
        holds,
        detail: acpp
            .map(|s| {
                format!(
                    "timeouts at x = {:?}",
                    s.points
                        .iter()
                        .filter(|p| p.timed_out)
                        .map(|p| p.x)
                        .collect::<Vec<_>>()
                )
            })
            .unwrap_or_default(),
    }
}

/// Evaluate the full claim set over figures 1 and 2 (+ contention on any
/// provided figure).
pub fn standard_claims(fig1: &FigureResult, fig2: &FigureResult) -> Vec<Claim> {
    vec![
        check_page_gap(fig1),
        check_chunk_parity(fig2),
        check_deopt_not_slower(fig1),
        check_chunk_size_walk(fig2),
        check_contention_growth(fig1),
        check_contention_growth(fig2),
        check_acpp_timeouts(fig2),
    ]
}

pub fn render_claims(claims: &[Claim]) -> String {
    let mut out = String::from("claim                | holds | detail\n");
    for c in claims {
        out.push_str(&format!(
            "{:<20} | {:<5} | {} — {}\n",
            c.id,
            if c.holds { "YES" } else { "NO" },
            c.text,
            c.detail
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::figures::{Point, Series};
    use crate::ouroboros::Variant;

    fn mk_series(backend: &'static str, ys: &[f64], timeouts: &[bool]) -> Series {
        Series {
            backend,
            device: "quadro-t2000",
            label: backend,
            points: ys
                .iter()
                .zip(timeouts)
                .enumerate()
                .map(|(i, (&y, &t))| Point {
                    x: 1 << i,
                    alloc_us: y,
                    alloc_us_all: y,
                    free_us: y,
                    alloc_us_per_op: y,
                    timed_out: t,
                    verify_ok: true,
                })
                .collect(),
        }
    }

    fn synthetic() -> (FigureResult, FigureResult) {
        let f = [false, false, false];
        let fig1 = FigureResult {
            fig: 1,
            variant: Variant::Page,
            left: vec![mk_series("cuda", &[0.5, 0.5, 0.6], &f)],
            right: vec![
                mk_series("cuda", &[0.5, 0.6, 0.8], &f),
                mk_series("cuda-deopt", &[0.45, 0.55, 0.75], &f),
                mk_series("sycl-nv", &[1.0, 1.2, 1.6], &f),
            ],
        };
        let fig2 = FigureResult {
            fig: 2,
            variant: Variant::Chunk,
            left: vec![mk_series("cuda", &[1.0, 1.5, 2.5], &f)],
            right: vec![
                mk_series("cuda", &[1.0, 1.2, 1.5], &f),
                mk_series("sycl-nv", &[1.1, 1.3, 1.6], &f),
                mk_series("acpp", &[1.2, 2.0, 9.0], &[false, false, true]),
            ],
        };
        (fig1, fig2)
    }

    #[test]
    fn synthetic_paper_shape_passes_all_claims() {
        let (f1, f2) = synthetic();
        let claims = standard_claims(&f1, &f2);
        for c in &claims {
            assert!(c.holds, "claim {} failed: {}", c.id, c.detail);
        }
        let txt = render_claims(&claims);
        assert!(txt.contains("page-2x"));
    }

    #[test]
    fn inverted_shape_fails_page_gap() {
        let (mut f1, _) = synthetic();
        // Make sycl *faster* than cuda — the claim must fail.
        f1.right[2] = mk_series("sycl-nv", &[0.2, 0.2, 0.2], &[false; 3]);
        assert!(!check_page_gap(&f1).holds);
    }
}
