//! Figure harness: regenerates every figure of the paper's evaluation.
//!
//! Each of Figures 1–6 has two panels over one allocator variant:
//! * **left**  — mean *subsequent* allocation time vs allocation size,
//!   1024 parallel allocations;
//! * **right** — mean subsequent allocation time vs number of
//!   simultaneous allocations, 1000 B each.
//!
//! Series: CUDA (optimised), CUDA (deoptimised), oneAPI SYCL on the same
//! NVIDIA profile, AdaptiveCpp on NVIDIA, and oneAPI SYCL on Iris Xe —
//! the paper's §3 toolchain×hardware matrix.

use std::sync::Arc;

use crate::util::errs::Result;

use crate::backend::{self, Backend};
use crate::coordinator::driver::{run_driver, DataPhase, DriverConfig};
use crate::coordinator::workload;
use crate::ouroboros::{HeapConfig, Variant};
use crate::simt::{Device, DeviceProfile};

/// One measured sweep point.
#[derive(Debug, Clone)]
pub struct Point {
    /// Sweep coordinate: allocation size (left) or thread count (right).
    pub x: u64,
    /// Mean subsequent allocation-phase time, microseconds — the paper's
    /// y-axis ("the average time for performing the allocations").
    pub alloc_us: f64,
    /// Mean over all iterations (includes first-launch JIT).
    pub alloc_us_all: f64,
    /// Free-phase time (subsequent mean).
    pub free_us: f64,
    /// Per-allocation views (alloc_us / threads), for the CSV.
    pub alloc_us_per_op: f64,
    /// Watchdog tripped (the acpp pathology).
    pub timed_out: bool,
    pub verify_ok: bool,
}

#[derive(Debug, Clone)]
pub struct Series {
    pub backend: &'static str,
    pub device: &'static str,
    pub label: &'static str,
    pub points: Vec<Point>,
}

#[derive(Debug, Clone)]
pub struct FigureResult {
    pub fig: u32,
    pub variant: Variant,
    /// Size sweep @ 1024 allocations.
    pub left: Vec<Series>,
    /// Thread sweep @ 1000 B.
    pub right: Vec<Series>,
}

#[derive(Debug, Clone)]
pub struct SweepOpts {
    /// Trimmed axes for CI / smoke runs.
    pub quick: bool,
    pub iterations: usize,
    pub heap: HeapConfig,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts { quick: false, iterations: 10, heap: HeapConfig::default() }
    }
}

/// The paper's toolchain × hardware matrix.
pub fn backend_device_pairs() -> Vec<(Arc<dyn Backend>, DeviceProfile)> {
    vec![
        (Arc::new(backend::Cuda::new()) as Arc<dyn Backend>, DeviceProfile::t2000()),
        (Arc::new(backend::CudaDeopt::new()), DeviceProfile::t2000()),
        (Arc::new(backend::SyclOneapiNv::new()), DeviceProfile::t2000()),
        (Arc::new(backend::Acpp::new()), DeviceProfile::t2000()),
        (Arc::new(backend::SyclOneapiXe::new()), DeviceProfile::iris_xe()),
    ]
}

fn measure(
    device: &Device,
    variant: Variant,
    alloc_size: u32,
    threads: u32,
    opts: &SweepOpts,
) -> Result<Point> {
    let cfg = DriverConfig {
        variant,
        alloc_size,
        num_allocations: threads,
        iterations: opts.iterations,
        data_phase: DataPhase::Sim,
        heap: opts.heap.clone(),
        seed: 0x0520,
    };
    let rep = run_driver(device, &cfg, None)?;
    let a = rep.alloc_split();
    let f = rep.free_split();
    Ok(Point {
        x: 0, // caller sets
        alloc_us: a.mean_subsequent,
        alloc_us_all: a.mean_all,
        free_us: f.mean_subsequent,
        alloc_us_per_op: a.mean_subsequent / threads as f64,
        timed_out: rep.any_timeout(),
        verify_ok: rep.verify_ok(),
    })
}

/// Regenerate one paper figure.
pub fn run_figure(fig: u32, opts: &SweepOpts) -> Result<FigureResult> {
    let variant = Variant::all()
        .into_iter()
        .find(|v| v.figure() == fig)
        .ok_or_else(|| crate::anyhow!("no figure {fig}; paper has 1..=6"))?;

    let sizes = if opts.quick {
        workload::quick_alloc_sizes()
    } else {
        workload::paper_alloc_sizes()
    };
    let threads = if opts.quick {
        workload::quick_thread_counts()
    } else {
        workload::paper_thread_counts()
    };

    let mut left = Vec::new();
    let mut right = Vec::new();
    for (be, profile) in backend_device_pairs() {
        let device = Device::new(profile, be.clone());

        let mut s = Series {
            backend: be.id(),
            device: device.profile.name,
            label: be.label(),
            points: Vec::new(),
        };
        for &size in &sizes {
            let mut p = measure(&device, variant, size, 1024, opts)?;
            p.x = size as u64;
            s.points.push(p);
        }
        left.push(s);

        let mut s = Series {
            backend: be.id(),
            device: device.profile.name,
            label: be.label(),
            points: Vec::new(),
        };
        for &t in &threads {
            let mut p = measure(&device, variant, 1000, t, opts)?;
            p.x = t as u64;
            s.points.push(p);
        }
        right.push(s);
    }
    Ok(FigureResult { fig, variant, left, right })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_matches_paper() {
        let pairs = backend_device_pairs();
        assert_eq!(pairs.len(), 5);
        // One Xe datapoint, four on the T2000.
        assert_eq!(
            pairs.iter().filter(|(_, d)| d.name == "iris-xe").count(),
            1
        );
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(run_figure(7, &SweepOpts::default()).is_err());
    }

    /// End-to-end smoke of one quick figure (also exercised much harder
    /// by the integration tests and `cargo bench`).
    #[test]
    fn quick_figure_has_all_series_and_points() {
        let opts = SweepOpts {
            quick: true,
            iterations: 2,
            heap: HeapConfig::default(),
        };
        let r = run_figure(1, &opts).unwrap();
        assert_eq!(r.variant, Variant::Page);
        assert_eq!(r.left.len(), 5);
        assert_eq!(r.right.len(), 5);
        for s in r.left.iter().chain(r.right.iter()) {
            assert!(!s.points.is_empty());
            assert!(s.points.iter().all(|p| p.verify_ok));
            assert!(s.points.iter().all(|p| p.alloc_us > 0.0));
        }
    }
}
