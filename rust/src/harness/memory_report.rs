//! Queue-memory footprint report — the core Ouroboros claim ("virtual
//! queues, which reduce queue sizes even further", paper §4.3; the ICS'20
//! original's headline table).
//!
//! The standard index queue must be provisioned for the worst case
//! (every page of the heap parked in one queue: `num_chunks x 512` slots
//! per queue); the virtualized queues hold only live segments. This
//! report measures both the *static* provisioning and the footprint
//! under a live load.

use crate::backend::Cuda;
use crate::ouroboros::{build_allocator, HeapConfig, Variant};
use crate::simt::DevCtx;

#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub variant: Variant,
    /// Queue metadata/storage at rest (freshly built).
    pub idle_bytes: u64,
    /// After `load_allocs` live allocations of `load_size` B.
    pub loaded_bytes: u64,
    /// Heap under management (for scale).
    pub heap_bytes: u64,
}

pub fn measure(cfg: &HeapConfig, load_allocs: u32, load_size: u32) -> Vec<MemoryRow> {
    let b = Cuda::new();
    Variant::all()
        .into_iter()
        .map(|variant| {
            let alloc = build_allocator(variant, cfg);
            let idle_bytes = alloc.metadata_bytes();
            let ctx = DevCtx::new(&b, 1455.0, 0);
            let addrs: Vec<u32> = (0..load_allocs)
                .map(|_| alloc.malloc(&ctx, load_size).expect("load alloc"))
                .collect();
            let loaded_bytes = alloc.metadata_bytes();
            for a in addrs {
                alloc.free(&ctx, a).expect("load free");
            }
            MemoryRow {
                variant,
                idle_bytes,
                loaded_bytes,
                heap_bytes: cfg.heap_bytes(),
            }
        })
        .collect()
}

pub fn render(rows: &[MemoryRow]) -> String {
    use std::fmt::Write;
    let mut out = String::from(
        "variant    queue memory (idle)   queue memory (loaded)   % of heap (idle)\n",
    );
    for r in rows {
        writeln!(
            out,
            "{:<10} {:>18} B {:>21} B {:>15.2}%",
            r.variant.id(),
            r.idle_bytes,
            r.loaded_bytes,
            100.0 * r.idle_bytes as f64 / r.heap_bytes as f64
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtualized_queues_are_much_smaller_at_rest() {
        let cfg = HeapConfig::default();
        let rows = measure(&cfg, 512, 1000);
        let get = |v: Variant| rows.iter().find(|r| r.variant == v).unwrap();
        let std_page = get(Variant::Page).idle_bytes;
        let va_page = get(Variant::VaPage).idle_bytes;
        let vl_page = get(Variant::VlPage).idle_bytes;
        // The headline Ouroboros claim: orders of magnitude less static
        // queue memory.
        assert!(
            va_page * 100 < std_page,
            "va {va_page} should be <<1% of standard {std_page}"
        );
        assert!(vl_page * 100 < std_page);
    }

    #[test]
    fn loaded_footprint_grows_with_occupancy_for_virtual() {
        let cfg = HeapConfig::default();
        let rows = measure(&cfg, 2048, 1000);
        let get = |v: Variant| rows.iter().find(|r| r.variant == v).unwrap();
        // Standard queue: flat (slots preallocated). Virtual: grows.
        let std_row = get(Variant::Chunk);
        assert_eq!(std_row.idle_bytes, std_row.loaded_bytes);
        let va_row = get(Variant::VaChunk);
        assert!(va_row.loaded_bytes >= va_row.idle_bytes);
    }

    #[test]
    fn render_has_all_rows() {
        let rows = measure(&HeapConfig::test_small(), 16, 256);
        let txt = render(&rows);
        for v in Variant::all() {
            assert!(txt.contains(v.id()));
        }
    }
}
