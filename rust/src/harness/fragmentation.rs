//! Fragmentation study — paper §4.1: the page allocator "suffers more
//! from fragmentation than the other more sophisticated schemes".
//!
//! Method: run a mixed-size churn trace against each variant and track
//! the *chunk footprint ratio* — heap chunks held by the allocator per
//! byte of live allocation — plus the reclaim behaviour at quiescent
//! sweeps. Page allocators can never reclaim a chunk whose pages are
//! scattered through the ring; chunk allocators reclaim any fully free
//! chunk.

use crate::backend::Cuda;
use crate::coordinator::workload::{churn_trace, TraceOp};
use crate::ouroboros::{build_allocator, params, HeapConfig, Variant};
use crate::simt::DevCtx;

#[derive(Debug, Clone)]
pub struct FragPoint {
    /// Trace progress (ops executed).
    pub ops: usize,
    /// Bytes live from the application's perspective.
    pub live_bytes: u64,
    /// Chunks held by the allocator (footprint).
    pub held_chunks: u32,
    /// footprint bytes / live bytes (1.0 = perfect).
    pub expansion: f64,
}

#[derive(Debug, Clone)]
pub struct FragReport {
    pub variant: Variant,
    pub points: Vec<FragPoint>,
    /// Chunks reclaimed by the final quiescent sweep.
    pub swept: u32,
    /// Chunks still held after the sweep with zero live bytes.
    pub stranded_chunks: u32,
}

impl FragReport {
    pub fn peak_expansion(&self) -> f64 {
        self.points.iter().map(|p| p.expansion).fold(0.0, f64::max)
    }
}

/// Run the fragmentation trace against one variant.
pub fn run_fragmentation(
    variant: Variant,
    seed: u64,
    slots: usize,
    ops: usize,
) -> FragReport {
    let cfg = HeapConfig { num_chunks: 1024, ..HeapConfig::default() };
    let alloc = build_allocator(variant, &cfg);
    let b = Cuda::new();
    let ctx = DevCtx::new(&b, 1455.0, 0);
    let trace = churn_trace(seed, slots, ops, params::CHUNK_SIZE);

    let mut live: std::collections::HashMap<usize, (u32, u32)> =
        Default::default();
    let mut live_bytes = 0u64;
    let mut points = Vec::new();
    let sample_every = (trace.len() / 32).max(1);

    for (i, op) in trace.iter().enumerate() {
        match *op {
            TraceOp::Alloc { slot, size } => {
                let addr = alloc.malloc(&ctx, size).expect("frag alloc");
                live.insert(slot, (addr, size));
                live_bytes += size as u64;
            }
            TraceOp::Free { slot } => {
                let (addr, size) = live.remove(&slot).unwrap();
                alloc.free(&ctx, addr).expect("frag free");
                live_bytes -= size as u64;
            }
        }
        if i % sample_every == 0 {
            let held = alloc.heap().live_chunks();
            points.push(FragPoint {
                ops: i,
                live_bytes,
                held_chunks: held,
                expansion: if live_bytes > 0 {
                    held as f64 * params::CHUNK_SIZE as f64 / live_bytes as f64
                } else {
                    0.0
                },
            });
        }
    }
    // Balanced trace: nothing live; measure what the allocator strands.
    assert!(live.is_empty());
    let swept = alloc.sweep(&ctx);
    FragReport {
        variant,
        points,
        swept,
        stranded_chunks: alloc.heap().live_chunks(),
    }
}

/// Paper-style comparison across all six variants.
pub fn fragmentation_table(seed: u64, slots: usize, ops: usize) -> String {
    use std::fmt::Write;
    let mut out = String::from(
        "variant    peak_expansion  swept_chunks  stranded_after_sweep\n",
    );
    for v in Variant::all() {
        let r = run_fragmentation(v, seed, slots, ops);
        writeln!(
            out,
            "{:<10} {:>14.2}x {:>13} {:>21}",
            v.id(),
            r.peak_expansion(),
            r.swept,
            r.stranded_chunks
        )
        .unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_allocator_reclaims_page_allocator_strands() {
        let page = run_fragmentation(Variant::Page, 7, 128, 2000);
        let chunk = run_fragmentation(Variant::Chunk, 7, 128, 2000);
        // Paper §4.1: the page allocator suffers more from
        // fragmentation: it strands chunks a sweep cannot reclaim.
        assert_eq!(chunk.stranded_chunks, 0, "chunk variant must drain");
        assert!(
            page.stranded_chunks > 0,
            "page variant should strand chunks (its documented weakness)"
        );
        assert!(chunk.swept > 0);
    }

    #[test]
    fn expansion_is_tracked() {
        let r = run_fragmentation(Variant::VaChunk, 9, 64, 1200);
        assert!(!r.points.is_empty());
        assert!(r.peak_expansion() >= 1.0, "footprint can't beat live bytes");
        // Bounded: churn shouldn't blow the footprint out absurdly.
        assert!(r.peak_expansion() < 80.0, "{}", r.peak_expansion());
    }

    #[test]
    fn table_renders_all_variants() {
        let t = fragmentation_table(3, 32, 400);
        for v in Variant::all() {
            assert!(t.contains(v.id()));
        }
    }
}
