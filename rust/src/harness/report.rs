//! Rendering: paper-style tables on stdout + CSV files for plotting.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::errs::{Context, Result};

use super::figures::{FigureResult, Series};

fn fmt_cell(p_us: f64, timed_out: bool) -> String {
    if timed_out {
        format!("{p_us:>9.3}*")
    } else {
        format!("{p_us:>10.3}")
    }
}

fn render_panel(title: &str, x_name: &str, series: &[Series]) -> String {
    let mut out = String::new();
    writeln!(out, "  {title}").unwrap();
    let mut header = format!("  {x_name:>8}");
    for s in series {
        header.push_str(&format!(" {:>10}", s.backend));
    }
    writeln!(out, "{header}").unwrap();
    let xs: Vec<u64> = series[0].points.iter().map(|p| p.x).collect();
    for (i, x) in xs.iter().enumerate() {
        let mut row = format!("  {x:>8}");
        for s in series {
            let p = &s.points[i];
            row.push_str(&format!(" {}", fmt_cell(p.alloc_us, p.timed_out)));
        }
        writeln!(out, "{row}").unwrap();
    }
    out
}

/// Paper-style text rendering of one figure (both panels).
pub fn render_figure(r: &FigureResult) -> String {
    let mut out = String::new();
    writeln!(
        out,
        "Figure {} — {} allocator (mean subsequent allocation-phase time, \
         us; `*` = watchdog timeout)",
        r.fig,
        r.variant.label()
    )
    .unwrap();
    out.push_str(&render_panel(
        "left: allocation-size sweep @ 1024 parallel allocations",
        "size[B]",
        &r.left,
    ));
    out.push_str(&render_panel(
        "right: thread sweep @ 1000 B allocations",
        "threads",
        &r.right,
    ));
    out
}

/// CSV rows: `panel,x,backend,device,alloc_us_per_op,alloc_us_per_op_all,
/// free_us_per_op,timed_out,verify_ok`.
pub fn to_csv(r: &FigureResult) -> String {
    let mut out = String::from(
        "panel,x,backend,device,alloc_us,alloc_us_all,free_us,\
         alloc_us_per_op,timed_out,verify_ok\n",
    );
    for (panel, series) in [("size", &r.left), ("threads", &r.right)] {
        for s in series.iter() {
            for p in &s.points {
                writeln!(
                    out,
                    "{panel},{},{},{},{:.4},{:.4},{:.4},{:.4},{},{}",
                    p.x,
                    s.backend,
                    s.device,
                    p.alloc_us,
                    p.alloc_us_all,
                    p.free_us,
                    p.alloc_us_per_op,
                    p.timed_out,
                    p.verify_ok
                )
                .unwrap();
            }
        }
    }
    out
}

/// Write `figN.txt` + `figN.csv` into `dir`.
pub fn write_figure(r: &FigureResult, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    std::fs::write(dir.join(format!("fig{}.txt", r.fig)), render_figure(r))?;
    std::fs::write(dir.join(format!("fig{}.csv", r.fig)), to_csv(r))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::figures::Point;

    fn tiny_result() -> FigureResult {
        let mk = |backend: &'static str, v: f64, t: bool| Series {
            backend,
            device: "quadro-t2000",
            label: backend,
            points: vec![Point {
                x: 16,
                alloc_us: v,
                alloc_us_all: v * 2.0,
                free_us: v / 2.0,
                alloc_us_per_op: v,
                timed_out: t,
                verify_ok: true,
            }],
        };
        FigureResult {
            fig: 1,
            variant: crate::ouroboros::Variant::Page,
            left: vec![mk("cuda", 0.5, false), mk("sycl-nv", 1.0, false)],
            right: vec![mk("cuda", 0.6, false), mk("acpp", 9.9, true)],
        }
    }

    #[test]
    fn text_render_contains_series_and_marker() {
        let txt = render_figure(&tiny_result());
        assert!(txt.contains("Figure 1"));
        assert!(txt.contains("cuda"));
        assert!(txt.contains("sycl-nv"));
        assert!(txt.contains('*'), "timeout marker missing:\n{txt}");
    }

    #[test]
    fn csv_shape() {
        let csv = to_csv(&tiny_result());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 1 + 4); // header + 2 panels x 2 series
        assert!(lines[0].starts_with("panel,x,backend"));
        assert!(lines.iter().any(|l| l.contains("acpp") && l.contains("true")));
    }

    #[test]
    fn write_files() {
        let dir = std::env::temp_dir().join("ouro_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_figure(&tiny_result(), &dir).unwrap();
        assert!(dir.join("fig1.txt").exists());
        assert!(dir.join("fig1.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
