//! Benchmark/figure harness: sweeps ([`figures`]), rendering
//! ([`report`]), and programmatic checks of the paper's qualitative
//! claims ([`expectations`]).

pub mod expectations;
pub mod figures;
pub mod fragmentation;
pub mod memory_report;
pub mod report;

pub use figures::{run_figure, FigureResult, Series, SweepOpts};
