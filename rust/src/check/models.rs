//! Extracted shadow models of the alloc service's concurrency
//! protocols, checked by [`crate::check::sched`].
//!
//! Each model re-states one protocol as plain data plus per-thread
//! step machines, small enough for bounded-exhaustive exploration but
//! faithful to the ordering decisions the real code makes. Where a
//! protocol had a historical bug (the PR 5 forwarding-grace TOCTOU,
//! the enumerate-before-gauge drain race), the model carries a
//! `pre_fix`/`buggy` mode reproducing the *old* logic so the test
//! suite can prove the checker finds the bug the fix removed.
//!
//! Invariants, one sentence each:
//! * [`RingModel`] — a TicketRing slot is granted to at most one
//!   client per generation and a completion is only ever taken by the
//!   operation that submitted into that generation.
//! * [`ForwardingModel`] — a migrated block's copy is freed at most
//!   once, a forwarding entry forwards at most one free, and a free
//!   accepted at submit is never rejected at dispatch (TOCTOU).
//! * [`DrainModel`] — no allocation placed by a client slips past the
//!   drainer's live-set enumeration (gauge-raise happens-before the
//!   health re-check).
//! * [`StateMachineModel`] — device health only moves along
//!   `healthy→draining→retired→readmitting→healthy` edges and exactly
//!   one actor wins each contended transition.
//! * [`QueueModel`] — the IndexQueue conserves values: everything
//!   admitted is either consumed exactly once or still in a slot, with
//!   the count permitted to be only transiently negative.
//! * [`FederationModel`] — a federated placement spills only past a
//!   latched/full group, a tag-routed free always lands on a group
//!   that still knows the name (even across a group restart — the
//!   `buggy` variant wipes the table on restart and loses a block),
//!   and every spill is matched by exactly one failback.
//! * [`LeaseModel`] — no block is served out of a client-cache lease
//!   after its span's recall quiesced (the owner re-checks the recall
//!   flag *under* its serve pin — the `buggy` variant checks before
//!   pinning and serves from a migrated span), and a cross-client
//!   delayed free is consumed by at most one drain.
//! * [`NotifyModel`] — a completion broadcast is only ever suppressed
//!   when no blocking waiter is registered and the published used
//!   index has not crossed the client's `used_event` watermark (the
//!   completer publishes the index *before* reading either — the
//!   `buggy` variant caches the verdict first and parks a waiter
//!   forever).

use super::sched::{Model, Step};

// ---------------------------------------------------------------------------
// TicketRing slot/generation lifecycle
// ---------------------------------------------------------------------------

const SLOT_FREE: u8 = 0;
const SLOT_SUBMITTED: u8 = 1;
const SLOT_COMPLETE: u8 = 2;

#[derive(Clone)]
struct RingSlot {
    state: u8,
    gen: u32,
    /// Which client's operation currently owns the slot.
    op: usize,
}

/// TicketRing: 1 slot, 2 clients, 1 completer — the single slot forces
/// slot reuse, exercising the generation bump that keeps a stale
/// ticket from consuming the next tenant's completion.
pub struct RingModel {
    slot: RingSlot,
    free: Vec<usize>,
    /// Client program counters: 0 = claim, 1 = await+take, 2 = done.
    cpc: [usize; 2],
    /// Generation each client's ticket was minted against.
    cgen: [u32; 2],
    completions_taken: [usize; 2],
    violation: Option<String>,
}

impl RingModel {
    const CLIENTS: usize = 2;
    const COMPLETER: usize = 2;

    pub fn new() -> Self {
        RingModel {
            slot: RingSlot { state: SLOT_FREE, gen: 0, op: usize::MAX },
            free: vec![0],
            cpc: [0; 2],
            cgen: [0; 2],
            completions_taken: [0; 2],
            violation: None,
        }
    }

    fn fail(&mut self, msg: String) {
        if self.violation.is_none() {
            self.violation = Some(msg);
        }
    }
}

impl Default for RingModel {
    fn default() -> Self {
        Self::new()
    }
}

impl Model for RingModel {
    fn reset(&mut self) {
        *self = RingModel::new();
    }

    fn threads(&self) -> usize {
        3
    }

    fn describe(&self, tid: usize) -> String {
        if tid == Self::COMPLETER {
            return "completer: complete a SUBMITTED slot".into();
        }
        match self.cpc[tid] {
            0 => format!("client{tid}: claim slot from free list"),
            _ => format!("client{tid}: await gen={} completion, take+reap", self.cgen[tid]),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid == Self::COMPLETER {
            if self.slot.state == SLOT_SUBMITTED {
                self.slot.state = SLOT_COMPLETE;
                return Step::Progress;
            }
            if self.cpc.iter().all(|&pc| pc == 2) {
                return Step::Done;
            }
            return Step::Blocked;
        }
        match self.cpc[tid] {
            0 => {
                let Some(idx) = self.free.pop() else {
                    return Step::Blocked;
                };
                debug_assert_eq!(idx, 0);
                if self.slot.state != SLOT_FREE {
                    self.fail(format!(
                        "free list granted slot in state {} to client{tid}",
                        self.slot.state
                    ));
                    return Step::Done;
                }
                // Ticket = (slot, generation at claim).
                self.cgen[tid] = self.slot.gen;
                self.slot.op = tid;
                self.slot.state = SLOT_SUBMITTED;
                self.cpc[tid] = 1;
                Step::Progress
            }
            1 => {
                // take(): only a COMPLETE slot whose generation still
                // matches our ticket may be consumed.
                if self.slot.state != SLOT_COMPLETE || self.slot.gen != self.cgen[tid] {
                    return Step::Blocked;
                }
                if self.slot.op != tid {
                    self.fail(format!(
                        "client{tid} (gen {}) took a completion submitted by client{} ",
                        self.cgen[tid], self.slot.op
                    ));
                    return Step::Done;
                }
                self.completions_taken[tid] += 1;
                // reap: bump generation so stale tickets can't match,
                // then recycle the slot.
                self.slot.state = SLOT_FREE;
                self.slot.gen += 1;
                self.slot.op = usize::MAX;
                self.free.push(0);
                self.cpc[tid] = 2;
                Step::Done
            }
            _ => Step::Done,
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        if self.free.len() > 1 {
            return Err("free list double-granted the slot".into());
        }
        if self.free.contains(&0) && self.slot.state != SLOT_FREE {
            return Err("slot on free list while not FREE".into());
        }
        if self.completions_taken.iter().any(|&c| c > 1) {
            return Err("a client took more than one completion".into());
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if self.completions_taken != [1, 1] {
            return Err(format!(
                "completion lost: taken = {:?}",
                self.completions_taken
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// ForwardingTable: forward-exactly-once + grace + re-mint invalidation
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Copy {
    Unminted,
    Live,
    Freed,
    Reminted,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Verdict {
    Pending,
    Forward,
    Reject,
    /// pre-fix only: submit accepted, but no verdict was pinned —
    /// dispatch re-derives it (the TOCTOU window).
    Accepted,
}

/// ForwardingTable protocol: a migrator re-homes a block and publishes
/// a forwarding entry; two racing stale frees, a grace-expiry clock,
/// and a re-minter recycling the freed copy all interleave against it.
///
/// `pre_fix = true` replays the PR 5 logic: submit checks the entry
/// and grace window but *does not consume*, and dispatch re-checks —
/// so grace can expire (or the other free can consume) between the two
/// probes and an accepted free is rejected at dispatch, leaking the
/// copy. The fixed protocol consumes at submit via a single CAS and
/// carries the pinned verdict to dispatch.
pub struct ForwardingModel {
    pub pre_fix: bool,
    /// Forwarding entry for the migrated name; `consumed` is the
    /// forward-exactly-once latch.
    entry: Option<bool>,
    grace_expired: bool,
    copy: Copy,
    source_live: bool,
    forwards: u32,
    copy_frees: u32,
    mpc: usize,
    fpc: [usize; 2],
    fverdict: [Verdict; 2],
    clock_pc: usize,
    remint_pc: usize,
    violation: Option<String>,
}

impl ForwardingModel {
    const MIGRATOR: usize = 0;
    const FREER0: usize = 1;
    const FREER1: usize = 2;
    const CLOCK: usize = 3;
    const REMINTER: usize = 4;

    pub fn fixed() -> Self {
        Self::with_mode(false)
    }

    pub fn pre_fix() -> Self {
        Self::with_mode(true)
    }

    fn with_mode(pre_fix: bool) -> Self {
        ForwardingModel {
            pre_fix,
            entry: None,
            grace_expired: false,
            copy: Copy::Unminted,
            source_live: true,
            forwards: 0,
            copy_frees: 0,
            mpc: 0,
            fpc: [0; 2],
            fverdict: [Verdict::Pending; 2],
            clock_pc: 0,
            remint_pc: 0,
            violation: None,
        }
    }

    fn fail(&mut self, msg: String) {
        if self.violation.is_none() {
            self.violation = Some(msg);
        }
    }

    fn free_copy(&mut self, who: usize) {
        match self.copy {
            Copy::Live => {
                self.copy = Copy::Freed;
                self.copy_frees += 1;
                self.forwards += 1;
            }
            Copy::Freed => self.fail(format!(
                "freer{who}: double free of the migrated copy"
            )),
            Copy::Reminted => self.fail(format!(
                "freer{who}: forwarded free landed on a re-minted block"
            )),
            Copy::Unminted => self.fail(format!(
                "freer{who}: forwarded free before the copy existed"
            )),
        }
    }

    fn freer_step(&mut self, f: usize) -> Step {
        match self.fpc[f] {
            0 => {
                // submit-side probe of the forwarding table. Stale
                // frees only exist once the entry is published.
                let Some(consumed) = self.entry else {
                    return Step::Blocked;
                };
                if self.pre_fix {
                    // PR 5 logic: accept if the entry looks alive now;
                    // verdict derived again at dispatch.
                    self.fverdict[f] = if !self.grace_expired && !consumed {
                        Verdict::Accepted
                    } else {
                        Verdict::Reject
                    };
                } else {
                    // Fixed: consume-at-submit decides once; the
                    // verdict is pinned into the ticket.
                    self.fverdict[f] = if !self.grace_expired && !consumed {
                        self.entry = Some(true);
                        Verdict::Forward
                    } else {
                        Verdict::Reject
                    };
                }
                self.fpc[f] = 1;
                Step::Progress
            }
            1 => {
                match self.fverdict[f] {
                    Verdict::Forward => self.free_copy(f),
                    Verdict::Accepted => {
                        // pre-fix dispatch: re-derive the verdict.
                        let ok = matches!(self.entry, Some(false)) && !self.grace_expired;
                        if ok {
                            self.entry = Some(true);
                            self.free_copy(f);
                        } else {
                            self.fail(format!(
                                "freer{f}: accepted at submit, rejected at \
                                 dispatch (grace/consumed raced) — copy leaked"
                            ));
                        }
                    }
                    Verdict::Reject => {}
                    Verdict::Pending => unreachable!(),
                }
                self.fpc[f] = 2;
                Step::Done
            }
            _ => Step::Done,
        }
    }
}

impl Model for ForwardingModel {
    fn reset(&mut self) {
        *self = Self::with_mode(self.pre_fix);
    }

    fn threads(&self) -> usize {
        5
    }

    fn describe(&self, tid: usize) -> String {
        match tid {
            Self::MIGRATOR => match self.mpc {
                0 => "migrator: mint copy on target".into(),
                1 => "migrator: publish forwarding entry".into(),
                _ => "migrator: claim source block".into(),
            },
            Self::FREER0 | Self::FREER1 => {
                let f = tid - Self::FREER0;
                match self.fpc[f] {
                    0 => format!("freer{f}: submit stale free (probe table)"),
                    _ => format!("freer{f}: dispatch free"),
                }
            }
            Self::CLOCK => "clock: expire the grace window".into(),
            Self::REMINTER => "re-minter: recycle freed copy + invalidate".into(),
            _ => unreachable!(),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            Self::MIGRATOR => match self.mpc {
                0 => {
                    self.copy = Copy::Live;
                    self.mpc = 1;
                    Step::Progress
                }
                1 => {
                    self.entry = Some(false);
                    self.mpc = 2;
                    Step::Progress
                }
                _ => {
                    self.source_live = false;
                    Step::Done
                }
            },
            Self::FREER0 => self.freer_step(0),
            Self::FREER1 => self.freer_step(1),
            Self::CLOCK => {
                self.grace_expired = true;
                Step::Done
            }
            Self::REMINTER => {
                if self.copy == Copy::Freed {
                    self.copy = Copy::Reminted;
                    // invalidate_reused(): any entry still pointing at
                    // the recycled block is killed before the address
                    // can be handed back out.
                    self.entry = Some(true);
                    Step::Done
                } else if self.fpc.iter().all(|&pc| pc == 2) {
                    // Nobody freed the copy this schedule; nothing to
                    // recycle.
                    Step::Done
                } else {
                    Step::Blocked
                }
            }
            _ => unreachable!(),
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        if self.forwards > 1 {
            return Err(format!("entry forwarded {} frees", self.forwards));
        }
        if self.copy_frees > 1 {
            return Err(format!("copy freed {} times", self.copy_frees));
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if self.source_live {
            return Err("migration never claimed the source".into());
        }
        let forwarded = self
            .fverdict
            .iter()
            .filter(|v| matches!(v, Verdict::Forward))
            .count();
        if !self.pre_fix && forwarded != self.forwards as usize {
            return Err(format!(
                "{} Forward verdicts but {} forwards applied",
                forwarded, self.forwards
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Drain quiesce: in-flight gauge vs health re-check
// ---------------------------------------------------------------------------

/// Drain quiesce handshake: two allocators race a drainer enumerating
/// the live set. The real protocol raises the per-device in-flight
/// gauge (SeqCst) *before* re-checking health, so the drainer — which
/// flips health to Draining and then spins until the gauge is zero —
/// either turns the allocator away or waits for its bit to land.
///
/// `buggy = true` swaps the order (check health, then raise the
/// gauge): an allocator can pass the health check, get descheduled,
/// and place its bit after enumeration — the "alloc slips past
/// enumeration" race the SeqCst handshake exists to prevent.
pub struct DrainModel {
    pub buggy: bool,
    draining: bool,
    inflight: u32,
    enumerated: bool,
    /// A block landed after the drainer enumerated the live set.
    missed: bool,
    placed: u32,
    rejected: u32,
    apc: [usize; 2],
    dpc: usize,
}

impl DrainModel {
    const DRAINER: usize = 2;

    pub fn fixed() -> Self {
        Self::with_mode(false)
    }

    pub fn buggy() -> Self {
        Self::with_mode(true)
    }

    fn with_mode(buggy: bool) -> Self {
        DrainModel {
            buggy,
            draining: false,
            inflight: 0,
            enumerated: false,
            missed: false,
            placed: 0,
            rejected: 0,
            apc: [0; 2],
            dpc: 0,
        }
    }

    fn place(&mut self) {
        if self.enumerated {
            self.missed = true;
        }
        self.placed += 1;
    }
}

impl Model for DrainModel {
    fn reset(&mut self) {
        *self = Self::with_mode(self.buggy);
    }

    fn threads(&self) -> usize {
        3
    }

    fn describe(&self, tid: usize) -> String {
        if tid == Self::DRAINER {
            return match self.dpc {
                0 => "drainer: set state = Draining".into(),
                1 => "drainer: spin until in-flight gauge is 0".into(),
                _ => "drainer: enumerate live set".into(),
            };
        }
        let (raise, chk) = if self.buggy { (1, 0) } else { (0, 1) };
        match self.apc[tid] {
            pc if pc == raise => format!("alloc{tid}: raise in-flight gauge"),
            pc if pc == chk => format!("alloc{tid}: re-check device health"),
            2 => format!("alloc{tid}: place block (set bitmap bit)"),
            _ => format!("alloc{tid}: release in-flight gauge"),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid == Self::DRAINER {
            return match self.dpc {
                0 => {
                    self.draining = true;
                    self.dpc = 1;
                    Step::Progress
                }
                1 => {
                    if self.inflight > 0 {
                        Step::Blocked
                    } else {
                        self.dpc = 2;
                        Step::Progress
                    }
                }
                _ => {
                    self.enumerated = true;
                    Step::Done
                }
            };
        }
        let pc = self.apc[tid];
        if self.buggy {
            // Buggy order: health check FIRST, gauge second.
            match pc {
                0 => {
                    if self.draining {
                        self.rejected += 1;
                        self.apc[tid] = 4;
                        return Step::Done;
                    }
                    self.apc[tid] = 1;
                    Step::Progress
                }
                1 => {
                    self.inflight += 1;
                    self.apc[tid] = 2;
                    Step::Progress
                }
                2 => {
                    self.place();
                    self.apc[tid] = 3;
                    Step::Progress
                }
                _ => {
                    self.inflight -= 1;
                    self.apc[tid] = 4;
                    Step::Done
                }
            }
        } else {
            // Real order: gauge up (SeqCst) FIRST, then re-check.
            match pc {
                0 => {
                    self.inflight += 1;
                    self.apc[tid] = 1;
                    Step::Progress
                }
                1 => {
                    if self.draining {
                        // Turned away: undo the gauge, no bit placed.
                        self.inflight -= 1;
                        self.rejected += 1;
                        self.apc[tid] = 4;
                        return Step::Done;
                    }
                    self.apc[tid] = 2;
                    Step::Progress
                }
                2 => {
                    self.place();
                    self.apc[tid] = 3;
                    Step::Progress
                }
                _ => {
                    self.inflight -= 1;
                    self.apc[tid] = 4;
                    Step::Done
                }
            }
        }
    }

    fn check(&self) -> Result<(), String> {
        if self.missed {
            return Err(
                "alloc slipped past enumeration: bit placed after the \
                 drainer captured the live set"
                    .into(),
            );
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if self.placed + self.rejected != 2 {
            return Err(format!(
                "allocator accounting drifted: {} placed + {} rejected != 2",
                self.placed, self.rejected
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Device health state machine
// ---------------------------------------------------------------------------

const ST_HEALTHY: u8 = 0;
const ST_DRAINING: u8 = 1;
const ST_RETIRED: u8 = 2;
const ST_READMITTING: u8 = 3;

fn st_name(s: u8) -> &'static str {
    match s {
        ST_HEALTHY => "Healthy",
        ST_DRAINING => "Draining",
        ST_RETIRED => "Retired",
        _ => "Readmitting",
    }
}

/// Device health lifecycle: a watchdog and an operator race to start a
/// drain (CAS Healthy→Draining, one winner), a retirer completes it
/// (Draining→Retired), and a readmitter runs the probation window
/// (Retired→Readmitting→Healthy). Every applied transition is logged
/// and validated against the legal edge set.
pub struct StateMachineModel {
    st: u8,
    log: Vec<(u8, u8)>,
    drain_wins: u32,
    readmits: u32,
    pc: [usize; 4],
    violation: Option<String>,
}

impl StateMachineModel {
    const WATCHDOG: usize = 0;
    const OPERATOR: usize = 1;
    const RETIRER: usize = 2;
    const READMITTER: usize = 3;

    pub fn new() -> Self {
        StateMachineModel {
            st: ST_HEALTHY,
            log: Vec::new(),
            drain_wins: 0,
            readmits: 0,
            pc: [0; 4],
            violation: None,
        }
    }

    fn apply(&mut self, from: u8, to: u8) {
        self.log.push((from, to));
        self.st = to;
    }

    /// CAS semantics: transition only if the current state matches.
    fn cas(&mut self, from: u8, to: u8) -> bool {
        if self.st == from {
            self.apply(from, to);
            true
        } else {
            false
        }
    }
}

impl Default for StateMachineModel {
    fn default() -> Self {
        Self::new()
    }
}

impl Model for StateMachineModel {
    fn reset(&mut self) {
        *self = StateMachineModel::new();
    }

    fn threads(&self) -> usize {
        4
    }

    fn describe(&self, tid: usize) -> String {
        match tid {
            Self::WATCHDOG => "watchdog: CAS Healthy -> Draining".into(),
            Self::OPERATOR => "operator: CAS Healthy -> Draining".into(),
            Self::RETIRER => "retirer: Draining -> Retired".into(),
            Self::READMITTER => match self.pc[Self::READMITTER] {
                0 => "readmitter: CAS Retired -> Readmitting".into(),
                _ => "readmitter: CAS Readmitting -> Healthy".into(),
            },
            _ => unreachable!(),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            Self::WATCHDOG | Self::OPERATOR => {
                if self.readmits > 0 {
                    // Probation: a freshly readmitted device is held
                    // out of watchdog/operator drains; without this a
                    // late scheduling would legally start a second
                    // lifecycle and the single-cycle accounting below
                    // would misfire.
                    return Step::Done;
                }
                // Both race the same CAS; losing is a clean no-op.
                if self.cas(ST_HEALTHY, ST_DRAINING) {
                    self.drain_wins += 1;
                }
                Step::Done
            }
            Self::RETIRER => {
                if self.st == ST_DRAINING {
                    self.apply(ST_DRAINING, ST_RETIRED);
                    Step::Done
                } else {
                    Step::Blocked
                }
            }
            Self::READMITTER => match self.pc[Self::READMITTER] {
                0 => {
                    if self.cas(ST_RETIRED, ST_READMITTING) {
                        self.pc[Self::READMITTER] = 1;
                        Step::Progress
                    } else {
                        Step::Blocked
                    }
                }
                _ => {
                    if self.cas(ST_READMITTING, ST_HEALTHY) {
                        self.readmits += 1;
                        Step::Done
                    } else {
                        self.violation = Some(format!(
                            "readmit finish raced: state is {} not Readmitting",
                            st_name(self.st)
                        ));
                        Step::Done
                    }
                }
            },
            _ => unreachable!(),
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        const LEGAL: [(u8, u8); 4] = [
            (ST_HEALTHY, ST_DRAINING),
            (ST_DRAINING, ST_RETIRED),
            (ST_RETIRED, ST_READMITTING),
            (ST_READMITTING, ST_HEALTHY),
        ];
        for &(from, to) in &self.log {
            if !LEGAL.contains(&(from, to)) {
                return Err(format!(
                    "illegal transition {} -> {}",
                    st_name(from),
                    st_name(to)
                ));
            }
        }
        if self.drain_wins > 1 {
            return Err("both watchdog and operator won Healthy -> Draining".into());
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if self.st != ST_HEALTHY {
            return Err(format!("terminal state is {}", st_name(self.st)));
        }
        if self.drain_wins != 1 || self.readmits != 1 {
            return Err(format!(
                "lifecycle miscounted: {} drain wins, {} readmits",
                self.drain_wins, self.readmits
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// IndexQueue admission/publish protocol
// ---------------------------------------------------------------------------

/// IndexQueue (capacity 2): two enqueuers and two dequeuers running
/// the real three-phase protocol — counter admission (with undo),
/// position reservation, then publish-CAS / consume-swap against the
/// slot array. The count goes transiently negative by design; the
/// invariant is value conservation, not count shape.
pub struct QueueModel {
    count: i32,
    front: u32,
    back: u32,
    slots: [u32; 2],
    accepted: Vec<u32>,
    got: Vec<u32>,
    /// pc per thread; enqueuers carry their reserved position.
    pc: [usize; 4],
    pos: [u32; 4],
    violation: Option<String>,
}

impl QueueModel {
    const CAP: i32 = 2;
    const EMPTY: u32 = 0;
    /// Values the enqueuers publish (non-zero; 0 is the EMPTY mark).
    const VALS: [u32; 2] = [101, 202];

    pub fn new() -> Self {
        QueueModel {
            count: 0,
            front: 0,
            back: 0,
            slots: [Self::EMPTY; 2],
            accepted: Vec::new(),
            got: Vec::new(),
            pc: [0; 4],
            pos: [0; 4],
            violation: None,
        }
    }
}

impl Default for QueueModel {
    fn default() -> Self {
        Self::new()
    }
}

impl Model for QueueModel {
    fn reset(&mut self) {
        *self = QueueModel::new();
    }

    fn threads(&self) -> usize {
        4
    }

    fn describe(&self, tid: usize) -> String {
        if tid < 2 {
            match self.pc[tid] {
                0 => format!("enq{tid}: admission fetch_add(count)"),
                1 => format!("enq{tid}: reserve position fetch_add(back)"),
                2 => format!("enq{tid}: publish CAS slot[{}]", self.pos[tid] & 1),
                _ => format!("enq{tid}: done"),
            }
        } else {
            let d = tid - 2;
            match self.pc[tid] {
                0 => format!("deq{d}: admission fetch_sub(count)"),
                1 => format!("deq{d}: reserve position fetch_add(front)"),
                2 => format!("deq{d}: consume swap slot[{}]", self.pos[tid] & 1),
                _ => format!("deq{d}: done"),
            }
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        if tid < 2 {
            match self.pc[tid] {
                0 => {
                    // fetch_add admission; undo on overflow.
                    let prev = self.count;
                    self.count += 1;
                    if prev >= Self::CAP {
                        self.count -= 1;
                        self.pc[tid] = 3;
                        return Step::Done;
                    }
                    self.pc[tid] = 1;
                    Step::Progress
                }
                1 => {
                    self.pos[tid] = self.back;
                    self.back = self.back.wrapping_add(1);
                    self.accepted.push(Self::VALS[tid]);
                    self.pc[tid] = 2;
                    Step::Progress
                }
                2 => {
                    let s = (self.pos[tid] & 1) as usize;
                    // Publish CAS EMPTY -> value; a prior tenant still
                    // in the slot means we spin (Blocked).
                    if self.slots[s] != Self::EMPTY {
                        return Step::Blocked;
                    }
                    self.slots[s] = Self::VALS[tid];
                    self.pc[tid] = 3;
                    Step::Done
                }
                _ => Step::Done,
            }
        } else {
            match self.pc[tid] {
                0 => {
                    let prev = self.count;
                    self.count -= 1;
                    if prev <= 0 {
                        // Empty: undo and retry the admission later.
                        self.count += 1;
                        return Step::Blocked;
                    }
                    self.pc[tid] = 1;
                    Step::Progress
                }
                1 => {
                    self.pos[tid] = self.front;
                    self.front = self.front.wrapping_add(1);
                    self.pc[tid] = 2;
                    Step::Progress
                }
                2 => {
                    let s = (self.pos[tid] & 1) as usize;
                    // Consume swap(EMPTY); publisher not there yet
                    // means spin.
                    if self.slots[s] == Self::EMPTY {
                        return Step::Blocked;
                    }
                    let v = std::mem::replace(&mut self.slots[s], Self::EMPTY);
                    self.got.push(v);
                    self.pc[tid] = 3;
                    Step::Done
                }
                _ => Step::Done,
            }
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        // Count is transiently out of [0, CAP] by design, but bounded
        // by the number of concurrently mid-admission threads.
        if !(-2..=Self::CAP + 2).contains(&self.count) {
            return Err(format!("count escaped its envelope: {}", self.count));
        }
        if self.got.iter().any(|v| !self.accepted.contains(v)) {
            return Err("dequeued a value never accepted".into());
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        // Conservation: accepted == got ∪ values still in slots.
        let mut have: Vec<u32> = self.got.clone();
        have.extend(self.slots.iter().copied().filter(|&v| v != Self::EMPTY));
        let mut want = self.accepted.clone();
        have.sort_unstable();
        want.sort_unstable();
        if have != want {
            return Err(format!(
                "value conservation broken: accepted {want:?}, accounted {have:?}"
            ));
        }
        let outstanding = self.accepted.len() as i32 - self.got.len() as i32;
        if self.count != outstanding {
            return Err(format!(
                "terminal count {} != outstanding {}",
                self.count, outstanding
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Cross-group federation: spillover, tag-routed frees, durable restart
// ---------------------------------------------------------------------------

/// Federation (2 groups, capacity 1 each): two clients whose primary is
/// group 0, a restarter that tears group 0 down and rebuilds it from
/// its durable handoff, and a healer that fails placements back once
/// the spilled-away-from group recovers.
///
/// The model abstracts each group to the set of block names it
/// currently honors (live blocks + restored forwarding promises — the
/// union is what "the group knows this name" means to a free). The
/// protocol steps mirror `coordinator/federation.rs`:
///
/// * a client allocs at its primary; a full primary latches `spilled`
///   and the placement spills to the standby, tagging the address with
///   the serving group;
/// * a free routes purely by the address's group tag and must land on a
///   group that knows the name;
/// * the restarter snapshots group 0's name table and rebuilds the
///   group from it ([`FederationModel::fixed`]) — or, in the
///   [`FederationModel::buggy`] variant, rebuilds with an *empty* table
///   (the restart-wipes-names bug the durable snapshot exists to
///   prevent), so any schedule that interleaves a restart between an
///   alloc and its free loses the block;
/// * the healer un-latches group 0 only once capacity is actually free
///   again (the failback probe).
///
/// Invariants: a group never holds more names than its capacity, a
/// tag-routed free is never lost, and at quiescence every block has
/// been freed, the latch is clear iff it was ever set, and a spill
/// implies exactly one failback.
pub struct FederationModel {
    buggy: bool,
    /// Names each group currently honors (live set ∪ restored
    /// forwarding promises).
    names: [Vec<usize>; 2],
    /// Placement latch on group 0 (the only contended group).
    spilled: bool,
    spill_events: u32,
    failbacks: u32,
    restarts: u32,
    allocs: u32,
    frees: u32,
    spilled_allocs: u32,
    cross_frees: u32,
    pc: [usize; 4],
    /// Each client's federated address: (serving group, name).
    addr: [Option<(usize, usize)>; 2],
    violation: Option<String>,
}

/// Per-group capacity in the model (1 forces the spillover path).
const FED_CAP: usize = 1;

impl FederationModel {
    const CLIENT_A: usize = 0;
    const CLIENT_B: usize = 1;
    const RESTARTER: usize = 2;
    const HEALER: usize = 3;

    /// The shipped protocol: the restart rebuilds group 0 from its
    /// durable handoff, so every name survives.
    pub fn fixed() -> Self {
        Self::new(false)
    }

    /// The bug the snapshot layer prevents: the restart comes back with
    /// an empty name table. The explorer must find a lost block.
    pub fn buggy() -> Self {
        Self::new(true)
    }

    fn new(buggy: bool) -> Self {
        FederationModel {
            buggy,
            names: [Vec::new(), Vec::new()],
            spilled: false,
            spill_events: 0,
            failbacks: 0,
            restarts: 0,
            allocs: 0,
            frees: 0,
            spilled_allocs: 0,
            cross_frees: 0,
            pc: [0; 4],
            addr: [None, None],
            violation: None,
        }
    }

    fn clients_done(&self) -> bool {
        self.pc[Self::CLIENT_A] >= 2 && self.pc[Self::CLIENT_B] >= 2
    }

    /// One client allocation: primary group 0 unless latched/full, else
    /// spill to group 1 (latching group 0). Blocked when both groups
    /// are full — the federation water-fills by retrying, it never
    /// fails the caller while a slot can still free up.
    fn step_alloc(&mut self, client: usize) -> Step {
        let name = 100 + client;
        let primary_open =
            !self.spilled && self.names[0].len() < FED_CAP;
        let g = if primary_open {
            0
        } else if self.names[1].len() < FED_CAP {
            // The spill path latches the primary on the way past
            // (idempotent, one spill event per latch transition).
            if !self.spilled && self.names[0].len() >= FED_CAP {
                self.spilled = true;
                self.spill_events += 1;
            }
            1
        } else {
            return Step::Blocked;
        };
        self.names[g].push(name);
        self.addr[client] = Some((g, name));
        self.allocs += 1;
        if g != 0 {
            self.spilled_allocs += 1;
        }
        Step::Progress
    }

    /// One client free: route purely by the address's group tag. A
    /// group that no longer knows the name is a lost block.
    fn step_free(&mut self, client: usize) -> Step {
        let (g, name) = self.addr[client].take().expect("free before alloc");
        match self.names[g].iter().position(|&n| n == name) {
            Some(i) => {
                self.names[g].remove(i);
                self.frees += 1;
                if g != 0 {
                    self.cross_frees += 1;
                }
            }
            None => {
                self.violation = Some(format!(
                    "block {name} lost: its tag routes to group {g}, but \
                     the group no longer knows the name (restart wiped \
                     the table?)"
                ));
            }
        }
        Step::Done
    }
}

impl Model for FederationModel {
    fn reset(&mut self) {
        *self = FederationModel::new(self.buggy);
    }

    fn threads(&self) -> usize {
        4
    }

    fn describe(&self, tid: usize) -> String {
        match tid {
            Self::CLIENT_A | Self::CLIENT_B => {
                let who = if tid == Self::CLIENT_A { "A" } else { "B" };
                match self.pc[tid] {
                    0 => format!("client {who}: alloc at primary 0, spill past pressure"),
                    _ => format!("client {who}: free by group tag"),
                }
            }
            Self::RESTARTER => {
                "restarter: kill group 0, rebuild from handoff".into()
            }
            Self::HEALER => "healer: probe group 0, fail back if recovered".into(),
            _ => unreachable!(),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            Self::CLIENT_A | Self::CLIENT_B => match self.pc[tid] {
                0 => {
                    let s = self.step_alloc(tid);
                    if s == Step::Progress {
                        self.pc[tid] = 1;
                    }
                    s
                }
                _ => {
                    self.pc[tid] = 2;
                    self.step_free(tid)
                }
            },
            Self::RESTARTER => {
                // prepare_handoff captures the table after the workers
                // join; start_group_restored re-applies it. The buggy
                // variant rebuilds with an empty table instead.
                self.restarts += 1;
                if self.buggy {
                    self.names[0].clear();
                }
                Step::Done
            }
            Self::HEALER => {
                if self.spilled {
                    if self.names[0].len() < FED_CAP {
                        // Recovery proven: un-latch, placements fail
                        // back.
                        self.spilled = false;
                        self.failbacks += 1;
                        Step::Done
                    } else {
                        Step::Blocked
                    }
                } else if self.clients_done() {
                    // No spill can happen any more; nothing to heal.
                    Step::Done
                } else {
                    Step::Blocked
                }
            }
            _ => unreachable!(),
        }
    }

    fn check(&self) -> Result<(), String> {
        if let Some(v) = &self.violation {
            return Err(v.clone());
        }
        for (g, names) in self.names.iter().enumerate() {
            if names.len() > FED_CAP {
                return Err(format!(
                    "group {g} over capacity: holds {:?}",
                    names
                ));
            }
        }
        if self.spilled_allocs > 0 && self.spill_events == 0 {
            return Err("spilled placement without a latched spill".into());
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if self.allocs != 2 || self.frees != 2 {
            return Err(format!(
                "conservation: {} allocs / {} frees (want 2/2)",
                self.allocs, self.frees
            ));
        }
        if !self.names[0].is_empty() || !self.names[1].is_empty() {
            return Err(format!(
                "blocks leaked at quiescence: {:?} / {:?}",
                self.names[0], self.names[1]
            ));
        }
        if self.spilled {
            return Err("group 0 still latched after recovery".into());
        }
        if self.spill_events != self.failbacks {
            return Err(format!(
                "{} spills but {} failbacks",
                self.spill_events, self.failbacks
            ));
        }
        if self.restarts != 1 {
            return Err(format!("restarter ran {} times", self.restarts));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Client-cache lease serve/recall handshake
// ---------------------------------------------------------------------------

/// The block index the cross-client freer hands back via the delayed
/// list. The owner starts with an empty local list, so any block it
/// serves was refilled from the delayed hand-off — both invariants
/// (serve-vs-recall and consume-once) run through the same trace.
const LEASE_DELAYED: u32 = 7;

/// The lease cache's serve/recall protocol (`coordinator::lease`):
/// an owner serving a block under the pin handshake, a cross-client
/// freer pushing a delayed free, and a recaller (drain) latching the
/// recall flag, quiescing the pins, and migrating the span. The real
/// code re-checks the recall flag *after* raising the pin (SeqCst on
/// both sides); the `buggy` variant checks before pinning — the
/// classic check-then-act TOCTOU — and an interleaving exists where
/// the recaller quiesces between the check and the pin, so the owner
/// serves a block out of a span that has already migrated.
pub struct LeaseModel {
    pub buggy: bool,
    /// Recall flag (SeqCst store by the recaller).
    recalled: bool,
    /// The recaller finished quiescing and moved the span.
    migrated: bool,
    /// Owner serve pins in flight.
    pins: u32,
    /// Owner-private free list (mimalloc page free list).
    local: Vec<u32>,
    /// Cross-client delayed-free list.
    delayed: Vec<u32>,
    /// Blocks the owner handed out.
    served: Vec<u32>,
    /// Delayed entries consumed by drains (must never exceed one —
    /// the real list is taken with `swap(0)`).
    drained: u32,
    /// A block was served after the span migrated: the violation.
    served_after_migrate: bool,
    opc: usize,
    xpc: usize,
    rpc: usize,
}

impl LeaseModel {
    const OWNER: usize = 0;
    const XFREER: usize = 1;
    const RECALLER: usize = 2;

    pub fn fixed() -> Self {
        Self::with_mode(false)
    }

    pub fn buggy() -> Self {
        Self::with_mode(true)
    }

    fn with_mode(buggy: bool) -> Self {
        LeaseModel {
            buggy,
            recalled: false,
            migrated: false,
            pins: 0,
            local: Vec::new(),
            delayed: Vec::new(),
            served: Vec::new(),
            drained: 0,
            served_after_migrate: false,
            opc: 0,
            xpc: 0,
            rpc: 0,
        }
    }

    /// Drain the delayed list into the local list (serve refill or
    /// surrender), counting consumption.
    fn drain_delayed(&mut self) {
        self.drained += self.delayed.len() as u32;
        let taken: Vec<u32> = self.delayed.drain(..).collect();
        self.local.extend(taken);
    }

    /// Surrender: release the lease, draining what the owner still
    /// holds (the free bits stay authoritative for the rest).
    fn surrender(&mut self) {
        self.drain_delayed();
        self.local.clear();
    }
}

impl Model for LeaseModel {
    fn reset(&mut self) {
        *self = Self::with_mode(self.buggy);
    }

    fn threads(&self) -> usize {
        3
    }

    fn describe(&self, tid: usize) -> String {
        match tid {
            Self::OWNER => {
                let (pin, chk) = if self.buggy { (1, 0) } else { (0, 1) };
                match self.opc {
                    pc if pc == pin => "owner: raise serve pin".into(),
                    pc if pc == chk => {
                        if self.buggy {
                            "owner: check recall flag (before pinning — buggy)"
                                .into()
                        } else {
                            "owner: re-check recall flag under the pin".into()
                        }
                    }
                    2 => "owner: refill local list from delayed".into(),
                    3 => "owner: pop local list, take block".into(),
                    4 => "owner: drop serve pin".into(),
                    _ => "owner: flush (surrender lease, drain delayed)"
                        .into(),
                }
            }
            Self::XFREER => match self.xpc {
                0 => "xfreer: set the block's free bit".into(),
                _ => "xfreer: push onto the delayed-free list".into(),
            },
            Self::RECALLER => match self.rpc {
                0 => "recaller: latch the recall flag".into(),
                1 => "recaller: spin until serve pins quiesce".into(),
                _ => "recaller: migrate the span".into(),
            },
            _ => unreachable!(),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            Self::OWNER => {
                let pc = self.opc;
                if self.buggy {
                    match pc {
                        0 => {
                            // Buggy order: recall checked with no pin
                            // held — the recaller may quiesce in the
                            // window before pc 1.
                            if self.recalled {
                                self.surrender();
                                self.opc = 6;
                                return Step::Done;
                            }
                            self.opc = 1;
                            Step::Progress
                        }
                        1 => {
                            self.pins += 1;
                            self.opc = 2;
                            Step::Progress
                        }
                        _ => self.step_serve_tail(pc),
                    }
                } else {
                    match pc {
                        0 => {
                            self.pins += 1;
                            self.opc = 1;
                            Step::Progress
                        }
                        1 => {
                            // Real order: the pin is up (SeqCst), so
                            // either the recaller sees it and waits,
                            // or its earlier latch is visible here.
                            if self.recalled {
                                self.pins -= 1;
                                self.surrender();
                                self.opc = 6;
                                return Step::Done;
                            }
                            self.opc = 2;
                            Step::Progress
                        }
                        _ => self.step_serve_tail(pc),
                    }
                }
            }
            Self::XFREER => match self.xpc {
                0 => {
                    // The free bit is the authoritative half; the
                    // model only tracks the list hand-off.
                    self.xpc = 1;
                    Step::Progress
                }
                _ => {
                    self.delayed.push(LEASE_DELAYED);
                    self.xpc = 2;
                    Step::Done
                }
            },
            Self::RECALLER => match self.rpc {
                0 => {
                    self.recalled = true;
                    self.rpc = 1;
                    Step::Progress
                }
                1 => {
                    if self.pins > 0 {
                        Step::Blocked
                    } else {
                        self.rpc = 2;
                        Step::Progress
                    }
                }
                _ => {
                    self.migrated = true;
                    self.rpc = 3;
                    Step::Done
                }
            },
            _ => unreachable!(),
        }
    }

    fn check(&self) -> Result<(), String> {
        if self.served_after_migrate {
            return Err(
                "block served out of a recalled span after its migration \
                 (owner's recall check raced the pin quiesce)"
                    .into(),
            );
        }
        if self.drained > 1 {
            return Err(format!(
                "delayed free consumed {} times (swap(0) takes it once)",
                self.drained
            ));
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        let mut seen = Vec::new();
        for &b in &self.served {
            if seen.contains(&b) {
                return Err(format!("block {b} served twice"));
            }
            seen.push(b);
        }
        if self.served.contains(&LEASE_DELAYED) && self.drained != 1 {
            return Err(
                "delayed block served without exactly one drain".into()
            );
        }
        Ok(())
    }
}

impl LeaseModel {
    /// Owner pcs 2..=5, identical in both modes: refill, take, unpin,
    /// flush.
    fn step_serve_tail(&mut self, pc: usize) -> Step {
        match pc {
            2 => {
                if self.local.is_empty() {
                    self.drain_delayed();
                }
                self.opc = 3;
                Step::Progress
            }
            3 => {
                if let Some(b) = self.local.pop() {
                    if self.migrated {
                        // take_block on a span the recaller already
                        // moved: the served name points at freed (or
                        // re-minted) storage.
                        self.served_after_migrate = true;
                    }
                    self.served.push(b);
                }
                self.opc = 4;
                Step::Progress
            }
            4 => {
                self.pins -= 1;
                self.opc = 5;
                Step::Progress
            }
            _ => {
                self.surrender();
                self.opc = 6;
                Step::Done
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Ring notification suppression (virtio EVENT_IDX)
// ---------------------------------------------------------------------------

/// "No interrupt requested": the model's copy of the ring's
/// `EVENT_IDLE` watermark sentinel.
const NOTIFY_IDLE: u32 = u32::MAX;

/// Virtio `vring_need_event`, u32-wrapping: fire iff the publish
/// `old → new` crossed the watermark (the model's copy of the ring's
/// `need_event`).
fn notify_need_event(event: u32, new: u32, old: u32) -> bool {
    new.wrapping_sub(event).wrapping_sub(1) < new.wrapping_sub(old)
}

/// Ring wakeup suppression: one completer racing one blocking waiter
/// over a single completion.
///
/// The shipped protocol publishes the used index (slot COMPLETE store
/// + SeqCst `fetch_add`, one model step) *before* reading the
/// waiter-registration counter and the `used_event` watermark, so in
/// the SeqCst total order either the completer's read sees the
/// registration (and it broadcasts) or the waiter's under-lock
/// re-check sees the completion. The `buggy()` mode caches the
/// suppress-or-deliver verdict *before* the publish — the store-load
/// reordering the real `complete_bulk`'s ordering exists to forbid —
/// and the explorer finds the lost wakeup: the waiter registers,
/// publishes its watermark, re-checks, and parks entirely inside the
/// stale-read window, after which nothing ever wakes it (deadlock).
pub struct NotifyModel {
    pub buggy: bool,
    /// Slot COMPLETE made visible (merged with the index publish: the
    /// real stores are adjacent and same-direction).
    completed: bool,
    /// Published used index.
    used_idx: u32,
    /// Client-published "interrupt me past N" watermark.
    used_event: u32,
    /// Registered blocking waiters (the eager-notify fallback).
    blocked: u32,
    /// Condvar broadcast delivered.
    notified: bool,
    /// The completer's suppress-or-deliver verdict (cached before the
    /// publish in buggy mode).
    deliver: bool,
    delivered: u32,
    suppressed: u32,
    /// The waiter's under-lock re-check saw the completion.
    took_at_recheck: bool,
    /// The waiter consumed the completion.
    taken: bool,
    cpc: usize,
    wpc: usize,
}

impl NotifyModel {
    const COMPLETER: usize = 0;
    const WAITER: usize = 1;

    pub fn fixed() -> Self {
        Self::with_mode(false)
    }

    pub fn buggy() -> Self {
        Self::with_mode(true)
    }

    fn with_mode(buggy: bool) -> Self {
        NotifyModel {
            buggy,
            completed: false,
            used_idx: 0,
            used_event: NOTIFY_IDLE,
            blocked: 0,
            notified: false,
            deliver: false,
            delivered: 0,
            suppressed: 0,
            took_at_recheck: false,
            taken: false,
            cpc: 0,
            wpc: 0,
        }
    }

    /// The completer's suppress-or-deliver read: a registered waiter
    /// forces delivery (the eager fallback); otherwise the watermark
    /// decides. `(new, old)` is the index publish this completion
    /// performs (buggy mode computes it before the publish happens).
    fn decide(&mut self, new: u32, old: u32) {
        self.deliver = self.blocked > 0
            || notify_need_event(self.used_event, new, old);
    }

    fn publish(&mut self) {
        self.completed = true;
        self.used_idx = self.used_idx.wrapping_add(1);
    }

    fn act(&mut self) {
        if self.deliver {
            self.notified = true;
            self.delivered += 1;
        } else {
            self.suppressed += 1;
        }
    }
}

impl Model for NotifyModel {
    fn reset(&mut self) {
        *self = Self::with_mode(self.buggy);
    }

    fn threads(&self) -> usize {
        2
    }

    fn describe(&self, tid: usize) -> String {
        match tid {
            Self::COMPLETER => {
                let (publish, read) = if self.buggy { (1, 0) } else { (0, 1) };
                match self.cpc {
                    pc if pc == publish => {
                        "completer: publish used index (COMPLETE + fetch_add)"
                            .into()
                    }
                    pc if pc == read => {
                        if self.buggy {
                            "completer: read registration + watermark \
                             (before the publish — buggy)"
                                .into()
                        } else {
                            "completer: read registration + watermark"
                                .into()
                        }
                    }
                    _ => {
                        if self.deliver {
                            "completer: deliver the broadcast".into()
                        } else {
                            "completer: suppress the broadcast".into()
                        }
                    }
                }
            }
            Self::WAITER => match self.wpc {
                0 => "waiter: register as blocking (eager fallback)".into(),
                1 => "waiter: publish used_event watermark".into(),
                2 => "waiter: re-check completion under the lock".into(),
                3 => "waiter: park on the condvar / wake".into(),
                _ => "waiter: take the completion, unregister".into(),
            },
            _ => unreachable!(),
        }
    }

    fn step(&mut self, tid: usize) -> Step {
        match tid {
            Self::COMPLETER => {
                let pc = self.cpc;
                self.cpc += 1;
                if self.buggy {
                    match pc {
                        0 => {
                            // Buggy order: verdict cached before the
                            // index is visible.
                            let old = self.used_idx;
                            self.decide(old.wrapping_add(1), old);
                            Step::Progress
                        }
                        1 => {
                            self.publish();
                            Step::Progress
                        }
                        _ => {
                            self.act();
                            Step::Done
                        }
                    }
                } else {
                    match pc {
                        0 => {
                            self.publish();
                            Step::Progress
                        }
                        1 => {
                            // Real order: the index is published, so a
                            // waiter not seen here re-checks *after*
                            // the publish and takes the completion.
                            let new = self.used_idx;
                            self.decide(new, new.wrapping_sub(1));
                            Step::Progress
                        }
                        _ => {
                            self.act();
                            Step::Done
                        }
                    }
                }
            }
            Self::WAITER => match self.wpc {
                0 => {
                    self.blocked += 1;
                    self.wpc = 1;
                    Step::Progress
                }
                1 => {
                    self.used_event = self.used_idx;
                    self.wpc = 2;
                    Step::Progress
                }
                2 => {
                    if self.completed {
                        self.took_at_recheck = true;
                    }
                    self.wpc = 3;
                    Step::Progress
                }
                3 => {
                    if self.took_at_recheck || self.notified {
                        self.wpc = 4;
                        Step::Progress
                    } else {
                        Step::Blocked
                    }
                }
                _ => {
                    self.blocked -= 1;
                    self.taken = true;
                    Step::Done
                }
            },
            _ => unreachable!(),
        }
    }

    fn check(&self) -> Result<(), String> {
        if self.taken && !self.completed {
            return Err(
                "waiter took a completion that was never published".into()
            );
        }
        if self.delivered + self.suppressed > 1 {
            return Err(format!(
                "one completion decided {} times",
                self.delivered + self.suppressed
            ));
        }
        // The completer is done and suppressed its broadcast, but the
        // waiter already re-checked (missed) and is at the park with
        // nothing left to wake it — the lost wakeup, caught here
        // rather than as a generic deadlock so the counterexample
        // replays through `Explorer::replay` (which re-runs steps, not
        // the runnable-set analysis).
        if self.cpc >= 3
            && self.suppressed == 1
            && self.wpc == 3
            && !self.took_at_recheck
            && !self.notified
        {
            return Err(
                "lost wakeup: broadcast suppressed while a registered \
                 waiter parked inside the stale-read window"
                    .into(),
            );
        }
        Ok(())
    }

    fn check_final(&self) -> Result<(), String> {
        if !self.taken {
            return Err("completion never consumed".into());
        }
        if self.blocked != 0 {
            return Err(format!(
                "waiter registration leaked: blocked = {}",
                self.blocked
            ));
        }
        if self.delivered + self.suppressed != 1 {
            return Err(format!(
                "completion decided {} + {} times",
                self.delivered, self.suppressed
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::check::sched::Explorer;

    #[test]
    fn all_fixed_models_pass_quick_exhaustive() {
        let ex = Explorer::default();
        ex.exhaustive(&mut RingModel::new()).expect("ring");
        ex.exhaustive(&mut DrainModel::fixed()).expect("drain");
        ex.exhaustive(&mut StateMachineModel::new()).expect("state");
        ex.exhaustive(&mut LeaseModel::fixed()).expect("lease");
        ex.exhaustive(&mut NotifyModel::fixed()).expect("notify");
    }

    #[test]
    fn buggy_notify_order_is_caught() {
        let ce = Explorer::default()
            .exhaustive(&mut NotifyModel::buggy())
            .expect_err("watermark-before-publish must lose a wakeup");
        assert!(ce.error.contains("lost wakeup"), "{ce}");
    }

    #[test]
    fn buggy_lease_recall_check_is_caught() {
        let ce = Explorer::default()
            .exhaustive(&mut LeaseModel::buggy())
            .expect_err("check-before-pin must race the quiesce");
        assert!(ce.error.contains("after its migration"), "{ce}");
    }

    #[test]
    fn buggy_drain_order_is_caught() {
        let ce = Explorer::default()
            .exhaustive(&mut DrainModel::buggy())
            .expect_err("check-then-raise must race enumeration");
        assert!(ce.error.contains("slipped past enumeration"), "{ce}");
    }
}
