//! Concurrent-history recording for the linearizability leg of the
//! analysis layer.
//!
//! Armed by `OURO_LIN=1` (mirroring the `OURO_SAN` `from_env`
//! pattern), a [`HistoryRecorder`] rides inside each service `Inner`
//! and collects one [`OpRecord`] per *successful* heap-effecting
//! operation — ring allocs/frees at dispatch, cached allocs/frees at
//! the client fast path, lease carve/recall/return, and migrations.
//! Each record is an **interval**: `inv_ns` is stamped before the
//! op's heap effect (at ring claim for submitted ops, at function
//! entry for cached ones) and `res_ns` after it, both from the same
//! process-wide monotonic clock (`ring::mono_ns`). Because every
//! linearization point falls inside its op's interval, every
//! precedence edge the checker derives (`res_a < inv_b`) is a true
//! precedence — the recorder can never manufacture a false violation.
//!
//! Failed or rolled-back operations record nothing: an unrecorded op
//! constrains nothing, so dropping them is sound (the shadow heap
//! already polices bookkeeping of the rollback paths themselves).
//!
//! Writes go to per-thread buffers (one tiny mutex per thread,
//! uncontended by construction) registered with the recorder;
//! [`HistoryRecorder::harvest`] merges and sorts them by invocation
//! time for [`crate::check::linearize::check`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// What an operation did to the heap, from the spec's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// A ring or cached alloc that returned `addr`.
    Alloc,
    /// A ring or cached free of `addr`.
    Free,
    /// A migration landing `addr` on this (device, class) partition.
    MigrateIn,
    /// A migration removing `addr` from this partition.
    MigrateOut,
    /// A lease span carved for a client cache (`addr` = origin span).
    LeaseCarve,
    /// A recall handshake on a live lease (`addr` = origin span).
    LeaseRecall,
    /// A lease span returned to the heap (`addr` = origin span).
    LeaseReturn,
}

/// One completed operation interval. `device`/`class` key the
/// partition; lease ops use the lease *origin* device and class so a
/// relocated span stays in the partition its cached names belong to.
#[derive(Clone, Copy, Debug)]
pub struct OpRecord {
    /// Invocation timestamp (monotonic ns), stamped before the heap
    /// effect.
    pub inv_ns: u64,
    /// Response timestamp (monotonic ns), stamped after the heap
    /// effect.
    pub res_ns: u64,
    /// The client handle (or worker pseudo-handle) that drove the op.
    pub client: u64,
    pub kind: OpKind,
    pub device: u32,
    /// Size-class queue index (the ring queue for submitted ops, the
    /// lease class for lease ops).
    pub class: u32,
    /// The address the op produced or consumed.
    pub addr: u32,
    /// Lease instance discriminator: 0 for ring/heap ops, the unique
    /// [`crate::coordinator::lease::Lease`] id for span ops *and*
    /// cached-block ops served from that lease. Cached blocks keep
    /// origin-based names even after the span relocates, so once the
    /// origin chunk is re-minted by the heap the same raw address can
    /// legitimately be live in both worlds at once — the id keeps the
    /// two specs in separate partitions.
    pub lease_id: u64,
}

impl OpRecord {
    /// Lease ops live in a separate spec partition from block ops:
    /// span carve/return talk about the *span base* address, which
    /// aliases block 0 of the span in the block space.
    pub fn is_lease(&self) -> bool {
        matches!(
            self.kind,
            OpKind::LeaseCarve | OpKind::LeaseRecall | OpKind::LeaseReturn
        )
    }
}

/// A per-thread record buffer. The mutex is per-thread and therefore
/// uncontended on the write path; harvest takes them all once.
struct ThreadBuf {
    recs: Mutex<Vec<OpRecord>>,
}

/// The per-service history recorder. Cloned by `Arc` into every lane
/// worker and client handle; survives `restart_group` by riding the
/// `Handoff` exactly like the shadow heap does, so a harvested
/// history spans restarts.
pub struct HistoryRecorder {
    /// Process-unique recorder identity (an `Arc` address could be
    /// reused after a drop and misdirect a thread's cached buffer).
    id: u64,
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
    /// Running count of recorded ops, for cheap progress asserts
    /// without harvesting.
    count: AtomicU64,
}

thread_local! {
    /// recorder id → this thread's buffer in it.
    static LOCAL: std::cell::RefCell<Vec<(u64, Arc<ThreadBuf>)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl HistoryRecorder {
    pub fn new() -> Arc<HistoryRecorder> {
        static NEXT_ID: AtomicU64 = AtomicU64::new(1);
        Arc::new(HistoryRecorder {
            // ordering: Relaxed — a unique-id counter; no memory is
            // published through it.
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            bufs: Mutex::new(Vec::new()),
            count: AtomicU64::new(0),
        })
    }

    /// `OURO_LIN=1` (any non-empty value other than `0`) arms
    /// recording — the same contract as `OURO_SAN`.
    pub fn from_env() -> Option<Arc<HistoryRecorder>> {
        match std::env::var("OURO_LIN") {
            Ok(v) if !v.is_empty() && v != "0" => Some(Self::new()),
            _ => None,
        }
    }

    fn local_buf(self: &Arc<Self>) -> Arc<ThreadBuf> {
        let key = self.id;
        LOCAL.with(|l| {
            let mut l = l.borrow_mut();
            if let Some((_, b)) = l.iter().find(|(k, _)| *k == key) {
                return b.clone();
            }
            let buf = Arc::new(ThreadBuf { recs: Mutex::new(Vec::new()) });
            self.bufs
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .push(buf.clone());
            l.push((key, buf.clone()));
            buf
        })
    }

    /// Append one completed op interval. Cost when armed: one
    /// thread-local lookup + one push under an uncontended mutex.
    pub fn record(self: &Arc<Self>, rec: OpRecord) {
        debug_assert!(rec.inv_ns <= rec.res_ns, "interval inverted");
        let buf = self.local_buf();
        buf.recs.lock().unwrap_or_else(PoisonError::into_inner).push(rec);
        // ordering: Relaxed — a monotonic progress counter read only by
        // tests after the threads of interest have been joined.
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of ops recorded so far.
    pub fn len(&self) -> u64 {
        // ordering: Relaxed — see `record`; exactness only matters
        // after joins, which synchronize.
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Merge every thread's buffer into one history sorted by
    /// invocation time. Non-destructive: harvesting twice returns the
    /// same (possibly grown) history.
    pub fn harvest(&self) -> Vec<OpRecord> {
        let bufs = self.bufs.lock().unwrap_or_else(PoisonError::into_inner);
        let mut all: Vec<OpRecord> = Vec::new();
        for b in bufs.iter() {
            all.extend(
                b.recs
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .iter()
                    .copied(),
            );
        }
        all.sort_by_key(|r| (r.inv_ns, r.res_ns, r.addr));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(inv: u64, res: u64, addr: u32) -> OpRecord {
        OpRecord {
            inv_ns: inv,
            res_ns: res,
            client: 1,
            kind: OpKind::Alloc,
            device: 0,
            class: 0,
            addr,
            lease_id: 0,
        }
    }

    #[test]
    fn harvest_merges_across_threads_sorted_by_invocation() {
        let r = HistoryRecorder::new();
        r.record(rec(30, 40, 3));
        let r2 = r.clone();
        std::thread::spawn(move || {
            r2.record(rec(10, 20, 1));
            r2.record(rec(20, 25, 2));
        })
        .join()
        .unwrap();
        let h = r.harvest();
        assert_eq!(h.len(), 3);
        assert_eq!(r.len(), 3);
        assert_eq!(
            h.iter().map(|o| o.addr).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        // Non-destructive.
        assert_eq!(r.harvest().len(), 3);
    }

    #[test]
    fn from_env_contract_matches_san() {
        // Not set / "0" / "" → off; anything else → on. Exercised via
        // the same parsing the sanitizer uses; avoid mutating process
        // env in-test (other tests run concurrently) by checking the
        // default path only.
        if std::env::var("OURO_LIN").is_err() {
            assert!(HistoryRecorder::from_env().is_none());
        }
    }
}
