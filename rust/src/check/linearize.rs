//! A Wing & Gong-style linearizability checker over recorded
//! allocation histories — the real-execution counterpart of
//! `Explorer::replay`'s model counterexamples.
//!
//! The history (from [`crate::check::history::HistoryRecorder`]) is
//! first split into independent **partitions** keyed by
//! `(device, class, lease?, lease id)` — Lowe's observation that a
//! history over
//! a product of independent objects is linearizable iff each
//! projection is, which keeps chaos-scale histories (tens of
//! thousands of ops) tractable. Within the allocator, partitions
//! really are independent: each (device, size-class) free list is its
//! own sequential object, and the lease table per origin device is
//! another (span bases alias block 0 of the span in the block space,
//! which is why lease ops get their own partition — `cacheable_class`
//! excludes the span class, so no cached block ever shares a
//! partition with a span op). Cached-block ops additionally carry
//! their lease's unique id: a relocated span's origin chunk can be
//! re-minted by the heap while the cache still serves origin-based
//! names, making the same raw address legitimately live in both
//! worlds — distinct partitions, not a violation.
//!
//! Within a partition the checker runs the classic algorithm: try to
//! extend a linearization one operation at a time, choosing among the
//! **candidates** (ops whose invocation precedes every pending op's
//! response — i.e. minimal in the precedence order), applying each to
//! the sequential spec, backtracking on spec rejection, and memoizing
//! visited (linearized-set) states so revisits cut off. The spec
//! state is a pure function of *which* ops have been linearized
//! (each op names its address and effect), so the memo key is an
//! incremental XOR of per-op splitmix64 hashes — O(1) to update and
//! order-independent, exactly what set-memoization needs.
//!
//! The sequential specification per block partition: an address may
//! be allocated only while **not live** (Alloc/MigrateIn insert,
//! rejecting duplicates) and freed only while **live** (Free/
//! MigrateOut remove, rejecting misses). Per lease partition: a span
//! may be carved only while absent, returned only while present, and
//! recalled only while present. On failure the checker reports a
//! **minimal non-linearizable window**: the shortest suffix of the
//! partition (by invocation order) that is itself non-linearizable,
//! plus the concrete ops the deepest search frontier choked on, with
//! their real timestamps.

use crate::check::history::{OpKind, OpRecord};
use std::collections::{BTreeMap, HashSet};

/// A proven non-linearizable partition, minimized for diagnosis.
#[derive(Debug, Clone)]
pub struct Violation {
    pub device: u32,
    pub class: u32,
    pub lease: bool,
    /// Lease instance id (0 for ring/heap partitions).
    pub lease_id: u64,
    /// The minimal non-linearizable suffix of the partition, in
    /// invocation order.
    pub window: Vec<OpRecord>,
    /// Human-oriented account of what the deepest frontier could not
    /// linearize.
    pub reason: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "non-linearizable history on device {} class {}{}: {}",
            self.device,
            self.class,
            if self.lease {
                format!(" (lease {})", self.lease_id)
            } else if self.lease_id != 0 {
                format!(" (cached blocks, lease {})", self.lease_id)
            } else {
                String::new()
            },
            self.reason
        )?;
        writeln!(f, "minimal window ({} ops):", self.window.len())?;
        for op in &self.window {
            writeln!(
                f,
                "  [{:>12}ns, {:>12}ns] client {:>3} {:?} addr {:#x}",
                op.inv_ns, op.res_ns, op.client, op.kind, op.addr
            )?;
        }
        Ok(())
    }
}

/// Summary of a successful check.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub ops: usize,
    pub partitions: usize,
    /// Largest single partition checked (the tractability number).
    pub max_partition_ops: usize,
}

/// Check a harvested history. `Ok(report)` means every partition is
/// linearizable w.r.t. the allocator spec; `Err(violation)` carries
/// the minimal failing window of the first failing partition.
pub fn check(history: &[OpRecord]) -> Result<Report, Violation> {
    let mut parts: BTreeMap<(u32, u32, bool, u64), Vec<OpRecord>> =
        BTreeMap::new();
    for op in history {
        parts
            .entry((op.device, op.class, op.is_lease(), op.lease_id))
            .or_default()
            .push(op.clone());
    }
    let mut report = Report {
        ops: history.len(),
        partitions: parts.len(),
        max_partition_ops: 0,
    };
    for ((device, class, lease, lease_id), mut ops) in parts {
        ops.sort_by_key(|o| (o.inv_ns, o.res_ns, o.addr));
        report.max_partition_ops = report.max_partition_ops.max(ops.len());
        if let Err((reason, frontier)) = linearize_partition(&ops) {
            let window = minimize_window(&ops, frontier);
            return Err(Violation {
                device,
                class,
                lease,
                lease_id,
                window,
                reason,
            });
        }
    }
    Ok(report)
}

/// splitmix64 — cheap, well-mixed per-op hash for the XOR set memo.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// The sequential spec: the set of live addresses in this partition
/// (live blocks, or live lease spans). Returns whether `op` is legal
/// in the current state and applies it if so.
fn apply(live: &mut HashSet<u32>, op: &OpRecord) -> bool {
    match op.kind {
        OpKind::Alloc | OpKind::MigrateIn | OpKind::LeaseCarve => {
            live.insert(op.addr)
        }
        OpKind::Free | OpKind::MigrateOut | OpKind::LeaseReturn => {
            live.remove(&op.addr)
        }
        // A recall is a read-your-state op: legal iff the span is
        // currently live, mutating nothing.
        OpKind::LeaseRecall => live.contains(&op.addr),
    }
}

fn unapply(live: &mut HashSet<u32>, op: &OpRecord) {
    match op.kind {
        OpKind::Alloc | OpKind::MigrateIn | OpKind::LeaseCarve => {
            live.remove(&op.addr);
        }
        OpKind::Free | OpKind::MigrateOut | OpKind::LeaseReturn => {
            live.insert(op.addr);
        }
        OpKind::LeaseRecall => {}
    }
}

/// One frame of the explicit DFS stack: which candidate index we are
/// about to try at this linearization depth.
struct Frame {
    /// Candidate op indices (into `ops`) at this depth, precomputed.
    candidates: Vec<usize>,
    /// Next candidate position in `candidates` to try.
    next: usize,
    /// The op index linearized to *enter* this frame (None for root).
    chosen: Option<usize>,
}

/// Candidates per Lowe: an op is minimal iff no *other* unlinearized
/// op's response precedes its invocation. Scan ops in invocation
/// order, tracking the min response among unlinearized ops seen so
/// far; once an op's invocation exceeds that min response, nothing
/// later can be a candidate.
fn candidates(ops: &[OpRecord], done: &[bool]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut min_res = u64::MAX;
    for (i, op) in ops.iter().enumerate() {
        if done[i] {
            continue;
        }
        if op.inv_ns > min_res {
            break; // ops are inv-sorted: no later op can qualify
        }
        out.push(i);
        min_res = min_res.min(op.res_ns);
    }
    // An op invoked at exactly min_res overlaps (closed intervals), so
    // strict `>` above is the correct cut.
    out
}

/// Wing & Gong with memoized state hashing over one partition.
/// `Err((reason, deepest_frontier))` on failure, where the frontier is
/// the set of candidate ops none of which could be linearized at the
/// deepest point the search reached.
fn linearize_partition(
    ops: &[OpRecord],
) -> Result<(), (String, Vec<usize>)> {
    let n = ops.len();
    if n == 0 {
        return Ok(());
    }
    let mut done = vec![false; n];
    let mut live: HashSet<u32> = HashSet::new();
    let mut memo: HashSet<u64> = HashSet::new();
    let mut hash: u64 = 0;
    let mut linearized = 0usize;
    // Deepest-failure diagnostics.
    let mut best_depth = 0usize;
    let mut best_frontier: Vec<usize> = Vec::new();
    let mut best_live: Vec<u32> = Vec::new();

    let mut stack = vec![Frame {
        candidates: candidates(ops, &done),
        next: 0,
        chosen: None,
    }];

    while let Some(frame) = stack.last_mut() {
        if frame.next == 0 && linearized >= best_depth {
            // Entering (or first visiting) this depth: remember the
            // frontier in case the search dies here.
            best_depth = linearized;
            best_frontier = frame.candidates.clone();
            let mut l: Vec<u32> = live.iter().copied().collect();
            l.sort_unstable();
            best_live = l;
        }
        let mut advanced = false;
        while frame.next < frame.candidates.len() {
            let i = frame.candidates[frame.next];
            frame.next += 1;
            if apply(&mut live, &ops[i]) {
                let h2 = hash ^ splitmix64(i as u64 + 1);
                // Memo on the linearized *set*: spec state is a
                // function of it, so a revisit explores nothing new.
                if memo.insert(h2) {
                    hash = h2;
                    done[i] = true;
                    linearized += 1;
                    if linearized == n {
                        return Ok(());
                    }
                    stack.push(Frame {
                        candidates: candidates(ops, &done),
                        next: 0,
                        chosen: Some(i),
                    });
                    advanced = true;
                    break;
                }
                unapply(&mut live, &ops[i]);
            }
        }
        if !advanced {
            // Exhausted this frame: backtrack.
            let frame = stack.pop().unwrap();
            if let Some(i) = frame.chosen {
                done[i] = false;
                linearized -= 1;
                hash ^= splitmix64(i as u64 + 1);
                unapply(&mut live, &ops[i]);
            }
        }
    }

    // Search space exhausted without completing a linearization.
    let frontier_desc: Vec<String> = best_frontier
        .iter()
        .map(|&i| {
            let op = &ops[i];
            let why = match op.kind {
                OpKind::Alloc | OpKind::MigrateIn | OpKind::LeaseCarve => {
                    if best_live.contains(&op.addr) {
                        format!("addr {:#x} already live", op.addr)
                    } else {
                        "state-hash revisit".to_string()
                    }
                }
                _ => {
                    if best_live.contains(&op.addr) {
                        "state-hash revisit".to_string()
                    } else {
                        format!("addr {:#x} not live", op.addr)
                    }
                }
            };
            format!("{:?} addr {:#x} ({why})", op.kind, op.addr)
        })
        .collect();
    Err((
        format!(
            "no linearization after {best_depth}/{n} ops; stuck frontier: \
             [{}]; live set at frontier: {:?}",
            frontier_desc.join(", "),
            best_live
                .iter()
                .map(|a| format!("{a:#x}"))
                .collect::<Vec<_>>()
        ),
        best_frontier,
    ))
}

/// Minimize the failing partition to the shortest suffix (in
/// invocation order) that is still non-linearizable. Suffixes are
/// sound minimal windows for this spec: a suffix's precedence order
/// is the restriction of the full order, and starting from the empty
/// live set only *weakens* require-present constraints, so a
/// non-linearizable suffix pins the contradiction to ops inside it.
/// The deepest-frontier indices seed the search: the window must
/// include the earliest frontier op.
fn minimize_window(ops: &[OpRecord], frontier: Vec<usize>) -> Vec<OpRecord> {
    let earliest = frontier.iter().copied().min().unwrap_or(0);
    // Binary-search the largest start whose suffix still fails: start
    // can't exceed `earliest` (the frontier op must be inside), and
    // monotonicity isn't guaranteed for arbitrary specs, so walk
    // linearly from `earliest` downward — partitions are small enough
    // after Lowe splitting that this stays cheap.
    let mut start = earliest;
    loop {
        if linearize_partition(&ops[start..]).is_err() {
            return ops[start..].to_vec();
        }
        if start == 0 {
            // The full partition failed but every proper suffix from
            // `earliest` passes with an empty initial state — the
            // contradiction needs the prefix's live set. Fall back to
            // the whole partition.
            return ops.to_vec();
        }
        start -= 1;
        if start < earliest.saturating_sub(64) {
            // Cap the walk; a 64-op window is already a diagnosis.
            return ops[start..].to_vec();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(
        inv: u64,
        res: u64,
        kind: OpKind,
        addr: u32,
        client: u64,
    ) -> OpRecord {
        OpRecord {
            inv_ns: inv,
            res_ns: res,
            client,
            kind,
            device: 0,
            class: 0,
            addr,
            lease_id: 0,
        }
    }

    #[test]
    fn empty_and_sequential_histories_pass() {
        assert!(check(&[]).is_ok());
        let h = vec![
            op(0, 1, OpKind::Alloc, 0x10, 1),
            op(2, 3, OpKind::Free, 0x10, 1),
            op(4, 5, OpKind::Alloc, 0x10, 2),
        ];
        let r = check(&h).unwrap();
        assert_eq!(r.ops, 3);
        assert_eq!(r.partitions, 1);
    }

    #[test]
    fn overlapping_free_and_realloc_linearize() {
        // Free [10,20] overlaps Alloc [12,30] of the same addr: legal
        // (free linearizes first).
        let h = vec![
            op(0, 5, OpKind::Alloc, 0x10, 1),
            op(10, 20, OpKind::Free, 0x10, 1),
            op(12, 30, OpKind::Alloc, 0x10, 2),
        ];
        assert!(check(&h).is_ok());
    }

    #[test]
    fn duplicate_live_alloc_is_rejected_with_window() {
        // Two non-overlapping allocs of the same address with no free
        // between them: no order can linearize the second.
        let h = vec![
            op(0, 5, OpKind::Alloc, 0x10, 1),
            op(10, 15, OpKind::Alloc, 0x10, 2),
            op(20, 25, OpKind::Free, 0x10, 1),
        ];
        let v = check(&h).unwrap_err();
        assert!(v.reason.contains("already live"), "{}", v.reason);
        assert!(!v.window.is_empty());
        assert!(
            v.window.iter().any(|o| o.addr == 0x10
                && matches!(o.kind, OpKind::Alloc)
                && o.inv_ns == 10),
            "window must contain the offending alloc: {v}"
        );
    }

    #[test]
    fn free_of_dead_addr_is_rejected() {
        let h = vec![
            op(0, 5, OpKind::Alloc, 0x10, 1),
            op(10, 15, OpKind::Free, 0x10, 1),
            op(20, 25, OpKind::Free, 0x10, 2),
        ];
        let v = check(&h).unwrap_err();
        assert!(v.reason.contains("not live"), "{}", v.reason);
    }

    #[test]
    fn partitions_are_independent() {
        // Same address on two devices is fine.
        let mut a = op(0, 5, OpKind::Alloc, 0x10, 1);
        let mut b = op(1, 6, OpKind::Alloc, 0x10, 2);
        a.device = 0;
        b.device = 1;
        let r = check(&[a, b]).unwrap();
        assert_eq!(r.partitions, 2);
    }

    #[test]
    fn lease_ops_partition_separately_from_blocks() {
        // Span base aliases block 0: carve (lease space) + alloc
        // (block space) of the same addr must not conflict.
        let carve = op(0, 5, OpKind::LeaseCarve, 0x100, 1);
        let blk = op(1, 6, OpKind::Alloc, 0x100, 2);
        let r = check(&[carve, blk]).unwrap();
        assert_eq!(r.partitions, 2);
    }

    #[test]
    fn cached_blocks_partition_by_lease_id() {
        // A relocated lease's cache still serves origin-based names
        // while the heap re-mints the origin chunk: same raw address,
        // concurrently live in both worlds. The lease id keeps the
        // histories apart.
        let ring = op(0, 5, OpKind::Alloc, 0x40, 1);
        let mut cached = op(1, 6, OpKind::Alloc, 0x40, 2);
        cached.lease_id = 7;
        let r = check(&[ring, cached]).unwrap();
        assert_eq!(r.partitions, 2);
        // Same lease id, same name, both live: still a violation.
        let mut dup = op(10, 15, OpKind::Alloc, 0x40, 3);
        dup.lease_id = 7;
        let v = check(&[cached, dup]).unwrap_err();
        assert_eq!(v.lease_id, 7);
        assert!(!v.lease);
    }

    #[test]
    fn lease_lifecycle_checks() {
        let h = vec![
            op(0, 5, OpKind::LeaseCarve, 0x100, 1),
            op(10, 15, OpKind::LeaseRecall, 0x100, 9),
            op(20, 25, OpKind::LeaseReturn, 0x100, 1),
        ];
        assert!(check(&h).is_ok());
        // Recall after return, non-overlapping: rejected.
        let bad = vec![
            op(0, 5, OpKind::LeaseCarve, 0x100, 1),
            op(10, 15, OpKind::LeaseReturn, 0x100, 1),
            op(20, 25, OpKind::LeaseRecall, 0x100, 9),
        ];
        assert!(check(&bad).is_err());
        // Recall overlapping the return: fine (recall first).
        let racy = vec![
            op(0, 5, OpKind::LeaseCarve, 0x100, 1),
            op(10, 20, OpKind::LeaseReturn, 0x100, 1),
            op(12, 25, OpKind::LeaseRecall, 0x100, 9),
        ];
        assert!(check(&racy).is_ok());
    }

    #[test]
    fn migrate_moves_between_partitions() {
        let mut out = op(10, 15, OpKind::MigrateOut, 0x10, 9);
        out.device = 0;
        let mut inn = op(10, 15, OpKind::MigrateIn, 0x90, 9);
        inn.device = 1;
        let h = vec![op(0, 5, OpKind::Alloc, 0x10, 1), out, inn];
        assert!(check(&h).is_ok());
    }

    #[test]
    fn deep_concurrent_history_stays_tractable() {
        // 64 clients × alloc/free of distinct addrs, all mutually
        // overlapping — candidate sets are wide; memoization must keep
        // this fast.
        let mut h = Vec::new();
        for c in 0..64u32 {
            h.push(op(0, 1000, OpKind::Alloc, 0x1000 + c, c as u64));
            h.push(op(500, 2000, OpKind::Free, 0x1000 + c, c as u64));
        }
        let r = check(&h).unwrap();
        assert_eq!(r.ops, 128);
    }

    #[test]
    fn window_is_minimal_suffix() {
        // A long legal prefix followed by a late contradiction: the
        // window must not drag the whole prefix in.
        let mut h = Vec::new();
        for i in 0..100u32 {
            let t = i as u64 * 10;
            h.push(op(t, t + 1, OpKind::Alloc, i, 1));
            h.push(op(t + 2, t + 3, OpKind::Free, i, 1));
        }
        h.push(op(2000, 2001, OpKind::Alloc, 0x10, 1));
        h.push(op(2010, 2011, OpKind::Alloc, 0x10, 2));
        let v = check(&h).unwrap_err();
        assert!(
            v.window.len() <= 66,
            "window should be a short suffix, got {} ops",
            v.window.len()
        );
    }
}
