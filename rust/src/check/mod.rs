//! Correctness tooling for the alloc service's lock-free protocols:
//! a deterministic model checker and a shadow-heap sanitizer.
//!
//! The service stacks seven hand-rolled concurrency protocols, and both
//! of the bugs that reached `main` historically (the PR 2 TicketRing
//! lost-notification wait, the PR 5 forwarding-grace TOCTOU) were
//! ordering races found by eye after shipping. This module turns that
//! vigilance into tooling. A third leg — the `lint_atomics` source
//! scanner (`rust/src/bin/lint_atomics.rs`) — enforces that every
//! `Ordering::*` site in the tree documents its rationale with a
//! `// ordering:` comment.
//!
//! # The protocols and their invariants
//!
//! * **TicketRing slot lifecycle** ([`models::RingModel`]): a slot is
//!   granted to one client per generation, and a completion is only
//!   consumed by the operation that submitted into that generation.
//! * **ForwardingTable** ([`models::ForwardingModel`]): a migrated
//!   block's copy is freed at most once, an entry forwards at most one
//!   free, and a free accepted at submit is never rejected at dispatch.
//! * **Drain quiesce** ([`models::DrainModel`]): no allocation placed
//!   by a racing client slips past the drainer's live-set enumeration.
//! * **Device health lifecycle** ([`models::StateMachineModel`]):
//!   health only moves along `healthy→draining→retired→readmitting→
//!   healthy` edges, one winner per contended transition.
//! * **IndexQueue** ([`models::QueueModel`]): every admitted value is
//!   consumed exactly once or still sits in a slot at quiescence.
//! * **Cross-group federation** ([`models::FederationModel`]):
//!   placements spill only past latched/full groups, tag-routed frees
//!   always land on a group that still knows the name — including
//!   across a kill + rebuild-from-snapshot restart — and every spill
//!   is matched by exactly one failback.
//!
//! # How to add a model
//!
//! 1. Re-state the protocol's *shared state* as plain fields on a new
//!    struct — atomics become ordinary integers/enums; the controlled
//!    scheduler serialises all access, so the model needs no `Atomic*`.
//! 2. Split each participant into *steps* at atomic-operation
//!    granularity: one step per load/CAS/store that other threads can
//!    observe between. Keep a per-thread `pc` field; each `step(tid)`
//!    call advances one step and returns [`sched::Step::Progress`],
//!    [`sched::Step::Blocked`] (failed CAS / empty poll — the step
//!    must NOT have mutated state), or [`sched::Step::Done`].
//! 3. Express the safety property in `check()` (re-run after every
//!    step) and the liveness/accounting property in `check_final()`
//!    (run once all threads finish).
//! 4. Explore it from a test:
//!    `Explorer::default().exhaustive(&mut MyModel::new())?` — and add
//!    a seeded `random` run for state spaces the DFS budget can't
//!    cover. A failure prints a replayable schedule; feed it back via
//!    `Explorer::replay` to get the step trace while debugging.
//! 5. If the model encodes a *fixed* bug, keep the broken variant
//!    behind a `pre_fix`/`buggy` flag and add a test asserting the
//!    explorer still finds the counterexample — that is the regression
//!    proof that the checker would have caught the original bug.
//!
//! # The shadow-heap sanitizer
//!
//! [`sanitizer::ShadowHeap`] is a lifecycle tracker the service hooks
//! feed when `OURO_SAN=1` is set (see `AllocService::sanitizer`): every
//! mint, free, forwarded free and migration lands in a shadow map, and
//! double-frees, frees of migrated-away addresses, cross-device
//! ownership mismatches and shutdown leaks panic immediately with the
//! full per-address event history. Run any existing test under it —
//! `OURO_SAN=1 cargo test --test failover` — to turn silent counter
//! drift into a diagnosed report.
//!
//! # Checking the real execution
//!
//! The models above verify hand-written *abstractions*, which can
//! drift from the shipped coordinator. Two further legs close that
//! gap by checking what the real service actually did:
//!
//! * **History recording + linearizability** ([`history`],
//!   [`linearize`]): with `OURO_LIN=1` (see
//!   `AllocService::history`), every successful alloc/free/migrate/
//!   lease op records its invocation–response interval into per-thread
//!   buffers; `linearize::check` then runs a Wing & Gong search
//!   (memoized, Lowe-partitioned by device × size class) against the
//!   sequential allocator spec and reports a minimal non-linearizable
//!   window on failure. The chaos suites harvest and check their own
//!   histories when armed.
//! * **Lock-order deadlock detection** ([`lockgraph`]): every
//!   coordinator lock is an `OrderedMutex`/`OrderedRwLock` with a
//!   static rank; acquisitions must be rank-increasing, a
//!   process-global lock-order graph records first-seen edges with
//!   sample acquisition histories, and an inversion panics with both
//!   conflicting histories. Always on — every test run doubles as a
//!   deadlock-freedom proof over the orders actually exercised.

pub mod history;
pub mod linearize;
pub mod lockgraph;
pub mod models;
pub mod sanitizer;
pub mod sched;
