//! Shadow-heap sanitizer: an env-gated (`OURO_SAN=1`) lifecycle
//! tracker mirrored alongside the real allocators.
//!
//! The alloc service reports every address event here — mint at
//! dispatch, free at dispatch (including forwarded/late-forwarded
//! frees, reported against the device that actually released the
//! block), and migration re-homing. The shadow map holds one record
//! per raw address word with its full event history, and turns the
//! bug classes that used to surface as silent counter drift into
//! immediate panics carrying that history:
//!
//! * **double free** — a free over a record already `Freed`;
//! * **free-after-migrate** — a free landing on the *source* name
//!   after migration re-homed it (past grace, nothing forwards it);
//! * **cross-device ownership mismatch** — a block released by a
//!   device other than the one the record says owns it;
//! * **shutdown leaks** — records still `Live` when the service joins.
//!
//! One interleaving is legal and must not trip the tracker: dispatch
//! lanes run concurrently, so the lane minting a *recycled* address
//! can report before the lane that freed the previous tenant reports.
//! A mint over a `Live` record therefore opens a *pending* window
//! (remembering the prior tenant's device); the next free over that
//! record resolves the old generation instead of the new one. An
//! unresolved window at shutdown — a mint-over-live whose matching
//! free never arrived — is itself reported as a violation.
//!
//! # Lease lifecycle (`coordinator::lease`)
//!
//! A client-cache lease span is tracked in a dedicated span table: the
//! ring-minted span record is **consumed** at carve time (its history
//! carries over) because the span's base address aliases its block 0 —
//! from carve to return the name space belongs to the carved blocks,
//! which get ordinary records via [`ShadowHeap::on_cached_alloc`] /
//! [`ShadowHeap::on_cached_free`] (the recycle-window machinery covers
//! the owner re-serving a block before a cross-client delayed free's
//! report lands). Recall/relocation append to the span history without
//! touching block records; [`ShadowHeap::on_lease_return`] re-mints
//! the span as a plain live block just before its ring free. A span
//! still leased at shutdown panics as a **leaked lease** with its full
//! history; spans on a hard-retired member are stranded, not leaked.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::ouroboros::GlobalAddr;

/// Lifecycle state of one shadow record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Live,
    Freed,
    Migrated,
    /// Live on a member that was hard-retired: dead by decision (frees
    /// of it fail `DeviceRetired`, readmission refuses while it
    /// exists), so the shutdown leak check reports real leaks only.
    Stranded,
}

/// One recorded event; `u64` is the global event sequence number.
#[derive(Debug, Clone, Copy)]
enum Event {
    Minted { device: u32 },
    Freed { device: u32 },
    /// Mint observed while the record was still `Live` — a recycled
    /// address whose previous tenant's free is still in flight on
    /// another lane.
    MintedWhileLive { device: u32 },
    /// Free that resolved a [`Event::MintedWhileLive`] window: it
    /// belongs to the *previous* generation of this address.
    FreedPrevGen { device: u32 },
    MigratedTo { to: GlobalAddr },
    /// The owning member was hard-retired while this block was live.
    StrandedOnRetire { device: u32 },
    /// The span was carved into a client-cache lease (its ring-minted
    /// record is consumed into the span table at this point).
    LeaseCarved,
    /// Drain/retire recalled the lease from its owner.
    LeaseRecalled,
    /// A recalled span migrated to a new home.
    LeaseRelocated { to: GlobalAddr },
    /// Every block came home and the lease was returned (the span
    /// becomes a plain live block again, about to be ring-freed).
    LeaseReturned,
    /// The span's current home was hard-retired while still leased.
    LeaseStranded { device: u32 },
    /// Block served from an owner's local lease cache (no ring op).
    CachedAlloc { device: u32 },
    /// Block freed into a lease bitmap (no ring op); `delayed` marks a
    /// cross-client free parked for the owner's renewal drain.
    CachedFree { device: u32, delayed: bool },
}

/// Lifecycle state of one lease-span record in the span table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SpanState {
    Leased,
    /// Current home hard-retired while leased: dead by decision, like
    /// [`State::Stranded`] — excluded from the shutdown leak check.
    Stranded,
}

struct SpanRec {
    state: SpanState,
    /// Every home the span has had; `homes[0]` is the origin (the key).
    homes: Vec<GlobalAddr>,
    events: Vec<(u64, Event)>,
}

struct Record {
    state: State,
    /// Device currently owning the live generation.
    device: u32,
    migrated_to: Option<GlobalAddr>,
    /// Open mint-over-live window: device that owned the previous
    /// generation, whose free has not been reported yet.
    pending_prev_device: Option<u32>,
    events: Vec<(u64, Event)>,
}

#[derive(Default)]
struct ShadowMap {
    seq: u64,
    records: HashMap<u32, Record>,
    /// Lease spans, keyed by the *origin* span address.
    spans: HashMap<u32, SpanRec>,
    /// Any home a span has had → its origin key.
    span_alias: HashMap<u32, u32>,
}

/// The shadow heap. Cheap when absent: service paths hold an
/// `Option<Arc<ShadowHeap>>` that is `None` unless `OURO_SAN` is set,
/// so the disabled cost is one branch per dispatch batch.
pub struct ShadowHeap {
    map: Mutex<ShadowMap>,
    shutdown_checked: AtomicBool,
}

impl Default for ShadowHeap {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowHeap {
    pub fn new() -> Self {
        ShadowHeap {
            map: Mutex::new(ShadowMap::default()),
            shutdown_checked: AtomicBool::new(false),
        }
    }

    /// Gate: `Some` iff `OURO_SAN` is set to anything but `""`/`"0"`.
    pub fn from_env() -> Option<Arc<ShadowHeap>> {
        match std::env::var("OURO_SAN") {
            Ok(v) if !v.is_empty() && v != "0" => Some(Arc::new(ShadowHeap::new())),
            _ => None,
        }
    }

    fn render(events: &[(u64, Event)]) -> String {
        let mut out = String::new();
        for (seq, ev) in events {
            let line = match ev {
                Event::Minted { device } => format!("minted on d{device}"),
                Event::Freed { device } => format!("freed by d{device}"),
                Event::MintedWhileLive { device } => format!(
                    "minted on d{device} while previous tenant still live \
                     (recycle window opened)"
                ),
                Event::FreedPrevGen { device } => {
                    format!("freed by d{device} (resolved previous generation)")
                }
                Event::MigratedTo { to } => format!("migrated to {to}"),
                Event::StrandedOnRetire { device } => {
                    format!("stranded: d{device} hard-retired while block live")
                }
                Event::LeaseCarved => "carved into a lease span".to_string(),
                Event::LeaseRecalled => {
                    "lease recalled by drain/retire".to_string()
                }
                Event::LeaseRelocated { to } => {
                    format!("leased span relocated to {to}")
                }
                Event::LeaseReturned => {
                    "lease returned (span live again)".to_string()
                }
                Event::LeaseStranded { device } => format!(
                    "lease stranded: d{device} hard-retired while span leased"
                ),
                Event::CachedAlloc { device } => {
                    format!("served from d{device}'s lease cache")
                }
                Event::CachedFree { device, delayed } => {
                    if *delayed {
                        format!("delayed-freed into d{device}'s lease")
                    } else {
                        format!("cached-freed into d{device}'s lease")
                    }
                }
            };
            out.push_str(&format!("    #{seq:04} {line}\n"));
        }
        out
    }

    fn violation(addr: GlobalAddr, what: &str, events: &[(u64, Event)]) -> ! {
        panic!(
            "OURO_SAN: {what} at {addr}\n  address history:\n{}",
            Self::render(events)
        );
    }

    /// A block came back from a device alloc: `addr` is the encoded
    /// global address the client will see.
    pub fn on_mint(&self, addr: GlobalAddr) {
        self.mint_impl(addr, false);
    }

    /// A block was served from a client's lease cache — a mint with no
    /// ring op behind it. Same recycle-window tolerance as
    /// [`ShadowHeap::on_mint`]: the owner may re-serve a block before a
    /// cross-client delayed free's report lands here.
    pub fn on_cached_alloc(&self, addr: GlobalAddr) {
        self.mint_impl(addr, true);
    }

    fn mint_impl(&self, addr: GlobalAddr, cached: bool) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let minted = if cached {
            Event::CachedAlloc { device: addr.device() }
        } else {
            Event::Minted { device: addr.device() }
        };
        let rec = m.records.entry(addr.raw()).or_insert_with(|| Record {
            state: State::Freed,
            device: addr.device(),
            migrated_to: None,
            pending_prev_device: None,
            events: Vec::new(),
        });
        match rec.state {
            State::Live => {
                // Recycled address, previous tenant's free still in
                // flight on another lane: open the pending window.
                rec.events
                    .push((seq, Event::MintedWhileLive { device: addr.device() }));
                if rec.pending_prev_device.is_some() {
                    Self::violation(
                        addr,
                        "address re-minted twice with no intervening free",
                        &rec.events,
                    );
                }
                rec.pending_prev_device = Some(rec.device);
                rec.device = addr.device();
                rec.migrated_to = None;
            }
            State::Freed | State::Migrated => {
                rec.events.push((seq, minted));
                rec.state = State::Live;
                rec.device = addr.device();
                rec.migrated_to = None;
            }
            State::Stranded => {
                // Readmission is refused while strands exist, so a
                // re-mint of a stranded name means the two aliased.
                rec.events.push((seq, minted));
                Self::violation(
                    addr,
                    "address re-minted while stranded on a retired member",
                    &rec.events,
                );
            }
        }
    }

    /// A block was released on `device` under the name `addr` (for
    /// forwarded frees, `addr` is the *forwarded* name — the copy —
    /// and `device` the member that actually freed it).
    pub fn on_free(&self, addr: GlobalAddr, device: u32) {
        self.free_impl(addr, Event::Freed { device }, device);
    }

    /// A block was freed into a lease bitmap (owner-local or delayed)
    /// — a free with no ring op behind it, always against the block's
    /// own (origin) device.
    pub fn on_cached_free(&self, addr: GlobalAddr, delayed: bool) {
        let device = addr.device();
        self.free_impl(addr, Event::CachedFree { device, delayed }, device);
    }

    fn free_impl(&self, addr: GlobalAddr, freed: Event, device: u32) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(rec) = m.records.get_mut(&addr.raw()) else {
            panic!(
                "OURO_SAN: free of never-minted address {addr} by d{device}\n  \
                 address history:\n    (none)"
            );
        };
        if let Some(prev) = rec.pending_prev_device {
            // This free belongs to the previous generation of a
            // recycled address; resolve the window.
            rec.events.push((seq, Event::FreedPrevGen { device }));
            if prev != device {
                Self::violation(
                    addr,
                    "cross-device free of the previous generation",
                    &rec.events,
                );
            }
            rec.pending_prev_device = None;
            return;
        }
        match rec.state {
            State::Live => {
                rec.events.push((seq, freed));
                if rec.device != device {
                    Self::violation(
                        addr,
                        "cross-device ownership mismatch on free",
                        &rec.events,
                    );
                }
                rec.state = State::Freed;
            }
            State::Freed => {
                rec.events.push((seq, freed));
                Self::violation(addr, "double free", &rec.events);
            }
            State::Migrated => {
                rec.events.push((seq, freed));
                Self::violation(
                    addr,
                    "free of a migrated-away address (past grace, nothing \
                     forwards it)",
                    &rec.events,
                );
            }
            State::Stranded => {
                rec.events.push((seq, freed));
                Self::violation(
                    addr,
                    "free succeeded against a stranded address on a \
                     retired member",
                    &rec.events,
                );
            }
        }
    }

    /// `device` was hard-retired with its lanes joined: every record
    /// still live there is stranded by decision, not leaked — and so is
    /// every lease span whose *current* home was that member. Called
    /// from `retire_device` after the member's workers are gone.
    pub fn on_retire(&self, device: u32) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        // Cached blocks are named after their lease's *origin* chunk,
        // so a lease that relocated AWAY from this member leaves live
        // block records tagged with the retiring device — those blocks
        // survive (their payload lives at the lease's current home)
        // and must not be stranded with it.
        let surviving: std::collections::HashSet<(u32, u32)> = m
            .spans
            .values()
            .filter(|s| {
                s.state == SpanState::Leased
                    && s.homes.last().map(|h| h.device()) != Some(device)
            })
            .map(|s| (s.homes[0].device(), s.homes[0].chunk()))
            .collect();
        for (&raw, rec) in m.records.iter_mut() {
            if rec.state == State::Live && rec.device == device {
                let a = GlobalAddr::from_raw(raw);
                if surviving.contains(&(a.device(), a.chunk())) {
                    continue;
                }
                rec.state = State::Stranded;
                rec.events.push((seq, Event::StrandedOnRetire { device }));
            }
        }
        for span in m.spans.values_mut() {
            if span.state == SpanState::Leased
                && span.homes.last().map(|h| h.device()) == Some(device)
            {
                span.state = SpanState::Stranded;
                span.events.push((seq, Event::LeaseStranded { device }));
            }
        }
    }

    /// A block record left live on a *relocated* lease span when the
    /// span's current home was hard-retired: its origin-device record
    /// is not caught by [`ShadowHeap::on_retire`]'s device sweep, so
    /// the retire path strands it by name.
    pub fn strand_cached_block(&self, addr: GlobalAddr, device: u32) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        if let Some(rec) = m.records.get_mut(&addr.raw()) {
            if rec.state == State::Live {
                rec.state = State::Stranded;
                rec.events.push((seq, Event::StrandedOnRetire { device }));
            }
        }
    }

    // ---- lease span lifecycle -------------------------------------------

    /// `span` (ring-minted a moment ago) was carved into a client-cache
    /// lease: its block record is consumed into the span table — from
    /// here to [`ShadowHeap::on_lease_return`] the span's base address
    /// names carved block 0, not the span allocation.
    pub fn on_lease_carve(&self, span: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let mut events = match m.records.remove(&span.raw()) {
            Some(rec) => {
                if rec.state != State::Live || rec.pending_prev_device.is_some()
                {
                    Self::violation(
                        span,
                        "lease carved from a non-live span",
                        &rec.events,
                    );
                }
                rec.events
            }
            None => Vec::new(),
        };
        events.push((seq, Event::LeaseCarved));
        if m.spans
            .insert(
                span.raw(),
                SpanRec { state: SpanState::Leased, homes: vec![span], events },
            )
            .is_some()
        {
            panic!("OURO_SAN: span {span} carved into two live leases");
        }
        m.span_alias.insert(span.raw(), span.raw());
    }

    /// Drain/retire recalled the lease holding `home` (any home the
    /// span has had resolves).
    pub fn on_lease_recall(&self, home: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(&origin) = m.span_alias.get(&home.raw()) else {
            panic!("OURO_SAN: recall of unleased span {home}");
        };
        let span = m.spans.get_mut(&origin).expect("aliased span record");
        span.events.push((seq, Event::LeaseRecalled));
    }

    /// A recalled span migrated `from → to`; the lease keeps serving
    /// its origin-based block names, so only the span record moves.
    pub fn on_lease_relocate(&self, from: GlobalAddr, to: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(&origin) = m.span_alias.get(&from.raw()) else {
            panic!("OURO_SAN: relocation of unleased span {from}");
        };
        let span = m.spans.get_mut(&origin).expect("aliased span record");
        span.events.push((seq, Event::LeaseRelocated { to }));
        span.homes.push(to);
        m.span_alias.insert(to.raw(), origin);
    }

    /// Every block came home and the lease was returned: the span
    /// record retires and `home` (the *current* home) becomes a plain
    /// live block again — the ring free that follows reports through
    /// the ordinary [`ShadowHeap::on_free`].
    pub fn on_lease_return(&self, home: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(&origin) = m.span_alias.get(&home.raw()) else {
            panic!("OURO_SAN: return of unleased span {home}");
        };
        let mut span = m.spans.remove(&origin).expect("aliased span record");
        for h in &span.homes {
            m.span_alias.remove(&h.raw());
        }
        span.events.push((seq, Event::LeaseReturned));
        let rec = m.records.entry(home.raw()).or_insert_with(|| Record {
            state: State::Freed,
            device: home.device(),
            migrated_to: None,
            pending_prev_device: None,
            events: Vec::new(),
        });
        if rec.state == State::Live || rec.pending_prev_device.is_some() {
            Self::violation(
                home,
                "lease returned over a live block record",
                &rec.events,
            );
        }
        rec.events.extend(span.events);
        rec.state = State::Live;
        rec.device = home.device();
        rec.migrated_to = None;
    }

    /// Lease spans currently leased (not yet returned or stranded).
    pub fn leased_count(&self) -> usize {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.spans.values().filter(|s| s.state == SpanState::Leased).count()
    }

    /// Migration re-homed `from` into the freshly minted `to`: the old
    /// name stops being freeable (forwarded frees are reported against
    /// `to` by the dispatcher).
    pub fn on_migrate(&self, from: GlobalAddr, to: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(rec) = m.records.get_mut(&from.raw()) else {
            panic!(
                "OURO_SAN: migration of never-minted address {from}\n  \
                 address history:\n    (none)"
            );
        };
        rec.events.push((seq, Event::MigratedTo { to }));
        if rec.state != State::Live {
            Self::violation(from, "migration of a non-live address", &rec.events);
        }
        rec.state = State::Migrated;
        rec.migrated_to = Some(to);
    }

    /// Where `addr` was re-homed, if its live generation was migrated.
    pub fn migrated_to(&self, addr: GlobalAddr) -> Option<GlobalAddr> {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records.get(&addr.raw()).and_then(|r| r.migrated_to)
    }

    /// Records currently `Live` (plus open recycle windows) plus spans
    /// still leased — a lease is a live block on its device.
    pub fn live_count(&self) -> usize {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records
            .values()
            .filter(|r| r.state == State::Live || r.pending_prev_device.is_some())
            .count()
            + m.spans.values().filter(|s| s.state == SpanState::Leased).count()
    }

    /// Human-readable event history for one address (empty if never
    /// seen).
    pub fn history(&self, addr: GlobalAddr) -> Vec<String> {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records
            .get(&addr.raw())
            .map(|r| {
                Self::render(&r.events)
                    .lines()
                    .map(|l| l.trim_start().to_string())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Formatted leak report: every record still live or unresolved.
    pub fn leak_report(&self) -> String {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        let mut addrs: Vec<u32> = m
            .records
            .iter()
            .filter(|(_, r)| r.state == State::Live || r.pending_prev_device.is_some())
            .map(|(&a, _)| a)
            .collect();
        addrs.sort_unstable();
        let mut out = String::new();
        for a in addrs {
            let rec = &m.records[&a];
            let what = if rec.state == State::Live {
                "leaked (still live)"
            } else {
                "unresolved recycle window (previous tenant never freed)"
            };
            out.push_str(&format!("  {}: {what}\n", GlobalAddr::from_raw(a)));
            out.push_str(&Self::render(&rec.events));
        }
        out
    }

    /// Shutdown leak check. Idempotent (the service's `shutdown()` and
    /// `Drop` both funnel here) and inert while already panicking so a
    /// poisoned test can't double-panic into an abort.
    pub fn check_shutdown(&self) {
        // ordering: SeqCst once-latch; cold path, strongest order is free.
        if self.shutdown_checked.swap(true, Ordering::SeqCst) {
            return;
        }
        if std::thread::panicking() {
            return;
        }
        // Leaked leases first: a span still leased at shutdown means a
        // cached client handle was never dropped or flushed — report it
        // by name with its full history.
        {
            let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
            let mut leaked: Vec<&SpanRec> = m
                .spans
                .values()
                .filter(|s| s.state == SpanState::Leased)
                .collect();
            leaked.sort_by_key(|s| s.homes[0].raw());
            if let Some(span) = leaked.first() {
                panic!(
                    "OURO_SAN: {} lease(s) leaked at service shutdown (cached \
                     client handle not dropped/flushed before the service); \
                     first leaked span {}\n  span history:\n{}",
                    leaked.len(),
                    span.homes[0],
                    Self::render(&span.events)
                );
            }
        }
        let leaks = self.live_count();
        if leaks > 0 {
            panic!(
                "OURO_SAN: {leaks} address(es) leaked at service shutdown\n{}",
                self.leak_report()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a(dev: u32, local: u32) -> GlobalAddr {
        GlobalAddr::new(dev, local)
    }

    #[test]
    fn clean_lifecycle_is_silent() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 16));
        san.on_free(a(0, 16), 0);
        san.on_mint(a(0, 16));
        san.on_free(a(0, 16), 0);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn double_free_panics_with_history() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 32));
        san.on_free(a(1, 32), 1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(1, 32), 1);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("double free"), "{msg}");
        assert!(msg.contains("minted on d1"), "{msg}");
    }

    #[test]
    fn cross_device_free_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 64));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(0, 64), 2);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("cross-device"), "{msg}");
    }

    #[test]
    fn free_after_migrate_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 128));
        san.on_mint(a(1, 128));
        san.on_migrate(a(0, 128), a(1, 128));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(0, 128), 0);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("migrated-away"), "{msg}");
        assert!(msg.contains("migrated to d1"), "{msg}");
    }

    #[test]
    fn recycle_window_tolerates_out_of_order_lanes() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 256));
        // Lane B re-mints the recycled address before lane A reports
        // the free of the previous tenant.
        san.on_mint(a(0, 256));
        assert_eq!(san.live_count(), 1);
        san.on_free(a(0, 256), 0); // resolves the PREVIOUS generation
        assert_eq!(san.live_count(), 1);
        san.on_free(a(0, 256), 0); // frees the current generation
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn unresolved_recycle_window_is_a_leak() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 512));
        san.on_mint(a(0, 512));
        san.on_free(a(0, 512), 0); // resolves previous generation only
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.check_shutdown();
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("leaked at service shutdown"), "{msg}");
    }

    #[test]
    fn stranded_on_retire_is_not_a_leak() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 2048));
        san.on_retire(1);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown(); // no panic: stranded != leaked
    }

    #[test]
    fn free_of_stranded_address_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 4096));
        san.on_retire(1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(1, 4096), 1);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("stranded"), "{msg}");
    }

    #[test]
    fn lease_lifecycle_is_silent() {
        let san = ShadowHeap::new();
        let span = a(0, 8192);
        san.on_mint(span); // the ring alloc behind the mint
        san.on_lease_carve(span);
        assert_eq!(san.leased_count(), 1);
        assert_eq!(san.live_count(), 1, "a leased span is a live block");
        // Serve two blocks from the cache — block 0 aliases the span
        // base and must be trackable as its own record while leased.
        san.on_cached_alloc(a(0, 8192));
        san.on_cached_alloc(a(0, 8192 + 1024));
        san.on_cached_free(a(0, 8192), false);
        san.on_cached_free(a(0, 8192 + 1024), true);
        san.on_lease_return(span);
        assert_eq!(san.leased_count(), 0);
        san.on_free(span, 0); // the ring free returning the span
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn cached_double_free_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 8192));
        san.on_lease_carve(a(0, 8192));
        san.on_cached_alloc(a(0, 9216));
        san.on_cached_free(a(0, 9216), false);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_cached_free(a(0, 9216), true);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("double free"), "{msg}");
        assert!(msg.contains("lease"), "{msg}");
    }

    #[test]
    fn delayed_free_report_may_trail_the_reserve() {
        // Cross-client delayed free: the owner can drain the delayed
        // bit and re-serve the block before the freeing thread's
        // sanitizer report lands — the recycle window covers it.
        let san = ShadowHeap::new();
        san.on_mint(a(0, 8192));
        san.on_lease_carve(a(0, 8192));
        san.on_cached_alloc(a(0, 9216));
        san.on_cached_alloc(a(0, 9216)); // re-serve, free report in flight
        san.on_cached_free(a(0, 9216), true); // resolves the previous gen
        san.on_cached_free(a(0, 9216), false); // frees the current gen
        assert_eq!(san.live_count(), 1, "just the leased span");
    }

    #[test]
    fn leaked_lease_panics_with_history() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 16384));
        san.on_lease_carve(a(1, 16384));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.check_shutdown();
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("lease(s) leaked"), "{msg}");
        assert!(msg.contains("carved into a lease span"), "{msg}");
        assert!(msg.contains("minted on d1"), "{msg}");
    }

    #[test]
    fn lease_relocation_and_retire_strand() {
        let san = ShadowHeap::new();
        let (old, new) = (a(0, 8192), a(2, 24576));
        san.on_mint(old);
        san.on_lease_carve(old);
        san.on_cached_alloc(a(0, 9216));
        san.on_lease_recall(old);
        san.on_lease_relocate(old, new);
        // Return resolves through the *new* home's alias.
        assert_eq!(san.leased_count(), 1);
        // Hard-retire the new home instead: the span strands (not a
        // leak), and the origin-named block is stranded by name.
        san.on_retire(2);
        assert_eq!(san.leased_count(), 0);
        san.strand_cached_block(a(0, 9216), 2);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn relocated_lease_returns_at_its_new_home() {
        let san = ShadowHeap::new();
        let (old, new) = (a(0, 8192), a(1, 8192));
        san.on_mint(old);
        san.on_lease_carve(old);
        san.on_lease_recall(old);
        san.on_lease_relocate(old, new);
        san.on_lease_return(new);
        assert_eq!(san.leased_count(), 0);
        san.on_free(new, 1);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn shutdown_check_is_idempotent() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 1024)); // leak it
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.check_shutdown();
        }))
        .is_err());
        // Second call (Drop after shutdown()) must be a no-op.
        san.check_shutdown();
    }
}
