//! Shadow-heap sanitizer: an env-gated (`OURO_SAN=1`) lifecycle
//! tracker mirrored alongside the real allocators.
//!
//! The alloc service reports every address event here — mint at
//! dispatch, free at dispatch (including forwarded/late-forwarded
//! frees, reported against the device that actually released the
//! block), and migration re-homing. The shadow map holds one record
//! per raw address word with its full event history, and turns the
//! bug classes that used to surface as silent counter drift into
//! immediate panics carrying that history:
//!
//! * **double free** — a free over a record already `Freed`;
//! * **free-after-migrate** — a free landing on the *source* name
//!   after migration re-homed it (past grace, nothing forwards it);
//! * **cross-device ownership mismatch** — a block released by a
//!   device other than the one the record says owns it;
//! * **shutdown leaks** — records still `Live` when the service joins.
//!
//! One interleaving is legal and must not trip the tracker: dispatch
//! lanes run concurrently, so the lane minting a *recycled* address
//! can report before the lane that freed the previous tenant reports.
//! A mint over a `Live` record therefore opens a *pending* window
//! (remembering the prior tenant's device); the next free over that
//! record resolves the old generation instead of the new one. An
//! unresolved window at shutdown — a mint-over-live whose matching
//! free never arrived — is itself reported as a violation.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::ouroboros::GlobalAddr;

/// Lifecycle state of one shadow record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Live,
    Freed,
    Migrated,
    /// Live on a member that was hard-retired: dead by decision (frees
    /// of it fail `DeviceRetired`, readmission refuses while it
    /// exists), so the shutdown leak check reports real leaks only.
    Stranded,
}

/// One recorded event; `u64` is the global event sequence number.
#[derive(Debug, Clone, Copy)]
enum Event {
    Minted { device: u32 },
    Freed { device: u32 },
    /// Mint observed while the record was still `Live` — a recycled
    /// address whose previous tenant's free is still in flight on
    /// another lane.
    MintedWhileLive { device: u32 },
    /// Free that resolved a [`Event::MintedWhileLive`] window: it
    /// belongs to the *previous* generation of this address.
    FreedPrevGen { device: u32 },
    MigratedTo { to: GlobalAddr },
    /// The owning member was hard-retired while this block was live.
    StrandedOnRetire { device: u32 },
}

struct Record {
    state: State,
    /// Device currently owning the live generation.
    device: u32,
    migrated_to: Option<GlobalAddr>,
    /// Open mint-over-live window: device that owned the previous
    /// generation, whose free has not been reported yet.
    pending_prev_device: Option<u32>,
    events: Vec<(u64, Event)>,
}

#[derive(Default)]
struct ShadowMap {
    seq: u64,
    records: HashMap<u32, Record>,
}

/// The shadow heap. Cheap when absent: service paths hold an
/// `Option<Arc<ShadowHeap>>` that is `None` unless `OURO_SAN` is set,
/// so the disabled cost is one branch per dispatch batch.
pub struct ShadowHeap {
    map: Mutex<ShadowMap>,
    shutdown_checked: AtomicBool,
}

impl Default for ShadowHeap {
    fn default() -> Self {
        Self::new()
    }
}

impl ShadowHeap {
    pub fn new() -> Self {
        ShadowHeap {
            map: Mutex::new(ShadowMap::default()),
            shutdown_checked: AtomicBool::new(false),
        }
    }

    /// Gate: `Some` iff `OURO_SAN` is set to anything but `""`/`"0"`.
    pub fn from_env() -> Option<Arc<ShadowHeap>> {
        match std::env::var("OURO_SAN") {
            Ok(v) if !v.is_empty() && v != "0" => Some(Arc::new(ShadowHeap::new())),
            _ => None,
        }
    }

    fn render(events: &[(u64, Event)]) -> String {
        let mut out = String::new();
        for (seq, ev) in events {
            let line = match ev {
                Event::Minted { device } => format!("minted on d{device}"),
                Event::Freed { device } => format!("freed by d{device}"),
                Event::MintedWhileLive { device } => format!(
                    "minted on d{device} while previous tenant still live \
                     (recycle window opened)"
                ),
                Event::FreedPrevGen { device } => {
                    format!("freed by d{device} (resolved previous generation)")
                }
                Event::MigratedTo { to } => format!("migrated to {to}"),
                Event::StrandedOnRetire { device } => {
                    format!("stranded: d{device} hard-retired while block live")
                }
            };
            out.push_str(&format!("    #{seq:04} {line}\n"));
        }
        out
    }

    fn violation(addr: GlobalAddr, what: &str, events: &[(u64, Event)]) -> ! {
        panic!(
            "OURO_SAN: {what} at {addr}\n  address history:\n{}",
            Self::render(events)
        );
    }

    /// A block came back from a device alloc: `addr` is the encoded
    /// global address the client will see.
    pub fn on_mint(&self, addr: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let rec = m.records.entry(addr.raw()).or_insert_with(|| Record {
            state: State::Freed,
            device: addr.device(),
            migrated_to: None,
            pending_prev_device: None,
            events: Vec::new(),
        });
        match rec.state {
            State::Live => {
                // Recycled address, previous tenant's free still in
                // flight on another lane: open the pending window.
                rec.events
                    .push((seq, Event::MintedWhileLive { device: addr.device() }));
                if rec.pending_prev_device.is_some() {
                    Self::violation(
                        addr,
                        "address re-minted twice with no intervening free",
                        &rec.events,
                    );
                }
                rec.pending_prev_device = Some(rec.device);
                rec.device = addr.device();
                rec.migrated_to = None;
            }
            State::Freed | State::Migrated => {
                rec.events.push((seq, Event::Minted { device: addr.device() }));
                rec.state = State::Live;
                rec.device = addr.device();
                rec.migrated_to = None;
            }
            State::Stranded => {
                // Readmission is refused while strands exist, so a
                // re-mint of a stranded name means the two aliased.
                rec.events.push((seq, Event::Minted { device: addr.device() }));
                Self::violation(
                    addr,
                    "address re-minted while stranded on a retired member",
                    &rec.events,
                );
            }
        }
    }

    /// A block was released on `device` under the name `addr` (for
    /// forwarded frees, `addr` is the *forwarded* name — the copy —
    /// and `device` the member that actually freed it).
    pub fn on_free(&self, addr: GlobalAddr, device: u32) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(rec) = m.records.get_mut(&addr.raw()) else {
            panic!(
                "OURO_SAN: free of never-minted address {addr} by d{device}\n  \
                 address history:\n    (none)"
            );
        };
        if let Some(prev) = rec.pending_prev_device {
            // This free belongs to the previous generation of a
            // recycled address; resolve the window.
            rec.events.push((seq, Event::FreedPrevGen { device }));
            if prev != device {
                Self::violation(
                    addr,
                    "cross-device free of the previous generation",
                    &rec.events,
                );
            }
            rec.pending_prev_device = None;
            return;
        }
        match rec.state {
            State::Live => {
                rec.events.push((seq, Event::Freed { device }));
                if rec.device != device {
                    Self::violation(
                        addr,
                        "cross-device ownership mismatch on free",
                        &rec.events,
                    );
                }
                rec.state = State::Freed;
            }
            State::Freed => {
                rec.events.push((seq, Event::Freed { device }));
                Self::violation(addr, "double free", &rec.events);
            }
            State::Migrated => {
                rec.events.push((seq, Event::Freed { device }));
                Self::violation(
                    addr,
                    "free of a migrated-away address (past grace, nothing \
                     forwards it)",
                    &rec.events,
                );
            }
            State::Stranded => {
                rec.events.push((seq, Event::Freed { device }));
                Self::violation(
                    addr,
                    "free succeeded against a stranded address on a \
                     retired member",
                    &rec.events,
                );
            }
        }
    }

    /// `device` was hard-retired with its lanes joined: every record
    /// still live there is stranded by decision, not leaked. Called
    /// from `retire_device` after the member's workers are gone.
    pub fn on_retire(&self, device: u32) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        for rec in m.records.values_mut() {
            if rec.state == State::Live && rec.device == device {
                rec.state = State::Stranded;
                rec.events.push((seq, Event::StrandedOnRetire { device }));
            }
        }
    }

    /// Migration re-homed `from` into the freshly minted `to`: the old
    /// name stops being freeable (forwarded frees are reported against
    /// `to` by the dispatcher).
    pub fn on_migrate(&self, from: GlobalAddr, to: GlobalAddr) {
        let mut m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.seq += 1;
        let seq = m.seq;
        let Some(rec) = m.records.get_mut(&from.raw()) else {
            panic!(
                "OURO_SAN: migration of never-minted address {from}\n  \
                 address history:\n    (none)"
            );
        };
        rec.events.push((seq, Event::MigratedTo { to }));
        if rec.state != State::Live {
            Self::violation(from, "migration of a non-live address", &rec.events);
        }
        rec.state = State::Migrated;
        rec.migrated_to = Some(to);
    }

    /// Where `addr` was re-homed, if its live generation was migrated.
    pub fn migrated_to(&self, addr: GlobalAddr) -> Option<GlobalAddr> {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records.get(&addr.raw()).and_then(|r| r.migrated_to)
    }

    /// Records currently `Live` (plus open recycle windows).
    pub fn live_count(&self) -> usize {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records
            .values()
            .filter(|r| r.state == State::Live || r.pending_prev_device.is_some())
            .count()
    }

    /// Human-readable event history for one address (empty if never
    /// seen).
    pub fn history(&self, addr: GlobalAddr) -> Vec<String> {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        m.records
            .get(&addr.raw())
            .map(|r| {
                Self::render(&r.events)
                    .lines()
                    .map(|l| l.trim_start().to_string())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Formatted leak report: every record still live or unresolved.
    pub fn leak_report(&self) -> String {
        let m = self.map.lock().unwrap_or_else(|e| e.into_inner());
        let mut addrs: Vec<u32> = m
            .records
            .iter()
            .filter(|(_, r)| r.state == State::Live || r.pending_prev_device.is_some())
            .map(|(&a, _)| a)
            .collect();
        addrs.sort_unstable();
        let mut out = String::new();
        for a in addrs {
            let rec = &m.records[&a];
            let what = if rec.state == State::Live {
                "leaked (still live)"
            } else {
                "unresolved recycle window (previous tenant never freed)"
            };
            out.push_str(&format!("  {}: {what}\n", GlobalAddr::from_raw(a)));
            out.push_str(&Self::render(&rec.events));
        }
        out
    }

    /// Shutdown leak check. Idempotent (the service's `shutdown()` and
    /// `Drop` both funnel here) and inert while already panicking so a
    /// poisoned test can't double-panic into an abort.
    pub fn check_shutdown(&self) {
        // ordering: SeqCst once-latch; cold path, strongest order is free.
        if self.shutdown_checked.swap(true, Ordering::SeqCst) {
            return;
        }
        if std::thread::panicking() {
            return;
        }
        let leaks = self.live_count();
        if leaks > 0 {
            panic!(
                "OURO_SAN: {leaks} address(es) leaked at service shutdown\n{}",
                self.leak_report()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a(dev: u32, local: u32) -> GlobalAddr {
        GlobalAddr::new(dev, local)
    }

    #[test]
    fn clean_lifecycle_is_silent() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 16));
        san.on_free(a(0, 16), 0);
        san.on_mint(a(0, 16));
        san.on_free(a(0, 16), 0);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn double_free_panics_with_history() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 32));
        san.on_free(a(1, 32), 1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(1, 32), 1);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("double free"), "{msg}");
        assert!(msg.contains("minted on d1"), "{msg}");
    }

    #[test]
    fn cross_device_free_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 64));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(0, 64), 2);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("cross-device"), "{msg}");
    }

    #[test]
    fn free_after_migrate_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 128));
        san.on_mint(a(1, 128));
        san.on_migrate(a(0, 128), a(1, 128));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(0, 128), 0);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("migrated-away"), "{msg}");
        assert!(msg.contains("migrated to d1"), "{msg}");
    }

    #[test]
    fn recycle_window_tolerates_out_of_order_lanes() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 256));
        // Lane B re-mints the recycled address before lane A reports
        // the free of the previous tenant.
        san.on_mint(a(0, 256));
        assert_eq!(san.live_count(), 1);
        san.on_free(a(0, 256), 0); // resolves the PREVIOUS generation
        assert_eq!(san.live_count(), 1);
        san.on_free(a(0, 256), 0); // frees the current generation
        assert_eq!(san.live_count(), 0);
        san.check_shutdown();
    }

    #[test]
    fn unresolved_recycle_window_is_a_leak() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 512));
        san.on_mint(a(0, 512));
        san.on_free(a(0, 512), 0); // resolves previous generation only
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.check_shutdown();
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("leaked at service shutdown"), "{msg}");
    }

    #[test]
    fn stranded_on_retire_is_not_a_leak() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 2048));
        san.on_retire(1);
        assert_eq!(san.live_count(), 0);
        san.check_shutdown(); // no panic: stranded != leaked
    }

    #[test]
    fn free_of_stranded_address_panics() {
        let san = ShadowHeap::new();
        san.on_mint(a(1, 4096));
        san.on_retire(1);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.on_free(a(1, 4096), 1);
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("stranded"), "{msg}");
    }

    #[test]
    fn shutdown_check_is_idempotent() {
        let san = ShadowHeap::new();
        san.on_mint(a(0, 1024)); // leak it
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            san.check_shutdown();
        }))
        .is_err());
        // Second call (Drop after shutdown()) must be a no-op.
        san.check_shutdown();
    }
}
