//! Deterministic scheduler for protocol model checking — a hand-rolled
//! loom-lite (the offline image has no `loom`).
//!
//! A [`Model`] expresses a concurrency protocol as a set of *virtual
//! threads*, each advanced one atomic-granularity step at a time over a
//! shared shadow state. The [`Explorer`] owns the interleaving: bounded
//! exhaustive DFS over every schedule (up to a budget), plus a
//! seeded-random mode for spaces the exhaustive budget cannot cover.
//! Every step the model's invariant is re-checked; a violation (or a
//! deadlock — every live thread blocked) yields a [`CounterExample`]
//! carrying the exact thread-id schedule, which [`Explorer::replay`]
//! reproduces deterministically and prints as a step trace.
//!
//! The exploration is *stateless*: the DFS replays the schedule prefix
//! from `reset()` for every branch instead of snapshotting model state,
//! so models stay plain structs with no undo machinery. That makes two
//! contracts load-bearing:
//!
//! * `step()` must be deterministic — same prefix, same state;
//! * a step returning [`Step::Blocked`] must **not** have mutated the
//!   shared state (it models a failed CAS / an empty poll; the thread
//!   is re-eligible once any other thread makes progress).

use std::fmt;

use crate::util::rng::Rng;

/// Outcome of advancing one virtual thread by one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The thread mutated shared state (or its own) and has more to do.
    Progress,
    /// The thread cannot advance until another thread makes progress
    /// (failed CAS, empty queue, spin-wait). State must be unchanged.
    Blocked,
    /// The thread finished its program (its final step may mutate).
    Done,
}

/// A concurrency protocol extracted into an explorable shadow model.
pub trait Model {
    /// Restore the pristine initial state. Called before every replay.
    fn reset(&mut self);
    /// Number of virtual threads (fixed across resets).
    fn threads(&self) -> usize;
    /// What thread `tid` would do next (for the step trace).
    fn describe(&self, tid: usize) -> String;
    /// Advance thread `tid` by one step. See the module contract on
    /// [`Step::Blocked`].
    fn step(&mut self, tid: usize) -> Step;
    /// Safety invariant, re-checked after every step.
    fn check(&self) -> Result<(), String>;
    /// Invariant over the terminal state (all threads done).
    fn check_final(&self) -> Result<(), String> {
        Ok(())
    }
}

/// A failing schedule: replayable thread ids plus the human-readable
/// step trace up to (and including) the violating step.
#[derive(Debug, Clone)]
pub struct CounterExample {
    /// Thread id chosen at each step; feed to [`Explorer::replay`].
    pub schedule: Vec<usize>,
    /// One line per executed step.
    pub trace: Vec<String>,
    /// The violated invariant.
    pub error: String,
}

impl fmt::Display for CounterExample {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "invariant violated: {}", self.error)?;
        writeln!(f, "schedule (replayable): {:?}", self.schedule)?;
        for line in &self.trace {
            writeln!(f, "  {line}")?;
        }
        Ok(())
    }
}

/// Exploration tallies; the test suite asserts coverage floors on them.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ExploreStats {
    /// Complete (or truncated) schedules explored without a violation.
    pub schedules: usize,
    /// Total model steps executed, replays included.
    pub steps: u64,
    /// Schedules cut off at `max_steps` before every thread finished.
    pub truncated: usize,
    /// The `max_schedules` budget stopped the search before the DFS
    /// frontier was exhausted — coverage is a sample, not a proof.
    pub capped: bool,
}

/// Result of replaying one schedule prefix.
struct PrefixRun {
    done: Vec<bool>,
    blocked: Vec<bool>,
    trace: Vec<String>,
}

/// The controlled scheduler: bounded exhaustive DFS plus seeded-random
/// schedule sampling over any [`Model`].
#[derive(Debug, Clone, Copy)]
pub struct Explorer {
    /// Budget on complete schedules explored (DFS leaves / random runs).
    pub max_schedules: usize,
    /// Budget on steps per schedule (bounds livelock-ish models).
    pub max_steps: usize,
}

impl Default for Explorer {
    fn default() -> Self {
        Explorer { max_schedules: schedule_budget(), max_steps: 128 }
    }
}

/// The default schedule budget, env-tunable so the analysis CI job can
/// dial exhaustiveness without editing code: `OURO_MC_SCHEDULES=50000`
/// (any positive integer). Unset/invalid → 20k, the long-standing
/// default.
fn schedule_budget() -> usize {
    match std::env::var("OURO_MC_SCHEDULES") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => 20_000,
        },
        Err(_) => 20_000,
    }
}

impl Explorer {
    /// Run one schedule prefix from a fresh reset. `Err` carries the
    /// violating counterexample (invariant or final-state check).
    fn run_prefix<M: Model>(
        model: &mut M,
        schedule: &[usize],
    ) -> Result<PrefixRun, Box<CounterExample>> {
        model.reset();
        let n = model.threads();
        let mut done = vec![false; n];
        let mut blocked = vec![false; n];
        let mut trace = Vec::with_capacity(schedule.len());
        for (i, &tid) in schedule.iter().enumerate() {
            debug_assert!(tid < n && !done[tid], "schedule picked a dead thread");
            trace.push(format!("#{i:03} T{tid}: {}", model.describe(tid)));
            match model.step(tid) {
                // Progress may unblock spinners; re-arm every parked
                // thread (Blocked = "retry after someone else moves").
                Step::Progress => blocked.iter_mut().for_each(|b| *b = false),
                Step::Blocked => blocked[tid] = true,
                Step::Done => {
                    done[tid] = true;
                    blocked.iter_mut().for_each(|b| *b = false);
                }
            }
            if let Err(error) = model.check() {
                return Err(Box::new(CounterExample {
                    schedule: schedule[..=i].to_vec(),
                    trace,
                    error,
                }));
            }
        }
        if done.iter().all(|&d| d) {
            if let Err(e) = model.check_final() {
                return Err(Box::new(CounterExample {
                    schedule: schedule.to_vec(),
                    trace,
                    error: format!("final state: {e}"),
                }));
            }
        }
        Ok(PrefixRun { done, blocked, trace })
    }

    /// Bounded exhaustive DFS over every interleaving (up to the
    /// budgets). `Ok` carries coverage stats; `Err` the first failing
    /// schedule found.
    pub fn exhaustive<M: Model>(
        &self,
        model: &mut M,
    ) -> Result<ExploreStats, Box<CounterExample>> {
        let mut stats = ExploreStats::default();
        let mut prefix = Vec::new();
        self.dfs(model, &mut prefix, &mut stats)?;
        Ok(stats)
    }

    fn dfs<M: Model>(
        &self,
        model: &mut M,
        prefix: &mut Vec<usize>,
        stats: &mut ExploreStats,
    ) -> Result<(), Box<CounterExample>> {
        if stats.schedules >= self.max_schedules {
            stats.capped = true;
            return Ok(());
        }
        let run = Self::run_prefix(model, prefix)?;
        stats.steps += prefix.len() as u64;
        let runnable: Vec<usize> = (0..run.done.len())
            .filter(|&t| !run.done[t] && !run.blocked[t])
            .collect();
        if runnable.is_empty() {
            if run.done.iter().all(|&d| d) {
                stats.schedules += 1;
                return Ok(());
            }
            // Every live thread is parked and nothing can wake them.
            return Err(Box::new(CounterExample {
                schedule: prefix.clone(),
                trace: run.trace,
                error: "deadlock: every live thread blocked".into(),
            }));
        }
        if prefix.len() >= self.max_steps {
            stats.truncated += 1;
            stats.schedules += 1;
            return Ok(());
        }
        for tid in runnable {
            prefix.push(tid);
            self.dfs(model, prefix, stats)?;
            prefix.pop();
        }
        Ok(())
    }

    /// Seeded-random schedule sampling: `schedules` straight-through
    /// runs, each picking uniformly among runnable threads. Cheap
    /// coverage for spaces the exhaustive budget cannot enumerate;
    /// failures are as replayable as DFS ones.
    pub fn random<M: Model>(
        &self,
        model: &mut M,
        seed: u64,
        schedules: usize,
    ) -> Result<ExploreStats, Box<CounterExample>> {
        let mut rng = Rng::new(seed);
        let mut stats = ExploreStats::default();
        for round in 0..schedules {
            let mut thread_rng = rng.fork(round as u64);
            model.reset();
            let n = model.threads();
            let mut done = vec![false; n];
            let mut blocked = vec![false; n];
            let mut schedule = Vec::new();
            let mut trace = Vec::new();
            loop {
                let runnable: Vec<usize> = (0..n)
                    .filter(|&t| !done[t] && !blocked[t])
                    .collect();
                if runnable.is_empty() {
                    if done.iter().all(|&d| d) {
                        if let Err(e) = model.check_final() {
                            return Err(Box::new(CounterExample {
                                schedule,
                                trace,
                                error: format!("final state: {e}"),
                            }));
                        }
                        break;
                    }
                    return Err(Box::new(CounterExample {
                        schedule,
                        trace,
                        error: "deadlock: every live thread blocked".into(),
                    }));
                }
                if schedule.len() >= self.max_steps {
                    stats.truncated += 1;
                    break;
                }
                let tid = runnable[thread_rng.below(runnable.len() as u64) as usize];
                trace.push(format!(
                    "#{:03} T{tid}: {}",
                    schedule.len(),
                    model.describe(tid)
                ));
                schedule.push(tid);
                match model.step(tid) {
                    Step::Progress => blocked.iter_mut().for_each(|b| *b = false),
                    Step::Blocked => blocked[tid] = true,
                    Step::Done => {
                        done[tid] = true;
                        blocked.iter_mut().for_each(|b| *b = false);
                    }
                }
                stats.steps += 1;
                if let Err(error) = model.check() {
                    return Err(Box::new(CounterExample { schedule, trace, error }));
                }
            }
            stats.schedules += 1;
        }
        Ok(stats)
    }

    /// Deterministically re-run a (counterexample) schedule, returning
    /// the step trace on success or the reproduced failure.
    pub fn replay<M: Model>(
        model: &mut M,
        schedule: &[usize],
    ) -> Result<Vec<String>, Box<CounterExample>> {
        Self::run_prefix(model, schedule).map(|r| r.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads racing a torn read-modify-write on one cell: the
    /// canonical lost-update bug the scheduler must be able to find.
    struct TornCounter {
        cell: u32,
        // Per-thread pc + loaded snapshot.
        pc: [usize; 2],
        loaded: [u32; 2],
    }

    impl TornCounter {
        fn new() -> Self {
            TornCounter { cell: 0, pc: [0; 2], loaded: [0; 2] }
        }
    }

    impl Model for TornCounter {
        fn reset(&mut self) {
            *self = TornCounter::new();
        }
        fn threads(&self) -> usize {
            2
        }
        fn describe(&self, tid: usize) -> String {
            match self.pc[tid] {
                0 => "load cell".into(),
                _ => "store cell+1".into(),
            }
        }
        fn step(&mut self, tid: usize) -> Step {
            match self.pc[tid] {
                0 => {
                    self.loaded[tid] = self.cell;
                    self.pc[tid] = 1;
                    Step::Progress
                }
                _ => {
                    self.cell = self.loaded[tid] + 1;
                    Step::Done
                }
            }
        }
        fn check(&self) -> Result<(), String> {
            Ok(())
        }
        fn check_final(&self) -> Result<(), String> {
            if self.cell != 2 {
                return Err(format!("lost update: cell = {}", self.cell));
            }
            Ok(())
        }
    }

    #[test]
    fn exhaustive_finds_lost_update() {
        let ce = Explorer::default()
            .exhaustive(&mut TornCounter::new())
            .expect_err("the torn increment must be caught");
        assert!(ce.error.contains("lost update"), "{ce}");
        // The failing schedule replays to the same failure.
        let again = Explorer::replay(&mut TornCounter::new(), &ce.schedule)
            .expect_err("replay must reproduce");
        assert_eq!(again.error, ce.error);
        assert_eq!(again.schedule, ce.schedule);
    }

    #[test]
    fn random_finds_lost_update() {
        let ce = Explorer::default()
            .random(&mut TornCounter::new(), 0xC0FFEE, 64)
            .expect_err("random schedules must also hit the race");
        assert!(ce.error.contains("lost update"), "{ce}");
    }

    /// Two threads each waiting on a flag only the other would set:
    /// the scheduler must report deadlock, not spin forever.
    struct MutualWait {
        flags: [bool; 2],
        pc: [usize; 2],
    }

    impl Model for MutualWait {
        fn reset(&mut self) {
            *self = MutualWait { flags: [false; 2], pc: [0; 2] };
        }
        fn threads(&self) -> usize {
            2
        }
        fn describe(&self, tid: usize) -> String {
            format!("wait for flag {}", 1 - tid)
        }
        fn step(&mut self, tid: usize) -> Step {
            if self.flags[1 - tid] {
                self.flags[tid] = true;
                self.pc[tid] = 1;
                Step::Done
            } else {
                Step::Blocked
            }
        }
        fn check(&self) -> Result<(), String> {
            Ok(())
        }
    }

    #[test]
    fn mutual_wait_reported_as_deadlock() {
        let ce = Explorer::default()
            .exhaustive(&mut MutualWait { flags: [false; 2], pc: [0; 2] })
            .expect_err("deadlock must be detected");
        assert!(ce.error.contains("deadlock"), "{ce}");
    }

    /// A clean handshake explores every interleaving without violation
    /// and the stats count them.
    struct Handshake {
        turn: usize,
        pc: [usize; 2],
    }

    impl Model for Handshake {
        fn reset(&mut self) {
            *self = Handshake { turn: 0, pc: [0; 2] };
        }
        fn threads(&self) -> usize {
            2
        }
        fn describe(&self, tid: usize) -> String {
            format!("pc{}", self.pc[tid])
        }
        fn step(&mut self, tid: usize) -> Step {
            // Each thread takes two free steps; no coordination.
            self.pc[tid] += 1;
            self.turn += 1;
            if self.pc[tid] == 2 {
                Step::Done
            } else {
                Step::Progress
            }
        }
        fn check(&self) -> Result<(), String> {
            Ok(())
        }
        fn check_final(&self) -> Result<(), String> {
            if self.turn != 4 {
                return Err("step count drifted".into());
            }
            Ok(())
        }
    }

    #[test]
    fn exhaustive_counts_all_interleavings() {
        let stats = Explorer::default()
            .exhaustive(&mut Handshake { turn: 0, pc: [0; 2] })
            .expect("no violation");
        // 2 threads x 2 steps: C(4,2) = 6 interleavings.
        assert_eq!(stats.schedules, 6);
        assert!(!stats.capped);
        assert_eq!(stats.truncated, 0);
    }

    #[test]
    fn budget_caps_are_reported() {
        let tight = Explorer { max_schedules: 2, max_steps: 128 };
        let stats = tight
            .exhaustive(&mut Handshake { turn: 0, pc: [0; 2] })
            .expect("no violation");
        assert!(stats.capped);
        assert!(stats.schedules <= 2);
    }
}
