//! Ranked lock wrappers + a process-global lock-order graph: the
//! deadlock-freedom leg of the dynamic analysis layer.
//!
//! Every coordinator lock belongs to a [`LockClass`] with a static
//! *rank*; acquisitions must be strictly rank-increasing per thread
//! (outermost locks carry the lowest ranks). The full rank table lives
//! in [`classes`] and is documented in `docs/ARCHITECTURE.md` — it is
//! the written-down version of the nesting the coordinator actually
//! performs (federation slot → client caches → lease registry → lease
//! homes → forwarding → batcher → ring → launch-local results).
//!
//! Two layers of checking run on every acquisition:
//!
//! 1. **Rank discipline** (thread-local, a handful of ns): acquiring a
//!    lock whose rank is ≤ the highest rank already held panics
//!    immediately — before the process can deadlock — naming the full
//!    held chain and the acquisition site (`#[track_caller]`).
//! 2. **The lock-order graph** (process-global): the first time a
//!    thread acquires class B while holding class A, the edge A→B is
//!    recorded with a *sample acquisition history* (thread name, held
//!    chain, source locations). Inserting an edge that closes a cycle
//!    panics with **both** conflicting histories — the previously
//!    recorded path and the current acquisition — so an inverted order
//!    is diagnosed with evidence from both sides, not just a rank
//!    number. A per-thread edge cache keeps the global graph mutex off
//!    the hot path (one global hit per (thread, edge) pair, ever).
//!
//! The wrappers ([`OrderedMutex`], [`OrderedRwLock`]) mirror the std
//! API (`lock`/`read`/`write` returning [`std::sync::LockResult`]) so
//! call sites keep their `.unwrap()` poison handling; condvar waits go
//! through [`wait`] / [`wait_timeout`], which park on the *inner* std
//! guard (the lock really is released while parked, and the held-stack
//! entry stays put because a parked thread acquires nothing).
//!
//! Checking is always on: it is cheap enough for production builds,
//! and the point of ISSUE 10 is that every chaos run doubles as a
//! deadlock-freedom proof over the real execution.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::panic::Location;
use std::sync::{
    Condvar, LockResult, Mutex, MutexGuard, OnceLock, PoisonError, RwLock,
    RwLockReadGuard, RwLockWriteGuard, WaitTimeoutResult,
};
use std::time::Duration;

/// One lock *class*: every instance of a coordinator lock shares its
/// class's rank. Ranks must strictly increase along any nesting chain
/// (outer lock = lower rank).
#[derive(Debug)]
pub struct LockClass {
    pub name: &'static str,
    pub rank: u32,
}

/// The coordinator's rank table, outermost first. Gaps of 10 leave
/// room for future classes without renumbering. See
/// `docs/ARCHITECTURE.md` for the prose version of each edge.
pub mod classes {
    use super::LockClass;

    /// Federation watchdog spawn/stop slot (taken alone).
    pub static FED_WATCHDOG: LockClass =
        LockClass { name: "federation.watchdog", rank: 10 };
    /// Federation group slot (`RwLock<Option<AllocService>>`): held
    /// across whole client ops and restarts — the outermost lock of
    /// any federated call path.
    pub static FED_SLOT: LockClass =
        LockClass { name: "federation.slot", rank: 20 };
    /// Federation event log (recorded under the slot write lock on the
    /// restart path).
    pub static FED_EVENTS: LockClass =
        LockClass { name: "federation.events", rank: 30 };
    /// Per-federation-client cached group handles (held across the
    /// group-local client call).
    pub static FED_CLIENT_CACHE: LockClass =
        LockClass { name: "federation.client_cache", rank: 40 };
    /// Health monitor member table (reads gauges only; healing happens
    /// after it is dropped).
    pub static MONITOR_MEMBERS: LockClass =
        LockClass { name: "health.members", rank: 50 };
    /// Health monitor event log (taken alone).
    pub static MONITOR_EVENTS: LockClass =
        LockClass { name: "health.events", rank: 55 };
    /// The rebalance control plane (`Inner::rebalance_lock`).
    pub static REBALANCE: LockClass =
        LockClass { name: "service.rebalance", rank: 60 };
    /// Per-member paced-drain cursor (locked under the plane).
    pub static DRAIN_CURSOR: LockClass =
        LockClass { name: "service.drain_cursor", rank: 70 };
    /// Lane worker join handles (retire/readmit/shutdown).
    pub static WORKERS: LockClass =
        LockClass { name: "service.workers", rank: 80 };
    /// Per-handle outstanding-ticket ledger.
    pub static CLIENT_OUTSTANDING: LockClass =
        LockClass { name: "client.outstanding", rank: 90 };
    /// Per-handle lease cache (held across span mint + registry
    /// registration, hence below the registry and the ring).
    pub static CLIENT_CACHE: LockClass =
        LockClass { name: "client.cache", rank: 100 };
    /// Lease registry chunk map (`by_chunk` read held while lease
    /// homes are consulted in `resolve`).
    pub static LEASE_REGISTRY: LockClass =
        LockClass { name: "lease.by_chunk", rank: 110 };
    /// Per-lease span-home history.
    pub static LEASE_HOMES: LockClass =
        LockClass { name: "lease.homes", rank: 120 };
    /// Forwarding-table entry map.
    pub static FORWARDING: LockClass =
        LockClass { name: "forwarding.map", rank: 130 };
    /// Batcher avail-ring fill buffer (condvar-paired).
    pub static BATCHER_FILL: LockClass =
        LockClass { name: "batcher.fill", rank: 140 };
    /// Batcher spare-buffer pool.
    pub static BATCHER_SPARE: LockClass =
        LockClass { name: "batcher.spare", rank: 150 };
    /// Ticket-ring descriptor free list (condvar-paired).
    pub static RING_FREE: LockClass =
        LockClass { name: "ring.free", rank: 160 };
    /// Per-descriptor completion value slot.
    pub static RING_VALUE: LockClass =
        LockClass { name: "ring.value", rank: 170 };
    /// Ring completion-broadcast mutex (condvar-paired).
    pub static RING_DONE: LockClass =
        LockClass { name: "ring.done", rank: 180 };
    /// Launch-local result collectors (leaf: nothing nests inside).
    pub static LAUNCH_RESULT: LockClass =
        LockClass { name: "launch.result", rank: 190 };
}

/// One held-lock record on the thread-local stack.
#[derive(Clone, Copy)]
struct Held {
    class: &'static LockClass,
    at: &'static Location<'static>,
}

thread_local! {
    static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
    /// Edges this thread has already pushed to the global graph —
    /// keyed by (outer rank, inner rank) so the global mutex is paid
    /// once per (thread, edge), not per acquisition.
    static EDGE_CACHE: RefCell<HashSet<(u32, u32)>> =
        RefCell::new(HashSet::new());
}

/// A sample acquisition history for one observed edge: who held what,
/// where, when the edge was first seen.
#[derive(Clone, Debug)]
pub struct EdgeSample {
    pub thread: String,
    /// The held chain at acquisition time, as `name@file:line`.
    pub held_chain: Vec<String>,
    /// Where the inner lock was being acquired.
    pub acquired_at: String,
}

#[derive(Default)]
struct Graph {
    /// outer-class name → (inner-class name → first-seen sample).
    edges: HashMap<&'static str, HashMap<&'static str, EdgeSample>>,
}

impl Graph {
    /// Is `to` reachable from `from` along recorded edges?
    fn reaches(&self, from: &'static str, to: &'static str) -> bool {
        let mut seen = HashSet::new();
        let mut stack = vec![from];
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if !seen.insert(n) {
                continue;
            }
            if let Some(next) = self.edges.get(n) {
                stack.extend(next.keys().copied());
            }
        }
        false
    }
}

fn graph() -> &'static Mutex<Graph> {
    static GRAPH: OnceLock<Mutex<Graph>> = OnceLock::new();
    GRAPH.get_or_init(|| Mutex::new(Graph::default()))
}

fn held_chain_strings(held: &[Held]) -> Vec<String> {
    held.iter()
        .map(|h| format!("{}@{}:{}", h.class.name, h.at.file(), h.at.line()))
        .collect()
}

fn current_sample(held: &[Held], at: &'static Location<'static>) -> EdgeSample {
    EdgeSample {
        thread: std::thread::current()
            .name()
            .unwrap_or("<unnamed>")
            .to_string(),
        held_chain: held_chain_strings(held),
        acquired_at: format!("{}:{}", at.file(), at.line()),
    }
}

/// Record the acquisition of `class` at `at` given the current held
/// stack; panics on a rank inversion or a graph cycle, carrying both
/// conflicting acquisition histories.
fn check_and_record(class: &'static LockClass, at: &'static Location<'static>) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(outer) = held.iter().max_by_key(|e| e.class.rank) {
            let outer = *outer;
            if class.rank <= outer.class.rank {
                // Rank inversion. Consult the graph (without recording
                // the bad edge — the graph stays a DAG of *legal*
                // orders) for the previously recorded opposite
                // direction so the panic carries both histories.
                let conflict: Option<(String, EdgeSample)> = {
                    let g = graph()
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner);
                    if g.reaches(class.name, outer.class.name) {
                        g.edges
                            .get(class.name)
                            .and_then(|m| {
                                m.get(outer.class.name).cloned().or_else(
                                    || m.values().next().cloned(),
                                )
                            })
                            .map(|s| {
                                (
                                    format!(
                                        "{} -> {}",
                                        class.name, outer.class.name
                                    ),
                                    s,
                                )
                            })
                    } else {
                        None
                    }
                };
                let now = current_sample(&held, at);
                let prior = match &conflict {
                    Some((edge, s)) => format!(
                        "\n  previously recorded {edge} on thread {:?}:\n    \
                         held [{}], acquired at {}",
                        s.thread,
                        s.held_chain.join(", "),
                        s.acquired_at,
                    ),
                    None => String::new(),
                };
                panic!(
                    "lock-order cycle: acquiring {:?} (rank {}) while \
                     holding {:?} (rank {}) — ranks must strictly increase\n  \
                     this acquisition on thread {:?}:\n    held [{}], \
                     acquiring at {}{}",
                    class.name,
                    class.rank,
                    outer.class.name,
                    outer.class.rank,
                    now.thread,
                    now.held_chain.join(", "),
                    now.acquired_at,
                    prior,
                );
            }
            // Legal nesting: record the first-seen edge (per thread,
            // then per process) with its sample history.
            let fresh = EDGE_CACHE.with(|c| {
                c.borrow_mut().insert((outer.class.rank, class.rank))
            });
            if fresh {
                let mut g =
                    graph().lock().unwrap_or_else(PoisonError::into_inner);
                g.edges
                    .entry(outer.class.name)
                    .or_default()
                    .entry(class.name)
                    .or_insert_with(|| current_sample(&held, at));
            }
        }
        held.push(Held { class, at });
    });
}

fn pop_held(class: &'static LockClass) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        // Guards can drop out of stack order (e.g. `drop(outer)` while
        // an inner guard lives on); remove the most recent entry of
        // this class rather than assuming LIFO.
        if let Some(i) =
            held.iter().rposition(|e| std::ptr::eq(e.class, class))
        {
            held.remove(i);
        }
    });
}

/// Every edge the process has observed so far, as `(outer, inner)`
/// class-name pairs — the lock-order graph the chaos suites assert
/// acyclic (rank discipline makes a cycle panic at insertion, so a
/// surviving run *is* the proof; this accessor lets tests state it).
pub fn observed_edges() -> Vec<(&'static str, &'static str)> {
    let g = graph().lock().unwrap_or_else(PoisonError::into_inner);
    let mut out: Vec<(&'static str, &'static str)> = g
        .edges
        .iter()
        .flat_map(|(a, m)| m.keys().map(move |b| (*a, *b)))
        .collect();
    out.sort_unstable();
    out
}

/// Verify the recorded lock-order graph has no cycle (a redundant
/// check — an edge closing a cycle panics at acquisition — kept as the
/// explicit postcondition the chaos suites call).
pub fn assert_acyclic() {
    let g = graph().lock().unwrap_or_else(PoisonError::into_inner);
    let nodes: Vec<&'static str> = g.edges.keys().copied().collect();
    for &n in &nodes {
        if let Some(next) = g.edges.get(n) {
            for &m in next.keys() {
                assert!(
                    !g.reaches(m, n),
                    "lock-order graph cycle through {n} -> {m}"
                );
            }
        }
    }
}

// ---- Mutex ---------------------------------------------------------------

/// A std `Mutex` bound to a [`LockClass`]; acquisitions feed the rank
/// check and the lock-order graph.
pub struct OrderedMutex<T> {
    class: &'static LockClass,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub fn new(class: &'static LockClass, value: T) -> Self {
        OrderedMutex { class, inner: Mutex::new(value) }
    }

    #[track_caller]
    pub fn lock(&self) -> LockResult<OrderedMutexGuard<'_, T>> {
        check_and_record(self.class, Location::caller());
        match self.inner.lock() {
            Ok(g) => {
                Ok(OrderedMutexGuard { lock: self, inner: Some(g) })
            }
            Err(e) => Err(PoisonError::new(OrderedMutexGuard {
                lock: self,
                inner: Some(e.into_inner()),
            })),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("class", &self.class.name)
            .field("inner", &self.inner)
            .finish()
    }
}

pub struct OrderedMutexGuard<'a, T> {
    lock: &'a OrderedMutex<T>,
    /// `Option` so [`wait`]/[`wait_timeout`] can hand the inner guard
    /// to the condvar and put it back.
    inner: Option<MutexGuard<'a, T>>,
}

impl<T> std::ops::Deref for OrderedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().unwrap()
    }
}

impl<T> std::ops::DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().unwrap()
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        self.inner = None;
        pop_held(self.lock.class);
    }
}

/// Condvar wait through an [`OrderedMutexGuard`]: parks on the inner
/// std guard (the mutex is really released), hands the re-acquired
/// guard back. The held-stack entry stays put — a parked thread
/// acquires nothing, and on wake it holds exactly what it held before.
pub fn wait<'a, T>(
    cv: &Condvar,
    mut guard: OrderedMutexGuard<'a, T>,
) -> OrderedMutexGuard<'a, T> {
    let inner = guard.inner.take().unwrap();
    let inner = cv.wait(inner).unwrap_or_else(PoisonError::into_inner);
    guard.inner = Some(inner);
    guard
}

/// Timed counterpart of [`wait`].
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    mut guard: OrderedMutexGuard<'a, T>,
    dur: Duration,
) -> (OrderedMutexGuard<'a, T>, WaitTimeoutResult) {
    let inner = guard.inner.take().unwrap();
    let (inner, timed_out) = cv
        .wait_timeout(inner, dur)
        .unwrap_or_else(PoisonError::into_inner);
    guard.inner = Some(inner);
    (guard, timed_out)
}

// ---- RwLock --------------------------------------------------------------

/// A std `RwLock` bound to a [`LockClass`]. Read and write acquisitions
/// are ordered identically: a read held while a peer thread's writer
/// waits blocks later acquisitions just like a write would, so the
/// rank discipline must cover both.
pub struct OrderedRwLock<T> {
    class: &'static LockClass,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    pub fn new(class: &'static LockClass, value: T) -> Self {
        OrderedRwLock { class, inner: RwLock::new(value) }
    }

    #[track_caller]
    pub fn read(&self) -> LockResult<OrderedReadGuard<'_, T>> {
        check_and_record(self.class, Location::caller());
        match self.inner.read() {
            Ok(g) => Ok(OrderedReadGuard { lock: self, inner: Some(g) }),
            Err(e) => Err(PoisonError::new(OrderedReadGuard {
                lock: self,
                inner: Some(e.into_inner()),
            })),
        }
    }

    #[track_caller]
    pub fn write(&self) -> LockResult<OrderedWriteGuard<'_, T>> {
        check_and_record(self.class, Location::caller());
        match self.inner.write() {
            Ok(g) => Ok(OrderedWriteGuard { lock: self, inner: Some(g) }),
            Err(e) => Err(PoisonError::new(OrderedWriteGuard {
                lock: self,
                inner: Some(e.into_inner()),
            })),
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for OrderedRwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedRwLock")
            .field("class", &self.class.name)
            .field("inner", &self.inner)
            .finish()
    }
}

pub struct OrderedReadGuard<'a, T> {
    lock: &'a OrderedRwLock<T>,
    inner: Option<RwLockReadGuard<'a, T>>,
}

impl<T> std::ops::Deref for OrderedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().unwrap()
    }
}

impl<T> Drop for OrderedReadGuard<'_, T> {
    fn drop(&mut self) {
        self.inner = None;
        pop_held(self.lock.class);
    }
}

pub struct OrderedWriteGuard<'a, T> {
    lock: &'a OrderedRwLock<T>,
    inner: Option<RwLockWriteGuard<'a, T>>,
}

impl<T> std::ops::Deref for OrderedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().unwrap()
    }
}

impl<T> std::ops::DerefMut for OrderedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().unwrap()
    }
}

impl<T> Drop for OrderedWriteGuard<'_, T> {
    fn drop(&mut self) {
        self.inner = None;
        pop_held(self.lock.class);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Test-only classes with ranks far above the coordinator's so the
    // process-global graph never entangles these with real edges.
    static T_OUTER: LockClass = LockClass { name: "test.outer", rank: 1000 };
    static T_INNER: LockClass = LockClass { name: "test.inner", rank: 1010 };
    static T_A: LockClass = LockClass { name: "test.a", rank: 1100 };
    static T_B: LockClass = LockClass { name: "test.b", rank: 1110 };

    #[test]
    fn in_order_nesting_is_silent() {
        let outer = OrderedMutex::new(&T_OUTER, 1);
        let inner = OrderedMutex::new(&T_INNER, 2);
        let g1 = outer.lock().unwrap();
        let g2 = inner.lock().unwrap();
        assert_eq!(*g1 + *g2, 3);
        drop(g2);
        drop(g1);
        // Same thread, other order after full release: fine.
        let g2 = inner.lock().unwrap();
        drop(g2);
        let g1 = outer.lock().unwrap();
        drop(g1);
    }

    #[test]
    fn out_of_stack_order_guard_drop_is_fine() {
        let outer = OrderedMutex::new(&T_OUTER, 1);
        let inner = OrderedMutex::new(&T_INNER, 2);
        let g1 = outer.lock().unwrap();
        let g2 = inner.lock().unwrap();
        drop(g1); // outer released first
        drop(g2);
        let _g = outer.lock().unwrap();
    }

    #[test]
    fn inverted_acquisition_panics_with_both_histories() {
        // Record the legal order A -> B (with its history)...
        let a = OrderedMutex::new(&T_A, ());
        let b = OrderedMutex::new(&T_B, ());
        {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }
        // ...then invert it and catch the cycle report.
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
        }))
        .expect_err("inverted acquisition must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("lock-order cycle"), "{msg}");
        assert!(msg.contains("test.a") && msg.contains("test.b"), "{msg}");
        assert!(
            msg.contains("this acquisition"),
            "must carry the current history: {msg}"
        );
        assert!(
            msg.contains("previously recorded test.a -> test.b"),
            "must carry the recorded opposite-direction history: {msg}"
        );
        assert!(
            msg.contains("lockgraph.rs"),
            "histories must name source locations: {msg}"
        );
    }

    #[test]
    fn rwlock_read_participates_in_ordering() {
        static T_RW: LockClass = LockClass { name: "test.rw", rank: 1200 };
        static T_LEAF: LockClass =
            LockClass { name: "test.leaf", rank: 1210 };
        let rw = OrderedRwLock::new(&T_RW, 5);
        let leaf = OrderedMutex::new(&T_LEAF, ());
        let r = rw.read().unwrap();
        let _l = leaf.lock().unwrap();
        assert_eq!(*r, 5);
        drop(_l);
        drop(r);
        let mut w = rw.write().unwrap();
        *w += 1;
        drop(w);
        assert_eq!(*rw.read().unwrap(), 6);
    }

    #[test]
    fn condvar_wait_timeout_releases_and_reacquires() {
        static T_CV: LockClass = LockClass { name: "test.cv", rank: 1300 };
        let mx = OrderedMutex::new(&T_CV, 0u32);
        let cv = Condvar::new();
        let g = mx.lock().unwrap();
        let (mut g, timed_out) =
            wait_timeout(&cv, g, Duration::from_millis(1));
        assert!(timed_out.timed_out());
        *g += 1;
        drop(g);
        assert_eq!(*mx.lock().unwrap(), 1);
    }

    #[test]
    fn observed_edges_are_queryable_and_acyclic() {
        static T_E1: LockClass = LockClass { name: "test.e1", rank: 1400 };
        static T_E2: LockClass = LockClass { name: "test.e2", rank: 1410 };
        let a = OrderedMutex::new(&T_E1, ());
        let b = OrderedMutex::new(&T_E2, ());
        {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }
        assert!(observed_edges().contains(&("test.e1", "test.e2")));
        assert_acyclic();
    }
}
