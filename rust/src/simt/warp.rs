//! Lock-step warp execution.
//!
//! A warp executes its lanes sequentially inside one host task — exactly
//! the mental model of SIMT: lanes share a program counter, divergence is
//! expressed through the active mask. Kernels written against [`Warp`]
//! iterate `active_lanes()` for per-lane work and use `ballot`/`vote`
//! for warp-collective decisions, which the backend semantic model prices
//! (or deadlocks) per the paper's findings.

use super::ctx::DevCtx;

/// One warp's execution frame. `width` lanes, of which the low
/// `lanes_active` participate in this launch (tail warps are partial).
pub struct Warp<'a> {
    pub id: u32,
    pub width: u32,
    launch_mask: u32,
    diverged: u32,
    pub ctx: DevCtx<'a>,
}

impl<'a> Warp<'a> {
    pub fn new(id: u32, width: u32, lanes_active: u32, ctx: DevCtx<'a>) -> Self {
        assert!(width == 32 || width == 16, "warp width 16 or 32");
        assert!(lanes_active >= 1 && lanes_active <= width);
        let launch_mask = if lanes_active == 32 {
            u32::MAX
        } else {
            (1u32 << lanes_active) - 1
        };
        Warp { id, width, launch_mask, diverged: 0, ctx }
    }

    /// Mask of lanes resident in this launch (tail warps < full).
    pub fn launch_mask(&self) -> u32 {
        self.launch_mask
    }

    /// Mask of the full physical subgroup.
    pub fn full_mask(&self) -> u32 {
        if self.width == 32 {
            u32::MAX
        } else {
            (1u32 << self.width) - 1
        }
    }

    /// Currently active lanes (launch mask minus diverged lanes).
    pub fn active_mask(&self) -> u32 {
        self.launch_mask & !self.diverged
    }

    pub fn lane_count(&self) -> u32 {
        self.active_mask().count_ones()
    }

    /// Global thread id of `lane`.
    pub fn thread_id(&self, lane: u32) -> u32 {
        self.id * self.width + lane
    }

    /// Iterate the active lane indices (low to high — SIMT lane order).
    pub fn active_lanes(&self) -> impl Iterator<Item = u32> + '_ {
        let mask = self.active_mask();
        (0..self.width).filter(move |l| mask & (1 << l) != 0)
    }

    /// Mark `lane` diverged (it exited the current loop / took the other
    /// branch); collective ops afterwards see the reduced mask.
    pub fn diverge(&mut self, lane: u32) {
        self.diverged |= 1 << lane;
    }

    /// Reconverge all lanes of the launch (end of divergent region).
    pub fn reconverge(&mut self) {
        self.diverged = 0;
    }

    /// Warp ballot over the active lanes. Costs one vote; semantic
    /// validity is the backend's call (see `DevCtx::subgroup_sync`) —
    /// returns `None` when the backend deadlocks on a divergent mask.
    pub fn ballot(&self, pred: impl Fn(u32) -> bool) -> Option<u32> {
        if !self.ctx.subgroup_sync(self.active_mask(), self.launch_mask) {
            return None;
        }
        let mut out = 0u32;
        for lane in self.active_lanes() {
            if pred(lane) {
                out |= 1 << lane;
            }
        }
        Some(out)
    }

    /// `any` vote across active lanes.
    pub fn any(&self, pred: impl Fn(u32) -> bool) -> Option<bool> {
        self.ballot(pred).map(|m| m != 0)
    }

    /// `all` vote across active lanes.
    pub fn all(&self, pred: impl Fn(u32) -> bool) -> Option<bool> {
        let active = self.active_mask();
        self.ballot(pred).map(|m| m == active)
    }

    /// Elect the leader lane (lowest active), as `__ffs(__activemask())`.
    pub fn leader(&self) -> u32 {
        debug_assert!(self.active_mask() != 0);
        self.active_mask().trailing_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Acpp, Backend, Cuda};

    fn warp<'a>(b: &'a dyn Backend, active: u32) -> Warp<'a> {
        Warp::new(3, 32, active, DevCtx::new(b, 1000.0, 3))
    }

    #[test]
    fn full_warp_mask() {
        let b = Cuda::new();
        let w = warp(&b, 32);
        assert_eq!(w.active_mask(), u32::MAX);
        assert_eq!(w.lane_count(), 32);
        assert_eq!(w.active_lanes().count(), 32);
    }

    #[test]
    fn tail_warp_mask() {
        let b = Cuda::new();
        let w = warp(&b, 5);
        assert_eq!(w.active_mask(), 0b11111);
        assert_eq!(w.active_lanes().collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn thread_ids_are_global() {
        let b = Cuda::new();
        let w = warp(&b, 32);
        assert_eq!(w.thread_id(0), 96);
        assert_eq!(w.thread_id(31), 127);
    }

    #[test]
    fn divergence_and_reconvergence() {
        let b = Cuda::new();
        let mut w = warp(&b, 4);
        w.diverge(1);
        w.diverge(3);
        assert_eq!(w.active_mask(), 0b0101);
        assert_eq!(w.leader(), 0);
        w.diverge(0);
        assert_eq!(w.leader(), 2);
        w.reconverge();
        assert_eq!(w.active_mask(), 0b1111);
    }

    #[test]
    fn ballot_collects_predicate() {
        let b = Cuda::new();
        let w = warp(&b, 8);
        let m = w.ballot(|l| l % 2 == 0).unwrap();
        assert_eq!(m, 0b0101_0101);
        assert_eq!(w.any(|l| l == 3).unwrap(), true);
        assert_eq!(w.all(|l| l < 8).unwrap(), true);
        assert_eq!(w.all(|l| l < 4).unwrap(), false);
    }

    #[test]
    fn acpp_ballot_deadlocks_when_divergent() {
        let b = Acpp::new();
        let mut w = warp(&b, 32);
        assert!(w.ballot(|_| true).is_some()); // converged: fine
        w.diverge(7);
        assert!(w.ballot(|_| true).is_none()); // divergent: deadlock
        assert_eq!(w.ctx.events().deadlocks, 1);
    }

    #[test]
    fn width16_subgroup() {
        let b = Cuda::new();
        let w = Warp::new(0, 16, 16, DevCtx::new(&b, 1000.0, 0));
        assert_eq!(w.full_mask(), 0xFFFF);
        assert_eq!(w.active_mask(), 0xFFFF);
    }
}
