//! SIMT device simulator — the substrate replacing the paper's GPUs
//! (DESIGN.md §3). Real lock-free execution, modeled cycle costs.

mod ctx;
mod device;
mod warp;

pub use ctx::{ContendGuard, DevCtx, EventCounts, HotSpot, ParallelGuard};
pub use device::{Device, DeviceProfile, Grid, LaunchStats};
pub use warp::Warp;
