//! Simulated GPU device: launch geometry, the warp worker pool, and the
//! cycle→time makespan model.
//!
//! Substitution note (DESIGN.md §3): we have no NVIDIA/Intel GPU, so the
//! "device" executes warps as lock-step lane loops on a small host thread
//! pool, with **real** lock-free shared state (the allocator's atomics are
//! real `AtomicU32`s — races, retries and interleavings are real) and a
//! **modeled** clock: each warp accumulates device cycles from the backend
//! cost table, and launch time is the occupancy-weighted makespan.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::backend::Backend;

use super::ctx::{DevCtx, EventCounts};
use super::warp::Warp;

/// Hardware profile of the simulated accelerator.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Streaming multiprocessors (NVIDIA) / Xe-core-ish units (Intel).
    pub sms: u32,
    /// Resident warps per SM (occupancy ceiling).
    pub warps_per_sm: u32,
    /// SIMT width: 32 on NVIDIA, 16 subgroup lanes on Iris Xe.
    pub warp_width: u32,
    /// Core clock in MHz; converts cycles to microseconds.
    pub clock_mhz: f64,
}

impl DeviceProfile {
    /// NVIDIA Quadro T2000 (paper hardware #1): TU117, 16 SMs @ ~1455 MHz.
    pub fn t2000() -> Self {
        DeviceProfile {
            name: "quadro-t2000",
            sms: 16,
            warps_per_sm: 32,
            warp_width: 32,
            clock_mhz: 1455.0,
        }
    }

    /// Intel Iris Xe (i5-1340P iGPU, paper hardware #2): 80 EUs grouped in
    /// Xe cores, subgroup width 16, ~1500 MHz peak.
    pub fn iris_xe() -> Self {
        DeviceProfile {
            name: "iris-xe",
            sms: 10,
            warps_per_sm: 56,
            warp_width: 16,
            clock_mhz: 1500.0,
        }
    }

    /// Minimal single-"SM" profile for deterministic unit tests.
    pub fn test_tiny() -> Self {
        DeviceProfile {
            name: "test-tiny",
            sms: 1,
            warps_per_sm: 4,
            warp_width: 32,
            clock_mhz: 1000.0,
        }
    }

    /// Maximum concurrently resident warps.
    pub fn parallel_warps(&self) -> u64 {
        (self.sms * self.warps_per_sm) as u64
    }

    /// Look up a profile by name — the device-group construction hook
    /// (benches and tests spell heterogeneous topologies as name lists,
    /// e.g. `["t2000", "iris-xe"]`). Accepts each profile's `name` field
    /// plus the obvious short forms.
    pub fn parse(name: &str) -> Option<DeviceProfile> {
        match name {
            "quadro-t2000" | "t2000" => Some(DeviceProfile::t2000()),
            "iris-xe" | "xe" => Some(DeviceProfile::iris_xe()),
            "test-tiny" => Some(DeviceProfile::test_tiny()),
            _ => None,
        }
    }
}

/// Launch geometry: a flat number of logical threads, packed into warps of
/// `DeviceProfile::warp_width` lanes (tail warp partially active).
#[derive(Debug, Clone, Copy)]
pub struct Grid {
    pub threads: u32,
}

impl Grid {
    pub fn new(threads: u32) -> Self {
        assert!(threads > 0, "empty launch");
        Grid { threads }
    }

    pub fn warps(&self, width: u32) -> u32 {
        self.threads.div_ceil(width)
    }
}

/// Everything a launch reports back: modeled device time plus raw event
/// counts for the perf harness and the tests.
#[derive(Debug, Clone, Default)]
pub struct LaunchStats {
    /// Modeled device execution time, microseconds (excludes JIT warmup).
    pub device_us: f64,
    /// Modeled time including first-launch JIT translation, if this was
    /// the first time this program ran on this device+backend.
    pub device_us_with_jit: f64,
    /// Whether this launch paid the JIT warm-up.
    pub first_launch: bool,
    /// Host wall time spent simulating (L3 perf signal only).
    pub host_wall_us: f64,
    pub warps: u32,
    pub total_cycles: u64,
    pub max_warp_cycles: u64,
    pub events: EventCounts,
    /// True when a deadlock event tripped the watchdog (acpp pathology).
    pub timed_out: bool,
}

/// The simulated device. Owns the profile, the backend semantic model and
/// the JIT-seen program set.
pub struct Device {
    pub profile: DeviceProfile,
    pub backend: Arc<dyn Backend>,
    jit_seen: Mutex<std::collections::HashSet<String>>,
    pool_threads: usize,
}

impl Device {
    pub fn new(profile: DeviceProfile, backend: Arc<dyn Backend>) -> Self {
        let pool_threads = std::env::var("OURO_SIM_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(4)
            .max(1);
        Device { profile, backend, jit_seen: Mutex::new(Default::default()), pool_threads }
    }

    /// Reset JIT state (a fresh process in the paper's methodology).
    pub fn reset_jit(&self) {
        self.jit_seen.lock().unwrap().clear();
    }

    /// Execute `kernel` once per warp. The kernel body sees a [`Warp`]
    /// whose lanes it iterates in lock-step; shared state crossing warps
    /// must be atomics (exactly like the GPU original).
    pub fn launch<F>(&self, program: &str, grid: Grid, kernel: F) -> LaunchStats
    where
        F: Fn(&mut Warp) + Sync,
    {
        let width = self.profile.warp_width;
        let n_warps = grid.warps(width);
        let next = AtomicUsize::new(0);
        let agg: Mutex<(u64, u64, EventCounts)> =
            Mutex::new((0, 0, EventCounts::default()));

        let t0 = Instant::now();
        let workers = self.pool_threads.min(n_warps as usize).max(1);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // ordering: work-distribution ticket; uniqueness only
                    let w = next.fetch_add(1, Ordering::Relaxed);
                    if w >= n_warps as usize {
                        break;
                    }
                    let lanes_active = (grid.threads as u64
                        - (w as u64 * width as u64))
                        .min(width as u64) as u32;
                    let ctx = DevCtx::new(
                        self.backend.as_ref(),
                        self.profile.clock_mhz,
                        w as u32,
                    )
                    .with_grid_threads(grid.threads);
                    let mut warp = Warp::new(w as u32, width, lanes_active, ctx);
                    kernel(&mut warp);
                    let mut a = agg.lock().unwrap();
                    a.0 += warp.ctx.cycles();
                    a.1 = a.1.max(warp.ctx.cycles());
                    a.2.merge(&warp.ctx.events());
                });
            }
        });
        let host_wall_us = t0.elapsed().as_secs_f64() * 1e6;

        let (total_cycles, max_warp_cycles, events) =
            std::mem::take(&mut *agg.lock().unwrap());

        // Three-resource makespan model:
        //  * critical path — the longest single warp;
        //  * SM throughput — total warp cycles over resident-warp slots;
        //  * hot-word serialization — the device atomic unit retires RMWs
        //    on the same address one at a time; this bound is what makes
        //    total alloc time grow with thread count (paper right
        //    panels).
        let throughput_bound =
            total_cycles as f64 / self.profile.parallel_warps() as f64;
        let makespan_cycles = throughput_bound
            .max(max_warp_cycles as f64)
            .max(events.hot_serial_cycles as f64);
        let mut device_us = makespan_cycles / self.profile.clock_mhz;

        let timed_out = events.deadlocks > 0;
        if timed_out {
            // Watchdog: the paper's acpp runs hit kernel timeouts; the
            // reported time floors at the watchdog limit.
            device_us = device_us.max(self.backend.costs().watchdog_us);
        }

        let first_launch = self
            .jit_seen
            .lock()
            .unwrap()
            .insert(format!("{program}"));
        let jit = if first_launch { self.backend.costs().jit_warmup_us } else { 0.0 };

        LaunchStats {
            device_us,
            device_us_with_jit: device_us + jit,
            first_launch,
            host_wall_us,
            warps: n_warps,
            total_cycles,
            max_warp_cycles,
            events,
            timed_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Cuda, SyclOneapiNv};
    use std::sync::atomic::AtomicU32;

    fn dev() -> Device {
        Device::new(DeviceProfile::test_tiny(), Arc::new(Cuda::new()))
    }

    #[test]
    fn grid_packs_warps_with_tail() {
        assert_eq!(Grid::new(1).warps(32), 1);
        assert_eq!(Grid::new(32).warps(32), 1);
        assert_eq!(Grid::new(33).warps(32), 2);
        assert_eq!(Grid::new(1024).warps(32), 32);
        assert_eq!(Grid::new(1024).warps(16), 64);
    }

    #[test]
    fn launch_runs_every_lane_exactly_once() {
        let d = dev();
        let hits = AtomicU32::new(0);
        let st = d.launch("count", Grid::new(100), |w| {
            for _lane in w.active_lanes() {
                hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(st.warps, 4); // 100 / 32 -> 4 warps, tail of 4 lanes
    }

    #[test]
    fn cycles_accumulate_into_device_time() {
        let d = dev();
        let st = d.launch("charge", Grid::new(64), |w| {
            w.ctx.charge_alu(1000);
        });
        assert!(st.total_cycles >= 2000);
        assert!(st.device_us > 0.0);
        assert_eq!(st.max_warp_cycles, 1000);
    }

    #[test]
    fn makespan_respects_critical_path() {
        let d = dev();
        let st = d.launch("skew", Grid::new(128), |w| {
            if w.id == 0 {
                w.ctx.charge_alu(1_000_000);
            }
        });
        // One huge warp dominates: makespan ~ its cycles / clock.
        assert!(st.device_us >= 1_000_000.0 / 1000.0 * 0.99);
    }

    #[test]
    fn first_launch_pays_jit_then_stops() {
        let d = Device::new(
            DeviceProfile::test_tiny(),
            Arc::new(SyclOneapiNv::new()),
        );
        let a = d.launch("prog", Grid::new(32), |_| {});
        let b = d.launch("prog", Grid::new(32), |_| {});
        assert!(a.first_launch && !b.first_launch);
        assert!(a.device_us_with_jit > a.device_us);
        assert_eq!(b.device_us_with_jit, b.device_us);
    }

    #[test]
    fn reset_jit_restores_first_launch() {
        let d = Device::new(
            DeviceProfile::test_tiny(),
            Arc::new(SyclOneapiNv::new()),
        );
        let _ = d.launch("prog", Grid::new(32), |_| {});
        d.reset_jit();
        let again = d.launch("prog", Grid::new(32), |_| {});
        assert!(again.first_launch);
    }

    #[test]
    fn cuda_has_no_jit_warmup() {
        let d = dev();
        let a = d.launch("prog", Grid::new(32), |_| {});
        assert!(a.first_launch);
        assert_eq!(a.device_us_with_jit, a.device_us);
    }

    #[test]
    fn profiles_match_paper_hardware() {
        assert_eq!(DeviceProfile::t2000().warp_width, 32);
        assert_eq!(DeviceProfile::iris_xe().warp_width, 16);
        assert!(DeviceProfile::t2000().parallel_warps() >= 256);
    }

    #[test]
    fn profile_parse_roundtrips_names() {
        for p in [
            DeviceProfile::t2000(),
            DeviceProfile::iris_xe(),
            DeviceProfile::test_tiny(),
        ] {
            assert_eq!(DeviceProfile::parse(p.name).unwrap().name, p.name);
        }
        assert_eq!(DeviceProfile::parse("t2000").unwrap().name, "quadro-t2000");
        assert!(DeviceProfile::parse("h100").is_none());
    }
}
