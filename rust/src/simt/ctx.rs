//! Per-warp device context: the cycle ledger, instrumented atomics, the
//! contention model, backoff, and subgroup-sync semantics.
//!
//! All shared allocator state is **real** host atomics — the lock-free
//! algorithms run for real and their invariants are tested for real. What
//! is modeled is *cost*: every operation routed through [`DevCtx`] adds
//! backend-weighted device cycles to the warp's ledger, and RMWs on
//! declared [`HotSpot`]s additionally pay a serialisation term
//! proportional to the number of concurrently contending warps (this is
//! what makes latency grow with thread count, as in the paper's
//! right-hand panels).

use std::cell::Cell;
use std::sync::atomic::{AtomicU32, Ordering};

use crate::backend::{Backend, BackoffPolicy, VotePolicy};

/// A declared contention point (queue counters, chunk headers, ...).
/// `live` counts warps currently operating on the owning structure;
/// `ways` is the address-interleave factor — RMWs on a `ways`-way spread
/// structure serialize `ways`x less on the device atomic unit (e.g. page
/// acquires land on chunk headers spread across the resident set, while
/// a queue's `count` word is a single address).
#[derive(Debug)]
pub struct HotSpot {
    live: AtomicU32,
    ways: u32,
}

impl Default for HotSpot {
    fn default() -> Self {
        HotSpot { live: AtomicU32::new(0), ways: 1 }
    }
}

impl HotSpot {
    pub fn new() -> Self {
        Self::default()
    }

    /// A contention point interleaved over `ways` addresses.
    pub fn with_ways(ways: u32) -> Self {
        HotSpot { live: AtomicU32::new(0), ways: ways.max(1) }
    }

    pub fn contenders(&self) -> u32 {
        self.live.load(Ordering::Relaxed) // ordering: live-thread gauge; scheduler heuristic
    }

    pub fn ways(&self) -> u32 {
        self.ways
    }
}

/// RAII guard marking a warp as contending on a [`HotSpot`].
pub struct ContendGuard<'h> {
    hot: &'h HotSpot,
}

impl<'h> Drop for ContendGuard<'h> {
    fn drop(&mut self) {
        // ordering: live-thread gauge; scheduler heuristic
        self.hot.live.fetch_sub(1, Ordering::Relaxed);
    }
}

/// RAII guard for a SIMT lane-parallel region (see
/// [`DevCtx::parallel_lanes`]); restores the previous factor on drop.
pub struct ParallelGuard<'c, 'a> {
    ctx: &'c DevCtx<'a>,
    prev: f64,
}

impl Drop for ParallelGuard<'_, '_> {
    fn drop(&mut self) {
        self.ctx.parallel.set(self.prev);
    }
}

/// Raw event counters aggregated into `LaunchStats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventCounts {
    pub alu_ops: u64,
    pub mem_ops: u64,
    pub atomics: u64,
    pub cas_attempts: u64,
    pub cas_retries: u64,
    pub votes: u64,
    pub leader_elects: u64,
    pub fences: u64,
    pub sleeps: u64,
    pub deadlocks: u64,
    /// Device-wide serialized cycles on hot words (atomic-unit
    /// throughput + hot-line read stalls) — a *launch-level* resource
    /// bound, never divided by occupancy.
    pub hot_serial_cycles: u64,
}

impl EventCounts {
    pub fn merge(&mut self, o: &EventCounts) {
        self.alu_ops += o.alu_ops;
        self.mem_ops += o.mem_ops;
        self.atomics += o.atomics;
        self.cas_attempts += o.cas_attempts;
        self.cas_retries += o.cas_retries;
        self.votes += o.votes;
        self.leader_elects += o.leader_elects;
        self.fences += o.fences;
        self.sleeps += o.sleeps;
        self.deadlocks += o.deadlocks;
        self.hot_serial_cycles += o.hot_serial_cycles;
    }
}

/// Per-warp execution context. Not `Sync` — each warp owns its context;
/// only the underlying data atomics are shared.
pub struct DevCtx<'a> {
    backend: &'a dyn Backend,
    clock_mhz: f64,
    pub warp_id: u32,
    /// Total threads in the surrounding launch (drives the retry-
    /// divergence model; see [`DevCtx::divergence_draw`]).
    grid_threads: u32,
    /// SIMT lane parallelism of the current code region: per-lane costs
    /// charged inside a `parallel_lanes` region are divided by this
    /// (lanes of a warp execute concurrently; a warp's time is one
    /// lane's path, not the sum). Hot-serial costs are never divided —
    /// the atomic unit is a device-wide resource.
    parallel: Cell<f64>,
    cycles: Cell<u64>,
    // Event counters as individual cells: `Cell<EventCounts>` would copy
    // the whole 96-byte struct twice per charge — measured at ~18% of
    // the alloc hot path (EXPERIMENTS.md §Perf L3 iteration 1).
    alu_ops: Cell<u64>,
    mem_ops: Cell<u64>,
    atomics: Cell<u64>,
    cas_attempts: Cell<u64>,
    cas_retries: Cell<u64>,
    votes: Cell<u64>,
    leader_elects: Cell<u64>,
    fences: Cell<u64>,
    sleeps: Cell<u64>,
    deadlocks: Cell<u64>,
    hot_serial_cycles: Cell<u64>,
}

macro_rules! bump {
    ($self:ident . $field:ident += $n:expr) => {
        $self.$field.set($self.$field.get() + $n)
    };
}

impl<'a> DevCtx<'a> {
    pub fn new(backend: &'a dyn Backend, clock_mhz: f64, warp_id: u32) -> Self {
        DevCtx {
            backend,
            clock_mhz,
            warp_id,
            grid_threads: 32,
            parallel: Cell::new(1.0),
            cycles: Cell::new(0),
            alu_ops: Cell::new(0),
            mem_ops: Cell::new(0),
            atomics: Cell::new(0),
            cas_attempts: Cell::new(0),
            cas_retries: Cell::new(0),
            votes: Cell::new(0),
            leader_elects: Cell::new(0),
            fences: Cell::new(0),
            sleeps: Cell::new(0),
            deadlocks: Cell::new(0),
            hot_serial_cycles: Cell::new(0),
        }
    }

    /// Declare that the following per-lane work executes across `n`
    /// concurrent lanes; restores the previous factor on drop.
    pub fn parallel_lanes(&self, n: u32) -> ParallelGuard<'_, 'a> {
        let prev = self.parallel.get();
        self.parallel.set((n.max(1)) as f64);
        ParallelGuard { ctx: self, prev }
    }

    /// Set the launch width (Device::launch does this).
    pub fn with_grid_threads(mut self, n: u32) -> Self {
        self.grid_threads = n;
        self
    }

    /// Retry-divergence model: inside a lock-free retry loop, lanes of a
    /// warp diverge when some lanes' CAS/dequeue attempts fail while
    /// others succeed — the probability grows with the number of threads
    /// hammering the same queues. On this 1-core host the *physical*
    /// retry rate cannot scale with simulated thread count, so the draw
    /// is modeled: deterministic per (warp, round, width), zero below
    /// ~1024 threads, growing toward 1 at 10k — which reproduces the paper's
    /// observation that AdaptiveCpp "would struggle as the number of
    /// threads increased" while being stable at small widths
    /// (DESIGN.md §3).
    pub fn divergence_draw(&self, round: u32) -> bool {
        let t = self.grid_threads as f64;
        let p = ((t - 1024.0) / (t + 4096.0)).max(0.0);
        if p == 0.0 {
            return false;
        }
        let mut s = (self.warp_id as u64) << 40
            ^ (round as u64) << 8
            ^ self.grid_threads as u64;
        let r = crate::util::rng::splitmix64(&mut s) as f64
            / u64::MAX as f64;
        r < p
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    pub fn cycles(&self) -> u64 {
        self.cycles.get()
    }

    pub fn events(&self) -> EventCounts {
        EventCounts {
            alu_ops: self.alu_ops.get(),
            mem_ops: self.mem_ops.get(),
            atomics: self.atomics.get(),
            cas_attempts: self.cas_attempts.get(),
            cas_retries: self.cas_retries.get(),
            votes: self.votes.get(),
            leader_elects: self.leader_elects.get(),
            fences: self.fences.get(),
            sleeps: self.sleeps.get(),
            deadlocks: self.deadlocks.get(),
            hot_serial_cycles: self.hot_serial_cycles.get(),
        }
    }

    /// Modeled microseconds for this warp so far.
    pub fn us(&self) -> f64 {
        self.cycles.get() as f64 / self.clock_mhz
    }

    #[inline]
    fn add_cycles(&self, c: f64) {
        let c = c / self.parallel.get();
        self.cycles.set(self.cycles.get() + c.max(0.0) as u64);
    }

    /// Account device-wide serialized cycles (atomic-unit / hot-line
    /// traffic). Never divided by lane parallelism.
    #[inline]
    fn add_hot_serial(&self, c: f64) {
        bump!(self.hot_serial_cycles += c.max(0.0) as u64);
    }

    // ---- plain compute ---------------------------------------------------

    pub fn charge_alu(&self, n: u64) {
        self.add_cycles(self.backend.costs().alu * n as f64);
        bump!(self.alu_ops += n);
    }

    pub fn charge_mem(&self, n: u64) {
        self.add_cycles(self.backend.costs().mem * n as f64);
        bump!(self.mem_ops += n);
    }

    // ---- contention ------------------------------------------------------

    /// Mark this warp as contending on `hot` for the guard's lifetime.
    pub fn contend<'h>(&self, hot: &'h HotSpot) -> ContendGuard<'h> {
        // ordering: live-thread gauge; scheduler heuristic
        hot.live.fetch_add(1, Ordering::Relaxed);
        ContendGuard { hot }
    }

    #[inline]
    fn rmw_cost(&self, hot: &HotSpot) -> f64 {
        let c = self.backend.costs();
        c.atomic * c.atomic_overhead
            + c.contention_eta * hot.contenders() as f64
    }

    #[inline]
    fn rmw_serial(&self, hot: &HotSpot) -> f64 {
        let c = self.backend.costs();
        c.atomic_service * c.atomic_overhead / hot.ways() as f64
    }

    /// A read of a write-hot cache line (queue peek, occupancy-bitmap
    /// scan word, queue-list walk hop). Charges latency to the warp and
    /// a memory-system stall to the device-wide serial ledger — the
    /// stall is toolchain-independent (no codegen overhead multiplier).
    pub fn hot_read(&self, a: &AtomicU32, hot: &HotSpot) -> u32 {
        let c = self.backend.costs();
        self.add_cycles(c.mem + c.hot_read_stall);
        self.add_hot_serial(c.hot_read_stall / hot.ways() as f64);
        bump!(self.mem_ops += 1);
        a.load(Ordering::Acquire) // ordering: simulated device atomic; backend memory model
    }

    /// Hot-line stall without a physical load (walk hops over list
    /// metadata that the host-side structures don't materialise).
    pub fn charge_hot_read(&self, n: u64, hot: &HotSpot) {
        let c = self.backend.costs();
        self.add_cycles((c.mem + c.hot_read_stall) * n as f64);
        self.add_hot_serial(c.hot_read_stall * n as f64 / hot.ways() as f64);
        bump!(self.mem_ops += n);
    }

    // ---- instrumented atomics ---------------------------------------------

    /// Atomic load (read of potentially racing metadata).
    pub fn load(&self, a: &AtomicU32) -> u32 {
        self.add_cycles(self.backend.costs().mem);
        bump!(self.mem_ops += 1);
        a.load(Ordering::Acquire) // ordering: simulated device atomic; backend memory model
    }

    /// Atomic store.
    pub fn store(&self, a: &AtomicU32, v: u32) {
        self.add_cycles(self.backend.costs().mem);
        bump!(self.mem_ops += 1);
        a.store(v, Ordering::Release); // ordering: simulated device atomic; backend memory model
    }

    pub fn fetch_add(&self, a: &AtomicU32, v: u32, hot: &HotSpot) -> u32 {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        a.fetch_add(v, Ordering::AcqRel) // ordering: simulated device atomic; backend memory model
    }

    pub fn fetch_sub(&self, a: &AtomicU32, v: u32, hot: &HotSpot) -> u32 {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        a.fetch_sub(v, Ordering::AcqRel) // ordering: simulated device atomic; backend memory model
    }

    pub fn fetch_or(&self, a: &AtomicU32, v: u32, hot: &HotSpot) -> u32 {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        a.fetch_or(v, Ordering::AcqRel) // ordering: simulated device atomic; backend memory model
    }

    pub fn fetch_and(&self, a: &AtomicU32, v: u32, hot: &HotSpot) -> u32 {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        a.fetch_and(v, Ordering::AcqRel) // ordering: simulated device atomic; backend memory model
    }

    pub fn swap(&self, a: &AtomicU32, v: u32, hot: &HotSpot) -> u32 {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        a.swap(v, Ordering::AcqRel) // ordering: simulated device atomic; backend memory model
    }

    /// Compare-exchange; failures additionally pay the retry cost.
    pub fn cas(
        &self,
        a: &AtomicU32,
        cur: u32,
        new: u32,
        hot: &HotSpot,
    ) -> Result<u32, u32> {
        self.add_cycles(self.rmw_cost(hot));
        self.add_hot_serial(self.rmw_serial(hot));
        bump!(self.atomics += 1);
        bump!(self.cas_attempts += 1);
        // ordering: simulated device atomic; backend memory model
        let r = a.compare_exchange(cur, new, Ordering::AcqRel, Ordering::Acquire);
        if r.is_err() {
            self.add_cycles(self.backend.costs().cas_retry);
            bump!(self.cas_retries += 1);
        }
        r
    }

    // ---- backoff -----------------------------------------------------------

    /// Throttle this warp after `attempt` failed rounds on `hot`.
    /// CUDA `nanosleep` takes the warp *off* the hot path (live drops);
    /// the SYCL `atomic_fence` substitute keeps hammering (paper §2).
    pub fn backoff(&self, hot: &HotSpot, attempt: u32) {
        let c = self.backend.costs();
        match self.backend.backoff_policy() {
            BackoffPolicy::Nanosleep => {
                // ordering: live-thread gauge; scheduler heuristic
                hot.live.fetch_sub(1, Ordering::Relaxed);
                // Exponential up to 8x base, like the Ouroboros original.
                let factor = 1u64 << attempt.min(3);
                let ns = c.nanosleep_ns * factor as f64;
                self.add_cycles(ns * self.clock_mhz / 1000.0);
                bump!(self.sleeps += 1);
                // ordering: live-thread gauge; scheduler heuristic
                hot.live.fetch_add(1, Ordering::Relaxed);
            }
            BackoffPolicy::Fence => {
                // The fence is another device-wide memory-system round on
                // the contended line — unlike a sleeping warp, it keeps
                // adding serialized traffic (paper §2).
                self.add_cycles(c.fence);
                self.add_hot_serial(c.fence / hot.ways() as f64);
                bump!(self.fences += 1);
            }
        }
        // Let the host scheduler actually interleave on the 1-core box.
        std::thread::yield_now();
    }

    // ---- subgroup sync / votes ----------------------------------------------

    /// A subgroup-collective point reached with `active` of `full` lanes.
    /// Returns `false` if the backend deadlocks here (acpp + divergent
    /// mask); the caller falls back to the serial path and the watchdog
    /// accounts the timeout.
    pub fn subgroup_sync(&self, active: u32, full: u32) -> bool {
        let c = self.backend.costs();
        match self.backend.vote_policy() {
            VotePolicy::MaskedWarp => {
                self.add_cycles(c.vote);
                bump!(self.votes += 1);
                true
            }
            VotePolicy::ConvergedOnly => {
                if active == full {
                    self.add_cycles(c.vote);
                    bump!(self.votes += 1);
                } else {
                    self.add_cycles(c.vote + c.leader_elect);
                    bump!(self.votes += 1);
                    bump!(self.leader_elects += 1);
                }
                true
            }
            VotePolicy::EmulatedMaskDeadlock => {
                if active == full {
                    self.add_cycles(c.vote);
                    bump!(self.votes += 1);
                    true
                } else {
                    bump!(self.deadlocks += 1);
                    false
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Acpp, Backend, Cuda, CudaDeopt, SyclOneapiNv};

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    #[test]
    fn alu_and_mem_charges_accumulate() {
        let b = Cuda::new();
        let c = ctx(&b);
        c.charge_alu(10);
        c.charge_mem(5);
        assert_eq!(c.events().alu_ops, 10);
        assert_eq!(c.events().mem_ops, 5);
        assert!(c.cycles() >= 10 + 5 * 12);
    }

    #[test]
    fn atomics_are_real_and_counted() {
        let b = Cuda::new();
        let c = ctx(&b);
        let hot = HotSpot::new();
        let a = AtomicU32::new(5);
        assert_eq!(c.fetch_add(&a, 3, &hot), 5);
        assert_eq!(c.load(&a), 8);
        assert_eq!(c.swap(&a, 1, &hot), 8);
        assert_eq!(c.events().atomics, 2);
    }

    #[test]
    fn cas_failure_counts_retry() {
        let b = Cuda::new();
        let c = ctx(&b);
        let hot = HotSpot::new();
        let a = AtomicU32::new(7);
        assert!(c.cas(&a, 7, 8, &hot).is_ok());
        assert!(c.cas(&a, 7, 9, &hot).is_err());
        assert_eq!(c.events().cas_attempts, 2);
        assert_eq!(c.events().cas_retries, 1);
    }

    #[test]
    fn contention_raises_rmw_cost() {
        let b = Cuda::new();
        let hot = HotSpot::new();
        let a = AtomicU32::new(0);

        let quiet = ctx(&b);
        quiet.fetch_add(&a, 1, &hot);
        let quiet_cycles = quiet.cycles();

        let noisy = ctx(&b);
        let _g1 = noisy.contend(&hot);
        let _g2 = noisy.contend(&hot);
        let _g3 = noisy.contend(&hot);
        noisy.fetch_add(&a, 1, &hot);
        assert!(noisy.cycles() > quiet_cycles);
    }

    #[test]
    fn contend_guard_restores_live() {
        let b = Cuda::new();
        let c = ctx(&b);
        let hot = HotSpot::new();
        {
            let _g = c.contend(&hot);
            assert_eq!(hot.contenders(), 1);
        }
        assert_eq!(hot.contenders(), 0);
    }

    #[test]
    fn sycl_atomics_cost_about_double_cuda() {
        let hot = HotSpot::new();
        let a = AtomicU32::new(0);
        let bc = Cuda::new();
        let bs = SyclOneapiNv::new();
        let cc = ctx(&bc);
        let cs = ctx(&bs);
        for _ in 0..100 {
            cc.fetch_add(&a, 1, &hot);
            cs.fetch_add(&a, 1, &hot);
        }
        let ratio = cs.cycles() as f64 / cc.cycles() as f64;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn nanosleep_leaves_hot_path_fence_does_not() {
        let bc = Cuda::new();
        let bd = CudaDeopt::new();
        let hot = HotSpot::new();

        let c = ctx(&bc);
        c.backoff(&hot, 0);
        assert_eq!(c.events().sleeps, 1);
        assert_eq!(c.events().fences, 0);

        let d = ctx(&bd);
        d.backoff(&hot, 0);
        assert_eq!(d.events().fences, 1);
        assert_eq!(d.events().sleeps, 0);
    }

    #[test]
    fn backoff_grows_exponentially_then_caps() {
        let b = Cuda::new();
        let hot = HotSpot::new();
        let cost_of = |attempt| {
            let c = ctx(&b);
            c.backoff(&hot, attempt);
            c.cycles()
        };
        assert!(cost_of(1) > cost_of(0));
        assert!(cost_of(3) > cost_of(2));
        assert_eq!(cost_of(3), cost_of(9)); // capped at 8x
    }

    #[test]
    fn vote_semantics_per_backend() {
        let full = 0xFFFF_FFFF;
        let div = 0x0000_00FF;

        let b = Cuda::new();
        let c = ctx(&b);
        assert!(c.subgroup_sync(div, full)); // masked vote fine
        assert_eq!(c.events().leader_elects, 0);

        let b = SyclOneapiNv::new();
        let c = ctx(&b);
        assert!(c.subgroup_sync(div, full)); // works but leader-elects
        assert_eq!(c.events().leader_elects, 1);
        assert!(c.subgroup_sync(full, full));
        assert_eq!(c.events().leader_elects, 1);

        let b = Acpp::new();
        let c = ctx(&b);
        assert!(c.subgroup_sync(full, full)); // converged ok
        assert!(!c.subgroup_sync(div, full)); // divergent deadlocks
        assert_eq!(c.events().deadlocks, 1);
    }
}
