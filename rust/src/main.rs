//! ouroboros-tpu CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info             list variants, backends, device profiles
//!   driver           run the paper's benchmark driver once
//!   figures          regenerate paper figures (tables + CSV)
//!   claims           evaluate the paper's qualitative claims
//!   jit-table        the §3 Methods all-vs-subsequent JIT table
//!   fragmentation    the §4.1 churn study (--xla: Pallas frag_metric)
//!   memory-table     queue-memory footprint (the Ouroboros claim)
//!   verify-runtime   round-trip the AOT artifacts through PJRT

use std::path::PathBuf;

use ouroboros_tpu::backend;
use ouroboros_tpu::coordinator::driver::{run_driver, DataPhase, DriverConfig};
use ouroboros_tpu::harness::{expectations, figures, report};
use ouroboros_tpu::ouroboros::{HeapConfig, Variant};
use ouroboros_tpu::runtime::{pattern, Runtime};
use ouroboros_tpu::simt::{Device, DeviceProfile};
use ouroboros_tpu::util::cli::Args;
use ouroboros_tpu::util::errs::{Context, Result};
use ouroboros_tpu::{anyhow, bail, ensure};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.positional(0) {
        Some("info") => cmd_info(),
        Some("driver") => cmd_driver(&args),
        Some("figures") => cmd_figures(&args),
        Some("claims") => cmd_claims(&args),
        Some("jit-table") => cmd_jit_table(&args),
        Some("fragmentation") => cmd_fragmentation(&args),
        Some("memory-table") => cmd_memory_table(&args),
        Some("verify-runtime") => cmd_verify_runtime(),
        None | Some("help") => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command `{other}` (try `help`)"),
    }
}

fn print_help() {
    println!(
        "ouroboros-tpu — reproduction of 'Dynamic Memory Management on \
         GPUs with SYCL'\n\n\
         USAGE: ouroboros-tpu <command> [options]\n\n\
         COMMANDS:\n  \
         info             list variants, backends, device profiles\n  \
         driver           --variant page --backend cuda [--device t2000]\n                   \
         [--size 1000] [--threads 1024] [--iters 10]\n                   \
         [--data sim|xla|none]\n  \
         figures          --fig N | --all  [--quick] [--out results]\n  \
         claims           [--quick] evaluate the paper's claims\n  \
         jit-table        [--variant page] §3 all-vs-subsequent means\n  \
         fragmentation    [--slots 128] [--ops 2000] §4.1 churn study\n  \
         memory-table     queue-memory footprint (the Ouroboros claim)\n  \
         verify-runtime   PJRT round-trip of the AOT artifacts"
    );
}

fn device_for(args: &Args, backend_id: &str) -> Result<Device> {
    let be = backend::by_id(backend_id)
        .with_context(|| format!("unknown backend `{backend_id}`"))?;
    let profile = match args.get_or("device", "auto") {
        "t2000" => DeviceProfile::t2000(),
        "iris-xe" => DeviceProfile::iris_xe(),
        "auto" => {
            if backend_id == "sycl-xe" {
                DeviceProfile::iris_xe()
            } else {
                DeviceProfile::t2000()
            }
        }
        other => bail!("unknown device `{other}` (t2000 | iris-xe)"),
    };
    Ok(Device::new(profile, be))
}

fn cmd_info() -> Result<()> {
    println!("allocator variants (paper figure in parens):");
    for v in Variant::all() {
        println!("  {:<10} fig {}  {}", v.id(), v.figure(), v.label());
    }
    println!("\nbackends:");
    for b in backend::all_backends() {
        let c = b.costs();
        println!(
            "  {:<11} {:<24} atomic x{:.2}  jit {:>6.0}us  coalesced={} ",
            b.id(),
            b.label(),
            c.atomic_overhead,
            c.jit_warmup_us,
            b.warp_coalesced()
        );
    }
    println!("\ndevice profiles: t2000 (NVIDIA Quadro T2000), iris-xe (Intel Iris Xe)");
    Ok(())
}

fn cmd_driver(args: &Args) -> Result<()> {
    let variant = Variant::parse(args.get_or("variant", "page"))
        .context("unknown --variant (see `info`)")?;
    let backend_id = args.get_or("backend", "cuda").to_string();
    let device = device_for(args, &backend_id)?;
    let data_phase = match args.get_or("data", "sim") {
        "sim" => DataPhase::Sim,
        "xla" => DataPhase::Xla,
        "none" => DataPhase::None,
        other => bail!("unknown --data `{other}`"),
    };
    let cfg = DriverConfig {
        variant,
        alloc_size: args.u64_or("size", 1000) as u32,
        num_allocations: args.u64_or("threads", 1024) as u32,
        iterations: args.usize_or("iters", 10),
        data_phase,
        heap: HeapConfig::default(),
        seed: args.u64_or("seed", 0x5EED) as i32,
    };
    args.finish().map_err(|e| anyhow!(e))?;

    let runtime = if data_phase == DataPhase::Xla {
        Some(Runtime::load_default()?)
    } else {
        None
    };
    let rep = run_driver(&device, &cfg, runtime.as_ref())?;
    let a = rep.alloc_split();
    let f = rep.free_split();
    println!(
        "driver variant={} backend={} device={} size={}B threads={} iters={}",
        rep.variant.id(),
        rep.backend,
        rep.device,
        rep.alloc_size,
        rep.num_allocations,
        rep.iters.len()
    );
    println!(
        "alloc us/op: first={:.3} mean_all={:.3} mean_subsequent={:.3}",
        a.first / rep.num_allocations as f64,
        a.mean_all / rep.num_allocations as f64,
        a.mean_subsequent / rep.num_allocations as f64
    );
    println!(
        "free  us/op: first={:.3} mean_all={:.3} mean_subsequent={:.3}",
        f.first / rep.num_allocations as f64,
        f.mean_all / rep.num_allocations as f64,
        f.mean_subsequent / rep.num_allocations as f64
    );
    println!(
        "verify={} timeouts={} deadlocks={}",
        rep.verify_ok(),
        rep.any_timeout(),
        rep.total_deadlocks()
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let opts = figures::SweepOpts {
        quick: args.has_flag("quick"),
        iterations: args.usize_or("iters", 10),
        heap: HeapConfig::default(),
    };
    let out: PathBuf = args.get_or("out", "results").into();
    let figs: Vec<u32> = if args.has_flag("all") {
        (1..=6).collect()
    } else {
        vec![args.u64_or("fig", 1) as u32]
    };
    args.finish().map_err(|e| anyhow!(e))?;
    for fig in figs {
        eprintln!("running figure {fig} ...");
        let r = figures::run_figure(fig, &opts)?;
        print!("{}", report::render_figure(&r));
        report::write_figure(&r, &out)?;
        println!("  -> {}/fig{}.{{txt,csv}}\n", out.display(), fig);
    }
    Ok(())
}

fn cmd_claims(args: &Args) -> Result<()> {
    let opts = figures::SweepOpts {
        quick: args.has_flag("quick"),
        iterations: args.usize_or("iters", 6),
        heap: HeapConfig::default(),
    };
    args.finish().map_err(|e| anyhow!(e))?;
    eprintln!("measuring figures 1 and 2 for claim evaluation ...");
    let f1 = figures::run_figure(1, &opts)?;
    let f2 = figures::run_figure(2, &opts)?;
    let claims = expectations::standard_claims(&f1, &f2);
    print!("{}", expectations::render_claims(&claims));
    let failed = claims.iter().filter(|c| !c.holds).count();
    if failed > 0 {
        bail!("{failed} claim(s) do not hold on this run");
    }
    Ok(())
}

fn cmd_jit_table(args: &Args) -> Result<()> {
    let variant = Variant::parse(args.get_or("variant", "page"))
        .context("unknown --variant")?;
    let iters = args.usize_or("iters", 10);
    args.finish().map_err(|e| anyhow!(e))?;
    println!(
        "§3 Methods table — {} allocator, 1024 x 1000 B, {iters} iterations \
         (us/alloc)",
        variant.id()
    );
    println!(
        "{:<12} {:>12} {:>12} {:>12} {:>8}",
        "backend", "first", "mean_all", "mean_subseq", "jit?"
    );
    for (be, profile) in figures::backend_device_pairs() {
        let device = Device::new(profile, be.clone());
        let cfg = DriverConfig {
            variant,
            alloc_size: 1000,
            num_allocations: 1024,
            iterations: iters,
            data_phase: DataPhase::Sim,
            heap: HeapConfig::default(),
            seed: 7,
        };
        let rep = run_driver(&device, &cfg, None)?;
        let a = rep.alloc_split();
        let n = rep.num_allocations as f64;
        println!(
            "{:<12} {:>12.3} {:>12.3} {:>12.3} {:>8}",
            be.id(),
            a.first / n,
            a.mean_all / n,
            a.mean_subsequent / n,
            if be.costs().jit_warmup_us > 0.0 { "yes" } else { "no" }
        );
    }
    Ok(())
}

fn cmd_fragmentation(args: &Args) -> Result<()> {
    let slots = args.usize_or("slots", 128);
    let ops = args.usize_or("ops", 2000);
    let seed = args.u64_or("seed", 7);
    let use_xla = args.has_flag("xla");
    args.finish().map_err(|e| anyhow!(e))?;
    println!(
        "fragmentation study (paper §4.1): churn trace, {slots} slots, \
         {ops} ops, mixed sizes\n"
    );
    print!(
        "{}",
        ouroboros_tpu::harness::fragmentation::fragmentation_table(
            seed, slots, ops
        )
    );
    println!(
        "\n(page variants strand chunks — the fragmentation weakness the \
         paper notes; chunk variants reclaim via sweep)"
    );
    if use_xla {
        // Per-chunk fragmentation scores computed by the AOT Pallas
        // frag_metric kernel on a live page-allocator heap.
        use ouroboros_tpu::backend::Cuda;
        use ouroboros_tpu::coordinator::workload::{churn_trace, TraceOp};
        use ouroboros_tpu::ouroboros::{build_allocator, params};
        use ouroboros_tpu::simt::DevCtx;

        let rt = Runtime::load_default()?;
        let m = rt.manifest.clone();
        let alloc =
            build_allocator(Variant::Page, &HeapConfig::default());
        let b = Cuda::new();
        let ctx = DevCtx::new(&b, 1455.0, 0);
        let mut live: std::collections::HashMap<usize, u32> = Default::default();
        for op in churn_trace(seed, slots, ops, params::CHUNK_SIZE) {
            match op {
                TraceOp::Alloc { slot, size } => {
                    live.insert(slot, alloc.malloc(&ctx, size)?);
                }
                TraceOp::Free { slot } => {
                    if ops % 3 != 0 {
                        // leave some live allocations to fragment
                    }
                    if let Some(a) = live.remove(&slot) {
                        alloc.free(&ctx, a)?;
                    }
                }
            }
            if live.len() > slots / 2 {
                break; // snapshot mid-churn with plenty live
            }
        }
        let heap = alloc.heap();
        let words = m.bitmap_words as usize;
        let mut bitmaps = vec![u32::MAX; m.plan_chunks as usize * words];
        for c in 0..m.plan_chunks.min(heap.num_chunks()) {
            if heap.header(c).state()
                == ouroboros_tpu::ouroboros::chunk::STATE_OWNED
            {
                let snap = heap.header(c).snapshot_bitmap();
                let base = c as usize * words;
                bitmaps[base..base + words].copy_from_slice(&snap);
            }
        }
        let out = rt.frag_report(&bitmaps)?;
        let owned: Vec<usize> = (0..m.plan_chunks as usize)
            .filter(|&c| out.free_count[c] > 0 || out.longest_run[c] > 0)
            .collect();
        let mean_score: f64 = owned
            .iter()
            .map(|&c| out.frag_score[c] as f64)
            .sum::<f64>()
            / owned.len().max(1) as f64;
        println!(
            "\nXLA frag_report over live heap: {} occupied chunks, mean \
             frag score {:.0} permille (computed by the AOT Pallas kernel \
             via PJRT)",
            owned.len(),
            mean_score
        );
    }
    Ok(())
}

fn cmd_memory_table(args: &Args) -> Result<()> {
    let load = args.u64_or("load", 2048) as u32;
    let size = args.u64_or("size", 1000) as u32;
    args.finish().map_err(|e| anyhow!(e))?;
    println!(
        "queue-memory footprint (Ouroboros virtualization claim), load = \
         {load} x {size} B live:\n"
    );
    let rows = ouroboros_tpu::harness::memory_report::measure(
        &HeapConfig::default(),
        load,
        size,
    );
    print!("{}", ouroboros_tpu::harness::memory_report::render(&rows));
    Ok(())
}

fn cmd_verify_runtime() -> Result<()> {
    let rt = Runtime::load_default()?;
    println!("PJRT platform: {}", rt.platform());
    let m = rt.manifest.clone();
    println!(
        "manifest: {} queues, chunk {} B, plan {}x{}, touch {}x{}",
        m.num_queues, m.chunk_size, m.plan_batch, m.plan_chunks, m.touch_pages,
        m.page_words
    );

    // workload_step round trip vs the independent host pattern.
    let offsets: Vec<i32> = (0..m.touch_pages as i32).map(|i| i * 1024).collect();
    let out = rt.workload_step(&offsets, 42)?;
    for (i, &off) in offsets.iter().enumerate().step_by(97) {
        ensure!(
            out.checksums[i]
                == pattern::expected_checksum(off, m.page_words, 42),
            "checksum mismatch at page {i}"
        );
        ensure!(
            out.probe[i] == pattern::expected_word(off, 0, 42),
            "probe mismatch at page {i}"
        );
    }
    println!("workload_step: {} pages verified OK", offsets.len());

    // plan_alloc round trip vs the host queue binning.
    let sizes: Vec<i32> = (0..m.plan_batch as i32)
        .map(|i| 1 + (i * 37) % 8192)
        .collect();
    let bitmaps = vec![0u32; (m.plan_chunks * m.bitmap_words) as usize];
    let plan = rt.plan_alloc(&sizes, &bitmaps)?;
    for (i, &s) in sizes.iter().enumerate() {
        let want = ouroboros_tpu::ouroboros::params::queue_for_size(s as u32)
            .unwrap() as i32;
        ensure!(
            plan.queue_idx[i] == want,
            "queue binning mismatch for size {s}: {} != {want}",
            plan.queue_idx[i]
        );
    }
    ensure!(plan.first_free.iter().all(|&f| f == 0));
    ensure!(plan
        .free_count
        .iter()
        .all(|&c| c == 32 * m.bitmap_words as i32));
    println!("plan_alloc: {} requests verified OK", sizes.len());
    println!("verify-runtime OK");
    Ok(())
}
