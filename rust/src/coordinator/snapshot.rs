//! Durability snapshots: the hand-rolled, versioned wire format that
//! lets a restarted `AllocService` keep honoring the names a dead
//! process minted.
//!
//! Two pieces of control-plane state must survive a restart (see the
//! durability section of `coordinator/rebalance.rs`): the forwarding
//! table (stale names → migrated copies, with per-entry grace ages and
//! consumed flags) and the per-member paced-drain cursors. Everything
//! else — heaps, rings, workers — is either the durable data plane
//! itself or cheap to rebuild.
//!
//! # Format spec (`OUROSNAP` version 1)
//!
//! A snapshot is UTF-8 text, one record per `\n`-terminated line,
//! checksummed; the crate is zero-dependency so the format is
//! hand-rolled rather than serde-derived. Grammar:
//!
//! ```text
//! OUROSNAP 1                          header: magic + format version
//! grace <u64>                         forwarding grace, nanoseconds
//! cursors <n>                         exactly n cursor lines follow
//! cursor <chunk:u32> <page:u32> <exhausted:0|1>
//! entries <m>                         exactly m entry lines follow
//! entry <old:hex32> <to:hex32> <age_nanos:u64> <consumed:0|1>
//! checksum <fnv1a64:hex>              over every byte above this line
//! ```
//!
//! * `cursor` lines appear in member order: line *i* is device *i*'s
//!   drain position. Restore refuses a snapshot whose cursor count
//!   disagrees with the restarted group's member count.
//! * `entry` ages are **elapsed** nanoseconds at export time, so a
//!   restored entry resumes its grace countdown (`rebalance.rs`
//!   re-anchors them against the restore instant).
//! * The checksum is FNV-1a 64 over the exact bytes of all preceding
//!   lines (including their `\n` terminators), rendered as 16 lowercase
//!   hex digits.
//!
//! Any deviation — truncation anywhere (missing header, fewer records
//! than the declared counts, absent checksum line), a checksum
//! mismatch, an unsupported version, trailing bytes after the
//! checksum, or a malformed field — decodes to
//! [`AllocError::SnapshotCorrupt`]. Never a panic, and never a
//! silently empty table: a snapshot either applies whole or not at
//! all, because a half-restored forwarding table converts every
//! missing entry into a lost block.

use std::fs;
use std::path::Path;

use crate::ouroboros::{AllocError, GlobalAddr};

use super::rebalance::ForwardExport;

/// The only format version this build reads and writes.
pub const SNAPSHOT_VERSION: u32 = 1;

const MAGIC: &str = "OUROSNAP";

/// One member's paced-drain position as persisted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CursorSnapshot {
    pub chunk: u32,
    pub page: u32,
    pub exhausted: bool,
}

/// The durable control-plane state of one `AllocService`, as captured
/// by `AllocService::prepare_handoff` / `snapshot_state` and re-applied
/// by `AllocService::start_group_restored`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceSnapshot {
    /// Forwarding grace window, nanoseconds.
    pub grace_nanos: u64,
    /// Per-member drain cursors, in device order.
    pub cursors: Vec<CursorSnapshot>,
    /// Forwarding-table entries with their export-time ages.
    pub entries: Vec<ForwardExport>,
}

/// FNV-1a 64 — the crate's standing zero-dep integrity hash.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl ServiceSnapshot {
    /// Render the snapshot in the `OUROSNAP 1` wire format.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{MAGIC} {SNAPSHOT_VERSION}\n"));
        out.push_str(&format!("grace {}\n", self.grace_nanos));
        out.push_str(&format!("cursors {}\n", self.cursors.len()));
        for c in &self.cursors {
            out.push_str(&format!(
                "cursor {} {} {}\n",
                c.chunk,
                c.page,
                c.exhausted as u8
            ));
        }
        out.push_str(&format!("entries {}\n", self.entries.len()));
        for e in &self.entries {
            out.push_str(&format!(
                "entry {:08x} {:08x} {} {}\n",
                e.old,
                e.to.raw(),
                e.age_nanos,
                e.consumed as u8
            ));
        }
        out.push_str(&format!("checksum {:016x}\n", fnv1a64(out.as_bytes())));
        out
    }

    /// Parse and verify a snapshot. Every failure mode — truncation,
    /// checksum mismatch, version skew, malformed records, trailing
    /// garbage — is the single deterministic
    /// [`AllocError::SnapshotCorrupt`]; a caller never sees a partial
    /// table.
    pub fn decode(bytes: &[u8]) -> Result<ServiceSnapshot, AllocError> {
        let text = std::str::from_utf8(bytes).map_err(|_| AllocError::SnapshotCorrupt)?;

        // The checksum line covers every byte before it, so locate it
        // structurally (last line) before parsing anything else.
        let body_end = text.rfind("checksum ").ok_or(AllocError::SnapshotCorrupt)?;
        // The checksum line must start a line, not sit mid-record.
        if body_end != 0 && text.as_bytes()[body_end - 1] != b'\n' {
            return Err(AllocError::SnapshotCorrupt);
        }
        let (body, check_line) = text.split_at(body_end);
        let want = check_line
            .strip_prefix("checksum ")
            .and_then(|s| s.strip_suffix('\n'))
            .and_then(|s| u64::from_str_radix(s.trim(), 16).ok())
            .ok_or(AllocError::SnapshotCorrupt)?;
        if fnv1a64(body.as_bytes()) != want {
            return Err(AllocError::SnapshotCorrupt);
        }

        let mut lines = body.lines();
        let header = lines.next().ok_or(AllocError::SnapshotCorrupt)?;
        let version: u32 = header
            .strip_prefix(MAGIC)
            .map(str::trim)
            .and_then(|v| v.parse().ok())
            .ok_or(AllocError::SnapshotCorrupt)?;
        if version != SNAPSHOT_VERSION {
            return Err(AllocError::SnapshotCorrupt);
        }

        let grace_nanos: u64 = field(lines.next(), "grace")?
            .parse()
            .map_err(|_| AllocError::SnapshotCorrupt)?;

        let n_cursors: usize = field(lines.next(), "cursors")?
            .parse()
            .map_err(|_| AllocError::SnapshotCorrupt)?;
        let mut cursors = Vec::with_capacity(n_cursors.min(1024));
        for _ in 0..n_cursors {
            let rest = field(lines.next(), "cursor")?;
            let mut it = rest.split_ascii_whitespace();
            let chunk = parse_u32(it.next())?;
            let page = parse_u32(it.next())?;
            let exhausted = parse_flag(it.next())?;
            if it.next().is_some() {
                return Err(AllocError::SnapshotCorrupt);
            }
            cursors.push(CursorSnapshot { chunk, page, exhausted });
        }

        let n_entries: usize = field(lines.next(), "entries")?
            .parse()
            .map_err(|_| AllocError::SnapshotCorrupt)?;
        let mut entries = Vec::with_capacity(n_entries.min(4096));
        for _ in 0..n_entries {
            let rest = field(lines.next(), "entry")?;
            let mut it = rest.split_ascii_whitespace();
            let old = parse_hex32(it.next())?;
            let to = GlobalAddr::from_raw(parse_hex32(it.next())?);
            let age_nanos: u64 = it
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or(AllocError::SnapshotCorrupt)?;
            let consumed = parse_flag(it.next())?;
            if it.next().is_some() {
                return Err(AllocError::SnapshotCorrupt);
            }
            entries.push(ForwardExport { old, to, age_nanos, consumed });
        }

        // Trailing records beyond the declared counts are corruption
        // too — the counts are part of the integrity contract.
        if lines.next().is_some() {
            return Err(AllocError::SnapshotCorrupt);
        }

        Ok(ServiceSnapshot { grace_nanos, cursors, entries })
    }

    /// Write the encoded snapshot to a file (restart handoff via disk).
    pub fn save(&self, path: &Path) -> Result<(), AllocError> {
        fs::write(path, self.encode()).map_err(|_| AllocError::SnapshotCorrupt)
    }

    /// Read and decode a snapshot file. An unreadable file is reported
    /// the same way as an unparsable one: the caller's only decision is
    /// "restore or start fresh", and both failure shapes mean the
    /// snapshot cannot be trusted.
    pub fn load(path: &Path) -> Result<ServiceSnapshot, AllocError> {
        let bytes = fs::read(path).map_err(|_| AllocError::SnapshotCorrupt)?;
        ServiceSnapshot::decode(&bytes)
    }
}

/// Strip `"<key> "` from the next line, or corrupt.
fn field<'a>(line: Option<&'a str>, key: &str) -> Result<&'a str, AllocError> {
    line.and_then(|l| l.strip_prefix(key))
        .and_then(|l| l.strip_prefix(' '))
        .ok_or(AllocError::SnapshotCorrupt)
}

fn parse_u32(tok: Option<&str>) -> Result<u32, AllocError> {
    tok.and_then(|v| v.parse().ok()).ok_or(AllocError::SnapshotCorrupt)
}

fn parse_hex32(tok: Option<&str>) -> Result<u32, AllocError> {
    tok.and_then(|v| u32::from_str_radix(v, 16).ok())
        .ok_or(AllocError::SnapshotCorrupt)
}

fn parse_flag(tok: Option<&str>) -> Result<bool, AllocError> {
    match tok {
        Some("0") => Ok(false),
        Some("1") => Ok(true),
        _ => Err(AllocError::SnapshotCorrupt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServiceSnapshot {
        ServiceSnapshot {
            grace_nanos: 5_000_000_000,
            cursors: vec![
                CursorSnapshot { chunk: 3, page: 17, exhausted: false },
                CursorSnapshot { chunk: 0, page: 0, exhausted: true },
            ],
            entries: vec![
                ForwardExport {
                    old: GlobalAddr::new(1, 0x40).raw(),
                    to: GlobalAddr::new(0, 0x2000),
                    age_nanos: 123_456,
                    consumed: false,
                },
                ForwardExport {
                    old: GlobalAddr::new(0, 0x80).raw(),
                    to: GlobalAddr::new(2, 0x100),
                    age_nanos: 9_999,
                    consumed: true,
                },
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let snap = sample();
        let decoded = ServiceSnapshot::decode(snap.encode().as_bytes()).unwrap();
        assert_eq!(decoded, snap);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let snap = ServiceSnapshot { grace_nanos: 0, cursors: vec![], entries: vec![] };
        let decoded = ServiceSnapshot::decode(snap.encode().as_bytes()).unwrap();
        assert_eq!(decoded, snap);
    }

    #[test]
    fn truncation_anywhere_is_rejected() {
        let full = sample().encode();
        // Chop the snapshot at every byte boundary: no prefix may
        // decode (the only valid input is the complete file).
        for cut in 0..full.len() {
            assert_eq!(
                ServiceSnapshot::decode(full[..cut].as_bytes()),
                Err(AllocError::SnapshotCorrupt),
                "prefix of {cut} bytes must be rejected"
            );
        }
    }

    #[test]
    fn bitflip_is_rejected() {
        let full = sample().encode();
        // Flip one character in the body (an entry's age digit).
        let corrupted = full.replacen("123456", "123457", 1);
        assert_ne!(corrupted, full);
        assert_eq!(
            ServiceSnapshot::decode(corrupted.as_bytes()),
            Err(AllocError::SnapshotCorrupt)
        );
    }

    #[test]
    fn version_mismatch_is_rejected_even_with_valid_checksum() {
        // A well-formed future-version snapshot: body re-checksummed so
        // only the version gate can reject it.
        let body = format!("{MAGIC} 2\ngrace 0\ncursors 0\nentries 0\n");
        let full = format!("{body}checksum {:016x}\n", super::fnv1a64(body.as_bytes()));
        assert_eq!(
            ServiceSnapshot::decode(full.as_bytes()),
            Err(AllocError::SnapshotCorrupt)
        );
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let mut full = sample().encode();
        full.push_str("entry 00000001 00000002 5 0\n");
        assert_eq!(
            ServiceSnapshot::decode(full.as_bytes()),
            Err(AllocError::SnapshotCorrupt)
        );
    }

    #[test]
    fn count_mismatch_is_rejected() {
        // Declare 3 cursors but provide 2: the entries header is then
        // consumed as a cursor line and parsing fails deterministically.
        let body = "OUROSNAP 1\ngrace 0\ncursors 3\ncursor 0 0 0\ncursor 1 1 0\nentries 0\n";
        let full = format!("{body}checksum {:016x}\n", super::fnv1a64(body.as_bytes()));
        assert_eq!(
            ServiceSnapshot::decode(full.as_bytes()),
            Err(AllocError::SnapshotCorrupt)
        );
    }

    #[test]
    fn garbage_and_non_utf8_are_rejected() {
        assert_eq!(
            ServiceSnapshot::decode(b"not a snapshot at all"),
            Err(AllocError::SnapshotCorrupt)
        );
        assert_eq!(
            ServiceSnapshot::decode(&[0xFF, 0xFE, 0x00, 0x42]),
            Err(AllocError::SnapshotCorrupt)
        );
        assert_eq!(ServiceSnapshot::decode(b""), Err(AllocError::SnapshotCorrupt));
    }

    #[test]
    fn file_save_load_roundtrip_and_missing_file() {
        let snap = sample();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ourosnap_test_{}.snap", std::process::id()));
        snap.save(&path).unwrap();
        assert_eq!(ServiceSnapshot::load(&path).unwrap(), snap);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(ServiceSnapshot::load(&path), Err(AllocError::SnapshotCorrupt));
    }
}
