//! L3 coordination: the paper's benchmark driver, timing statistics, and
//! the device-group allocation service — N simulated devices (each with
//! its own heap and per-size-class ticket lanes) behind a submit-time
//! placement router, driven through an async submit/poll ticket
//! pipeline — plus workload generators and the group-resilience layer.
//!
//! # Failover & rebalancing at a glance
//!
//! Group members move through `healthy → draining → retired` (see
//! [`rebalance`] for the full state machine and the drain protocol):
//!
//! * [`AllocService::drain_device`] migrates a member's live set onto
//!   the healthy rest of the group (payloads copied device-to-device
//!   via `Heap::clone_block`); stale frees of migrated addresses are
//!   forwarded to their new home exactly once within a configurable
//!   grace window, then rejected.
//! * [`AllocService::retire_device`] kills the member: every routing
//!   policy skips it, its queued tickets fail with the deterministic
//!   `AllocError::DeviceRetired`, and its worker threads are joined.
//! * [`RoutePolicy::CapacityAware`] places new allocations by heap
//!   occupancy with shed/readmit hysteresis, so a nearly-full member
//!   sheds load *before* it OOMs.
//!
//! The group is **self-healing**: a [`HealthMonitor`] watchdog scores
//! members from per-device heartbeats (dispatch progress vs. ring
//! occupancy, alloc error rates) and automatically runs the
//! drain→quiesce→retire sequence on a member that trips its
//! [`HealthPolicy`]; draining is **paced** ([`AllocService::drain_tick`]
//! migrates a few blocks per tick from a persistent cursor instead of a
//! stop-the-world sweep); and repaired members are taken back by
//! [`AllocService::readmit_device`] (`retired → readmitting → healthy`).
//!
//! [`driver::run_failover_trace`] drives a multi-client trace across a
//! group while draining and retiring a member mid-flight;
//! [`driver::run_selfheal_trace`] goes further — a member *stalls*
//! mid-churn and the watchdog detects, paced-drains, retires and
//! readmits it with no operator call. The chaos harnesses
//! `tests/failover.rs` / `tests/selfheal.rs` and the bench rows build
//! on them.

pub mod batcher;
pub mod driver;
pub mod federation;
pub mod lease;
pub mod rebalance;
pub mod ring;
pub mod router;
pub mod service;
pub mod snapshot;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{
    failover_quiesce_timeout, run_cached_trace, run_driver,
    run_failover_trace, run_federation_trace, run_group_trace,
    run_selfheal_trace, run_service_trace, DataPhase, DriverConfig,
    DriverReport, FailoverReport, FederationTraceReport, IterTiming,
    SelfhealReport, ServiceTraceReport,
};
pub use federation::{
    FederationClient, FederationEvent, FederationEventKind,
    FederationRouter, FederationSnapshot, FederationStats, GroupPressure,
};
pub use rebalance::{
    drain_quiesce_timeout, Clock, DrainPacing, DrainReport, DrainTick,
    FakeClock, ForwardExport, ForwardVerdict, ForwardingTable,
    HealthEvent, HealthEventKind, HealthMonitor, HealthPolicy,
    HealthVerdict, HealthWatchdog, MigrationRecord, ReadmitReport,
    RetireReport, SystemClock, DEFAULT_FORWARD_GRACE,
};
pub use ring::{Completion, Ticket};
pub use router::{CapacityHysteresis, DeviceState, RoutePolicy};
pub use service::{
    AllocService, Handoff, RetryPolicy, ServiceClient, ServiceStats,
};
pub use snapshot::{
    CursorSnapshot, ServiceSnapshot, SNAPSHOT_VERSION,
};
pub use stats::{
    DeviceSnapshot, LatencyHist, LatencyPercentiles, StatsSnapshot,
};
