//! L3 coordination: the paper's benchmark driver, timing statistics, and
//! the device-group allocation service — N simulated devices (each with
//! its own heap and per-size-class ticket lanes) behind a submit-time
//! placement router, driven through an async submit/poll ticket
//! pipeline — plus workload generators.

pub mod batcher;
pub mod driver;
pub mod ring;
pub mod router;
pub mod service;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{
    run_driver, run_group_trace, run_service_trace, DataPhase, DriverConfig,
    DriverReport, IterTiming, ServiceTraceReport,
};
pub use ring::{Completion, Ticket};
pub use router::RoutePolicy;
pub use service::{AllocService, ServiceClient, ServiceStats};
pub use stats::{DeviceSnapshot, StatsSnapshot};
