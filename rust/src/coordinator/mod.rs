//! L3 coordination: the paper's benchmark driver, timing statistics, the
//! allocation service (router + warp-shaped batcher) and workload
//! generators.

pub mod batcher;
pub mod driver;
pub mod service;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{run_driver, DataPhase, DriverConfig, DriverReport, IterTiming};
pub use service::{AllocService, ServiceClient};
