//! L3 coordination: the paper's benchmark driver, timing statistics, the
//! sharded allocation service (per-size-class request lanes over
//! warp-shaped batchers, driven through an async submit/poll ticket
//! pipeline) and workload generators.

pub mod batcher;
pub mod driver;
pub mod ring;
pub mod service;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{
    run_driver, run_service_trace, DataPhase, DriverConfig, DriverReport,
    IterTiming, ServiceTraceReport,
};
pub use ring::{Completion, Ticket};
pub use service::{AllocService, ServiceClient, ServiceStats};
