//! L3 coordination: the paper's benchmark driver, timing statistics, and
//! the device-group allocation service — N simulated devices (each with
//! its own heap and per-size-class ticket lanes) behind a submit-time
//! placement router, driven through an async submit/poll ticket
//! pipeline — plus workload generators and the group-resilience layer.
//!
//! # Failover & rebalancing at a glance
//!
//! Group members move through `healthy → draining → retired` (see
//! [`rebalance`] for the full state machine and the drain protocol):
//!
//! * [`AllocService::drain_device`] migrates a member's live set onto
//!   the healthy rest of the group (payloads copied device-to-device
//!   via `Heap::clone_block`); stale frees of migrated addresses are
//!   forwarded to their new home exactly once within a configurable
//!   grace window, then rejected.
//! * [`AllocService::retire_device`] kills the member: every routing
//!   policy skips it, its queued tickets fail with the deterministic
//!   `AllocError::DeviceRetired`, and its worker threads are joined.
//! * [`RoutePolicy::CapacityAware`] places new allocations by heap
//!   occupancy with shed/readmit hysteresis, so a nearly-full member
//!   sheds load *before* it OOMs.
//!
//! [`driver::run_failover_trace`] drives a multi-client trace across a
//! group while draining and retiring a member mid-flight — the chaos
//! harness `tests/failover.rs` and the failover bench rows build on it.

pub mod batcher;
pub mod driver;
pub mod rebalance;
pub mod ring;
pub mod router;
pub mod service;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{
    run_driver, run_failover_trace, run_group_trace, run_service_trace,
    DataPhase, DriverConfig, DriverReport, FailoverReport, IterTiming,
    ServiceTraceReport,
};
pub use rebalance::{
    DrainReport, ForwardVerdict, ForwardingTable, MigrationRecord,
    RetireReport, DEFAULT_FORWARD_GRACE,
};
pub use ring::{Completion, Ticket};
pub use router::{CapacityHysteresis, DeviceState, RoutePolicy};
pub use service::{AllocService, ServiceClient, ServiceStats};
pub use stats::{DeviceSnapshot, StatsSnapshot};
