//! L3 coordination: the paper's benchmark driver, timing statistics, the
//! sharded allocation service (per-size-class request lanes over
//! warp-shaped batchers) and workload generators.

pub mod batcher;
pub mod driver;
pub mod service;
pub mod stats;
pub mod workload;

pub use batcher::{BatchPolicy, Batcher};
pub use driver::{run_driver, DataPhase, DriverConfig, DriverReport, IterTiming};
pub use service::{AllocService, ServiceClient, ServiceStats};
