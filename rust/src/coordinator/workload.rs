//! Workload definitions: the paper's sweep axes plus trace generators for
//! the examples and ablations.

use crate::util::rng::Rng;

/// Allocation sizes for the figures' left panels ("as a function of
/// allocation size for 1024 allocations"): every power-of-two page size
/// plus the paper's 1000 B reference point.
pub fn paper_alloc_sizes() -> Vec<u32> {
    let mut v: Vec<u32> = (0..10).map(|i| 16u32 << i).collect();
    v.push(1000);
    v.sort_unstable();
    v
}

/// Thread counts for the right panels ("as a function of number of
/// simultaneous allocations for an allocation size of 1000 bytes").
pub fn paper_thread_counts() -> Vec<u32> {
    vec![1, 4, 16, 64, 256, 1024, 4096, 8192, 10000]
}

/// Trimmed sweeps for quick runs / CI.
pub fn quick_alloc_sizes() -> Vec<u32> {
    vec![16, 128, 1000, 8192]
}

pub fn quick_thread_counts() -> Vec<u32> {
    // Must straddle the acpp divergence onset (~1024 threads) so the
    // quick sweep still exhibits the paper's timeout pathology.
    vec![32, 1024, 4096]
}

/// A mixed-size allocation trace (the motivating §1 workloads: graph
/// algorithms / agent models churn many small, some large objects).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOp {
    /// Allocate `size` bytes; slot index identifies it for later free.
    Alloc { slot: usize, size: u32 },
    /// Free the allocation in `slot`.
    Free { slot: usize },
}

/// Generate a churn trace: `slots` live cells, `ops` operations, sizes
/// log-uniform in [16, max_size]. Every trailing live slot is freed at
/// the end, so a correct allocator returns to its initial state.
pub fn churn_trace(seed: u64, slots: usize, ops: usize, max_size: u32) -> Vec<TraceOp> {
    let mut rng = Rng::new(seed);
    let mut live = vec![false; slots];
    let mut out = Vec::with_capacity(ops + slots);
    for _ in 0..ops {
        let slot = rng.below(slots as u64) as usize;
        if live[slot] {
            out.push(TraceOp::Free { slot });
            live[slot] = false;
        } else {
            // Log-uniform size: pick a power-of-two class, then jitter.
            let classes = (max_size as f64 / 16.0).log2() as u64 + 1;
            let class = rng.below(classes);
            let base = 16u32 << class;
            let size = rng.range(base as u64 / 2 + 1, base as u64) as u32;
            out.push(TraceOp::Alloc { slot, size: size.min(max_size) });
            live[slot] = true;
        }
    }
    for (slot, l) in live.iter().enumerate() {
        if *l {
            out.push(TraceOp::Free { slot });
        }
    }
    out
}

/// A pipeline-friendly rolling trace: allocate into `slots` cells round
/// robin, freeing each cell's previous occupant just before reuse, so
/// exactly `slots` allocations stay live in steady state. An async
/// client at depth ≤ `slots` never stalls on its own unresolved allocs
/// (every freed address was allocated ≥ `slots` ops earlier), which
/// makes this the service-throughput benchmark's submission pattern.
pub fn rolling_trace(slots: usize, allocs: usize, size: u32) -> Vec<TraceOp> {
    assert!(slots > 0);
    let mut out = Vec::with_capacity(2 * allocs);
    for i in 0..allocs {
        let slot = i % slots;
        if i >= slots {
            out.push(TraceOp::Free { slot });
        }
        out.push(TraceOp::Alloc { slot, size });
    }
    // Drain the trailing live window so a correct allocator returns to
    // its initial state.
    for slot in 0..slots.min(allocs) {
        out.push(TraceOp::Free { slot });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sizes_cover_all_queues() {
        let s = paper_alloc_sizes();
        assert!(s.contains(&16) && s.contains(&8192) && s.contains(&1000));
        assert_eq!(s.len(), 11);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn thread_counts_include_paper_extremes() {
        let t = paper_thread_counts();
        assert_eq!(*t.first().unwrap(), 1);
        assert_eq!(*t.last().unwrap(), 10000);
    }

    #[test]
    fn churn_trace_is_balanced() {
        let tr = churn_trace(42, 64, 1000, 8192);
        let mut live = std::collections::HashSet::new();
        for op in &tr {
            match op {
                TraceOp::Alloc { slot, size } => {
                    assert!((1..=8192).contains(size));
                    assert!(live.insert(*slot), "double alloc in slot");
                }
                TraceOp::Free { slot } => {
                    assert!(live.remove(slot), "free of dead slot");
                }
            }
        }
        assert!(live.is_empty(), "trace must end balanced");
    }

    #[test]
    fn churn_trace_deterministic_per_seed() {
        assert_eq!(churn_trace(7, 16, 100, 1024), churn_trace(7, 16, 100, 1024));
        assert_ne!(churn_trace(7, 16, 100, 1024), churn_trace(8, 16, 100, 1024));
    }

    #[test]
    fn rolling_trace_is_balanced_and_bounded() {
        let tr = rolling_trace(8, 50, 1000);
        let mut live = std::collections::HashSet::new();
        let mut peak = 0usize;
        let (mut allocs, mut frees) = (0, 0);
        for op in &tr {
            match op {
                TraceOp::Alloc { slot, size } => {
                    assert_eq!(*size, 1000);
                    assert!(live.insert(*slot), "slot reused while live");
                    allocs += 1;
                }
                TraceOp::Free { slot } => {
                    assert!(live.remove(slot), "free of dead slot");
                    frees += 1;
                }
            }
            peak = peak.max(live.len());
        }
        assert!(live.is_empty(), "rolling trace must end balanced");
        assert_eq!(allocs, 50);
        assert_eq!(frees, 50);
        assert_eq!(peak, 8, "live set must plateau at `slots`");
    }

    #[test]
    fn rolling_trace_shorter_than_window() {
        // Fewer allocs than slots: everything allocates, then drains.
        let tr = rolling_trace(16, 4, 64);
        assert_eq!(tr.len(), 8);
        assert!(matches!(tr[4], TraceOp::Free { slot: 0 }));
    }
}
