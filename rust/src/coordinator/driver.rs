//! The paper's benchmark driver (§3 Methods).
//!
//! "The program iterates ten times through allocating memory, writing
//! some data, checking that the data is correct when read back and then
//! freeing the memory. The average time for performing the allocations
//! and frees is calculated" — plus the paper's modification: the average
//! over *all* iterations and over *subsequent* iterations are reported
//! separately to expose the SYCL JIT warm-up.
//!
//! Three data-phase modes:
//! * `Sim`  — lanes write/verify the pattern through the simulated device
//!   (the pure-simulator benchmark path used for the figures);
//! * `Xla`  — the data phase runs through the AOT-compiled Pallas
//!   `touch_verify` kernel via PJRT, and the rust side independently
//!   re-verifies checksums + heap read-back (the full-stack path used by
//!   examples/e2e_driver);
//! * `None` — queue-throughput measurements only.

use std::collections::VecDeque;
use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::errs::{Context, Result};

use crate::ouroboros::{
    allocator::{warp_free, warp_malloc},
    build_allocator, AllocError, DeviceAllocator, GlobalAddr, HeapConfig,
    Variant,
};
use crate::runtime::{pattern, Runtime};
use crate::simt::{Device, EventCounts, Grid};

use super::federation::{
    FederationEvent, FederationRouter, FederationSnapshot,
};
use super::rebalance::{
    DrainReport, HealthEvent, HealthEventKind, HealthPolicy, ReadmitReport,
    RetireReport, SystemClock,
};
use super::ring::{Completion, Ticket};
use super::snapshot::ServiceSnapshot;
use super::router::DeviceState;
use super::service::{AllocService, ServiceClient};
use super::stats::{jit_split, JitSplit};
use super::workload::TraceOp;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPhase {
    None,
    Sim,
    Xla,
}

#[derive(Debug, Clone)]
pub struct DriverConfig {
    pub variant: Variant,
    /// Bytes per allocation ("data size to be allocated").
    pub alloc_size: u32,
    /// Parallel allocations ("number of allocations to be allocated in
    /// parallel") — one device thread each.
    pub num_allocations: u32,
    /// Paper default: 10.
    pub iterations: usize,
    pub data_phase: DataPhase,
    pub heap: HeapConfig,
    pub seed: i32,
}

impl DriverConfig {
    pub fn paper_default(variant: Variant) -> Self {
        DriverConfig {
            variant,
            alloc_size: 1000,
            num_allocations: 1024,
            iterations: 10,
            data_phase: DataPhase::Sim,
            heap: HeapConfig::default(),
            seed: 0x5EED,
        }
    }
}

/// One iteration's timings (modeled device microseconds).
#[derive(Debug, Clone)]
pub struct IterTiming {
    /// Allocation phase; includes JIT warm-up on the first iteration.
    pub alloc_us: f64,
    /// Free phase; ditto.
    pub free_us: f64,
    /// Data phase (write+verify), whichever mode produced it.
    pub write_us: f64,
    pub verify_ok: bool,
    pub alloc_failures: u32,
    pub timed_out: bool,
    pub deadlocks: u64,
    pub events: EventCounts,
    pub host_wall_us: f64,
}

#[derive(Debug, Clone)]
pub struct DriverReport {
    pub variant: Variant,
    pub backend: &'static str,
    pub device: &'static str,
    pub alloc_size: u32,
    pub num_allocations: u32,
    pub iters: Vec<IterTiming>,
}

impl DriverReport {
    pub fn alloc_split(&self) -> JitSplit {
        jit_split(&self.iters.iter().map(|i| i.alloc_us).collect::<Vec<_>>())
    }

    pub fn free_split(&self) -> JitSplit {
        jit_split(&self.iters.iter().map(|i| i.free_us).collect::<Vec<_>>())
    }

    pub fn verify_ok(&self) -> bool {
        self.iters.iter().all(|i| i.verify_ok)
    }

    pub fn any_timeout(&self) -> bool {
        self.iters.iter().any(|i| i.timed_out)
    }

    pub fn total_deadlocks(&self) -> u64 {
        self.iters.iter().map(|i| i.deadlocks).sum()
    }

    /// Per-allocation mean subsequent alloc time — the y-axis of every
    /// figure in the paper.
    pub fn alloc_us_per_op_subsequent(&self) -> f64 {
        self.alloc_split().mean_subsequent / self.num_allocations as f64
    }
}

/// Outcome of driving a [`TraceOp`] workload through the allocation
/// service's async ticket pipeline.
#[derive(Debug, Clone)]
pub struct ServiceTraceReport {
    /// Ops actually submitted (a free whose alloc failed is skipped).
    pub submitted: u64,
    pub allocs: u64,
    pub frees: u64,
    /// Allocs that completed with an error (OOM under churn is
    /// tolerated, mirroring `run_driver`'s failure accounting).
    pub alloc_failures: u64,
    /// Ops that hit `AllocError::DeviceRetired` — in-flight on a lane a
    /// concurrent `retire_device` drained, or aimed at the dead member
    /// afterwards. Only tolerated (counted instead of aborting the
    /// trace) by [`run_failover_trace`]'s clients.
    pub retired_ops: u64,
    /// Deepest in-flight window the runner reached.
    pub max_inflight: usize,
    pub wall: Duration,
}

impl ServiceTraceReport {
    pub fn ops_per_sec(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.submitted as f64 / self.wall.as_secs_f64()
        }
    }

    /// Roll up the reports of concurrently-run clients: counters sum,
    /// wall is the max (the clients ran side by side, so the group is
    /// done when the slowest client is).
    pub fn merged(reports: &[ServiceTraceReport]) -> ServiceTraceReport {
        let mut out = ServiceTraceReport {
            submitted: 0,
            allocs: 0,
            frees: 0,
            alloc_failures: 0,
            retired_ops: 0,
            max_inflight: 0,
            wall: Duration::ZERO,
        };
        for r in reports {
            out.submitted += r.submitted;
            out.allocs += r.allocs;
            out.frees += r.frees;
            out.alloc_failures += r.alloc_failures;
            out.retired_ops += r.retired_ops;
            out.max_inflight = out.max_inflight.max(r.max_inflight);
            out.wall = out.wall.max(r.wall);
        }
        out
    }
}

/// Drive a trace through the service's **async** path at pipeline depth
/// `depth`: up to `depth` tickets stay in flight; the oldest is reaped
/// whenever the window is full. `depth = 1` degenerates to the blocking
/// path (submit + wait per op) and is the baseline the throughput bench
/// compares against. `depth` is clamped to [`ServiceClient::max_depth`]
/// — a single thread submitting a whole lane ring's worth of ops
/// without reaping would deadlock in the ring claim.
///
/// A `Free` whose allocation is still in flight forces an early reap of
/// that ticket (the address is needed to route the free); the rolling
/// traces from [`super::workload::rolling_trace`] are built so this only
/// happens when `depth` exceeds the trace's live window.
pub fn run_service_trace(
    client: &ServiceClient,
    trace: &[TraceOp],
    depth: usize,
) -> std::result::Result<ServiceTraceReport, AllocError> {
    run_trace_inner(client, trace, depth, false)
}

/// Drive a trace through the **blocking** path of a caching-enabled
/// client: cacheable classes serve out of leased spans with zero ring
/// traffic (see `super::lease`), so this is the cached-throughput
/// counterpart of [`run_service_trace`]'s pipelined ring baseline. The
/// client's cache is armed on entry and flushed (leases returned)
/// before the wall clock stops, so a clean trace conserves the global
/// live set. Alloc failures are tolerated and counted like
/// [`run_driver`]'s; ops hitting `AllocError::DeviceRetired` — a lease
/// recalled onto a member that then hard-retired mid-trace — are
/// counted in `retired_ops` and skipped, the same contract as the
/// failover runner.
pub fn run_cached_trace(
    client: &ServiceClient,
    trace: &[TraceOp],
) -> std::result::Result<ServiceTraceReport, AllocError> {
    client.set_caching(true);
    let nslots = trace
        .iter()
        .map(|op| match op {
            TraceOp::Alloc { slot, .. } | TraceOp::Free { slot } => *slot + 1,
        })
        .max()
        .unwrap_or(0);
    let mut addr: Vec<Option<GlobalAddr>> = vec![None; nslots];
    let mut rep = ServiceTraceReport {
        submitted: 0,
        allocs: 0,
        frees: 0,
        alloc_failures: 0,
        retired_ops: 0,
        max_inflight: 1,
        wall: Duration::ZERO,
    };
    let t0 = std::time::Instant::now();
    for op in trace {
        match *op {
            TraceOp::Alloc { slot, size } => {
                rep.allocs += 1;
                match client.alloc(size) {
                    Ok(a) => addr[slot] = Some(a),
                    Err(e) => {
                        rep.alloc_failures += 1;
                        if e == AllocError::DeviceRetired {
                            rep.retired_ops += 1;
                        }
                    }
                }
            }
            TraceOp::Free { slot } => {
                if let Some(a) = addr[slot].take() {
                    match client.free(a) {
                        Ok(()) => rep.frees += 1,
                        Err(AllocError::DeviceRetired) => {
                            rep.retired_ops += 1;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }
    client.flush_cache();
    rep.submitted = rep.allocs + rep.frees;
    rep.wall = t0.elapsed();
    Ok(rep)
}

/// The shared trace runner. With `tolerate_retired`, ops that hit
/// `AllocError::DeviceRetired` — in flight on a lane a concurrent
/// `retire_device` drained, or a free aimed at the dead member — are
/// counted in `retired_ops` and skipped instead of aborting the trace;
/// that is the contract a failover-surviving client needs.
fn run_trace_inner(
    client: &ServiceClient,
    trace: &[TraceOp],
    depth: usize,
    tolerate_retired: bool,
) -> std::result::Result<ServiceTraceReport, AllocError> {
    let depth = depth.clamp(1, client.max_depth());
    let nslots = trace
        .iter()
        .map(|op| match op {
            TraceOp::Alloc { slot, .. } | TraceOp::Free { slot } => *slot + 1,
        })
        .max()
        .unwrap_or(0);
    let mut addr: Vec<Option<GlobalAddr>> = vec![None; nslots];
    let mut rep = ServiceTraceReport {
        submitted: 0,
        allocs: 0,
        frees: 0,
        alloc_failures: 0,
        retired_ops: 0,
        max_inflight: 0,
        wall: Duration::ZERO,
    };
    // In-flight window: `Some(slot)` for allocs (the completion carries
    // the slot's address), `None` for frees.
    let mut inflight: VecDeque<(Option<usize>, Ticket)> = VecDeque::new();

    fn reap(
        client: &ServiceClient,
        addr: &mut [Option<GlobalAddr>],
        rep: &mut ServiceTraceReport,
        slot: Option<usize>,
        t: Ticket,
        tolerate_retired: bool,
    ) -> std::result::Result<(), AllocError> {
        match client.wait(t)? {
            Completion::Alloc(Ok(a)) => {
                addr[slot.expect("alloc ticket without a slot")] = Some(a);
            }
            Completion::Alloc(Err(e)) => {
                rep.alloc_failures += 1;
                if e == AllocError::DeviceRetired {
                    rep.retired_ops += 1;
                }
            }
            Completion::Free(Err(AllocError::DeviceRetired))
                if tolerate_retired =>
            {
                rep.retired_ops += 1;
            }
            Completion::Free(r) => r?,
        }
        Ok(())
    }

    let t0 = std::time::Instant::now();
    for op in trace {
        while inflight.len() >= depth {
            let (slot, t) = inflight.pop_front().unwrap();
            reap(client, &mut addr, &mut rep, slot, t, tolerate_retired)?;
        }
        match *op {
            TraceOp::Alloc { slot, size } => {
                let t = client.submit_alloc(size)?;
                inflight.push_back((Some(slot), t));
                rep.allocs += 1;
            }
            TraceOp::Free { slot } => {
                // Resolve the address, reaping in order until this
                // slot's alloc completes (or turns out to have failed).
                while addr[slot].is_none() {
                    match inflight.pop_front() {
                        Some((s, t)) => reap(
                            client,
                            &mut addr,
                            &mut rep,
                            s,
                            t,
                            tolerate_retired,
                        )?,
                        None => break,
                    }
                }
                if let Some(a) = addr[slot].take() {
                    match client.submit_free(a) {
                        Ok(t) => {
                            inflight.push_back((None, t));
                            rep.frees += 1;
                        }
                        Err(AllocError::DeviceRetired) if tolerate_retired => {
                            // The owner died unmigrated: the block is
                            // stranded on the retired member.
                            rep.retired_ops += 1;
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
        rep.max_inflight = rep.max_inflight.max(inflight.len());
    }
    while let Some((slot, t)) = inflight.pop_front() {
        reap(client, &mut addr, &mut rep, slot, t, tolerate_retired)?;
    }
    rep.submitted = rep.allocs + rep.frees;
    rep.wall = t0.elapsed();
    Ok(rep)
}

/// Drive `clients` concurrent handles of `svc` — each a fresh
/// [`AllocService::client`], so under `RoutePolicy::ClientAffinity`
/// they spread across the group's devices — through the same `trace`
/// at pipeline depth `depth`. This is the multi-device workload runner:
/// with a group service, allocations scatter over the devices per the
/// route policy while every free finds its way home via the address
/// tag. Returns one report per client (roll up with
/// [`ServiceTraceReport::merged`]).
///
/// The **aggregate** in-flight demand must fit one lane's ring: in the
/// worst case (single-class trace, one device) every client pipelines
/// into the same lane, and once `clients × depth` exceeds
/// [`AllocService::max_depth`] all clients can end up parked in the
/// ring claim with nobody left to reap — a deadlock. Rejected up front
/// with a panic rather than discovered as a hang.
pub fn run_group_trace(
    svc: &AllocService,
    clients: usize,
    trace: &[TraceOp],
    depth: usize,
) -> std::result::Result<Vec<ServiceTraceReport>, AllocError> {
    assert!(clients > 0, "need at least one client");
    let depth = depth.clamp(1, svc.max_depth());
    assert!(
        clients.saturating_mul(depth) <= svc.max_depth(),
        "aggregate pipeline depth {clients} clients x {depth} exceeds the \
         lane ring capacity {} — clients sharing one lane would deadlock \
         in the ring claim; lower the depth or raise BatchPolicy::ring_slots",
        svc.max_depth()
    );
    let results: Mutex<Vec<std::result::Result<ServiceTraceReport, AllocError>>> =
        Mutex::new(Vec::with_capacity(clients));
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = svc.client();
            let results = &results;
            s.spawn(move || {
                let r = run_service_trace(&c, trace, depth);
                results.lock().unwrap().push(r);
            });
        }
    });
    results.into_inner().unwrap().into_iter().collect()
}

/// Outcome of [`run_failover_trace`]: the surviving clients' trace
/// reports plus what the mid-trace failover did.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    /// One report per client (roll up with
    /// [`ServiceTraceReport::merged`]).
    pub reports: Vec<ServiceTraceReport>,
    /// The live-set migration performed by `drain_device`.
    pub drain: DrainReport,
    /// The lane teardown performed by `retire_device`.
    pub retire: RetireReport,
}

/// Drive `clients` concurrent handles through `trace` at pipeline depth
/// `depth` — exactly like [`run_group_trace`] — while a controller
/// kills group member `victim` mid-trace: once the service has
/// dispatched `after_ops` ops it calls `drain_device(victim)` (live-set
/// migration), waits for the victim's lanes to go quiet, then
/// `retire_device(victim)`. Clients run in failover-tolerant mode:
/// `DeviceRetired` outcomes are counted per client
/// (`ServiceTraceReport::retired_ops`) instead of aborting — in a
/// clean drain that count is zero, which is exactly what
/// `tests/failover.rs` asserts.
///
/// If the trace finishes before `after_ops` ops were dispatched, the
/// failover still runs (against the drained, idle group) so the report
/// is always complete.
pub fn run_failover_trace(
    svc: &AllocService,
    clients: usize,
    trace: &[TraceOp],
    depth: usize,
    victim: usize,
    after_ops: u64,
) -> std::result::Result<FailoverReport, AllocError> {
    assert!(clients > 0, "need at least one client");
    let depth = depth.clamp(1, svc.max_depth());
    assert!(
        clients.saturating_mul(depth) <= svc.max_depth(),
        "aggregate pipeline depth {clients} clients x {depth} exceeds the \
         lane ring capacity {}",
        svc.max_depth()
    );
    type FailoverOutcome =
        std::result::Result<(DrainReport, RetireReport), AllocError>;
    let results: Mutex<Vec<std::result::Result<ServiceTraceReport, AllocError>>> =
        Mutex::new(Vec::with_capacity(clients));
    let failover: Mutex<Option<FailoverOutcome>> = Mutex::new(None);
    let done_clients = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = svc.client();
            let results = &results;
            let done_clients = &done_clients;
            s.spawn(move || {
                let r = run_trace_inner(&c, trace, depth, true);
                results.lock().unwrap().push(r);
                // ordering: Release; pairs with the drain loop Acquire
                done_clients.fetch_add(1, Ordering::Release);
            });
        }
        let failover = &failover;
        let done_clients = &done_clients;
        s.spawn(move || {
            // Trip the failover mid-trace (or at the end, for traces
            // too short to reach the trigger).
            // ordering: stat progress poll; done_clients decides
            while svc.stats().ops.load(Ordering::Relaxed) < after_ops
                && done_clients.load(Ordering::Acquire) < clients
            {
                std::thread::sleep(Duration::from_micros(200));
            }
            let drain = match svc.drain_device(victim) {
                Ok(d) => d,
                Err(e) => {
                    *failover.lock().unwrap() = Some(Err(e));
                    return;
                }
            };
            // Let in-flight ops on the victim's lanes finish before the
            // kill, the way an operator would: drain, quiesce, retire.
            // Event-driven (the rings' condvar occupancy wait — no
            // 200 µs busy-poll burning a core on loaded CI) and bounded
            // — retire is safe regardless, stragglers just show up as
            // DeviceRetired counts.
            svc.wait_lanes_quiet(victim, failover_quiesce_timeout());
            let retire = svc.retire_device(victim);
            *failover.lock().unwrap() = Some(Ok((drain, retire)));
        });
    });
    let (drain, retire) = failover
        .into_inner()
        .unwrap()
        .expect("failover controller always reports")?;
    let reports: Vec<ServiceTraceReport> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .collect::<std::result::Result<_, _>>()?;
    Ok(FailoverReport { reports, drain, retire })
}

/// Ring-quiet deadline the failover / self-heal controllers allow
/// between draining a member and retiring it. Env-tunable
/// (`OURO_QUIESCE_MS`, default 250) so loaded CI can stretch it
/// without a rebuild; the wait itself is event-driven
/// ([`AllocService::wait_lanes_quiet`]), so an idle group pays nothing.
pub fn failover_quiesce_timeout() -> Duration {
    let ms = std::env::var("OURO_QUIESCE_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(250u64);
    Duration::from_millis(ms)
}

/// Outcome of [`run_selfheal_trace`]: the acceptance scenario — a
/// member stalls mid-churn and the service, with **no manual
/// `retire_device` call**, detects, paced-drains, retires and later
/// readmits it.
#[derive(Debug, Clone)]
pub struct SelfhealReport {
    /// Phase-1 reports (churn through the stall + watchdog heal), one
    /// per client; roll up with [`ServiceTraceReport::merged`].
    pub reports: Vec<ServiceTraceReport>,
    /// Phase-2 reports (churn after the readmit).
    pub post_reports: Vec<ServiceTraceReport>,
    /// Everything the watchdog did, timestamped on the monitor clock.
    pub events: Vec<HealthEvent>,
    /// The readmit that brought the victim back.
    pub readmit: ReadmitReport,
    /// Monitor-clock µs from stall injection to the watchdog finishing
    /// the retire — the automatic detect→drain→retire recovery time.
    pub recovery_us: f64,
    /// Allocations the readmitted member served during phase 2.
    pub readmitted_allocs: u64,
}

/// Drive `clients` concurrent tolerant handles through `trace` at
/// pipeline depth `depth` while member `victim` **stalls** mid-trace
/// (its lane workers wedge after `after_ops` dispatched ops, via the
/// stall-injection chaos hook) — and nobody calls `retire_device`: a
/// [`super::rebalance::HealthMonitor`] polled by the controller
/// detects the stall under `policy`, paced-drains the live set,
/// retires the member, and, once phase 1 completes (flushing every
/// stale address through the forwarding table), the member is
/// readmitted and a second trace phase runs over the healed group.
///
/// Errors propagate like [`run_group_trace`]; if the watchdog never
/// retires the victim the subsequent readmit reports
/// [`crate::ouroboros::AllocError::ReadmitRefused`].
pub fn run_selfheal_trace(
    svc: &AllocService,
    clients: usize,
    trace: &[TraceOp],
    depth: usize,
    victim: usize,
    after_ops: u64,
    policy: HealthPolicy,
) -> std::result::Result<SelfhealReport, AllocError> {
    assert!(clients > 0, "need at least one client");
    let depth = depth.clamp(1, svc.max_depth());
    assert!(
        clients.saturating_mul(depth) <= svc.max_depth(),
        "aggregate pipeline depth {clients} clients x {depth} exceeds the \
         lane ring capacity {}",
        svc.max_depth()
    );
    let monitor =
        svc.monitor_with_clock(policy.clone(), Arc::new(SystemClock::new()));
    let results: Mutex<Vec<std::result::Result<ServiceTraceReport, AllocError>>> =
        Mutex::new(Vec::with_capacity(clients));
    let injected_at: Mutex<Option<Duration>> = Mutex::new(None);
    let done_clients = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = svc.client();
            let results = &results;
            let done_clients = &done_clients;
            s.spawn(move || {
                let r = run_trace_inner(&c, trace, depth, true);
                results.lock().unwrap().push(r);
                // ordering: Release; pairs with the drain loop Acquire
                done_clients.fetch_add(1, Ordering::Release);
            });
        }
        let monitor = &monitor;
        let injected_at = &injected_at;
        let done_clients = &done_clients;
        s.spawn(move || {
            // Wedge the victim mid-churn (or at trace end for traces
            // too short to reach the trigger — the watchdog still runs
            // so the report is always complete).
            // ordering: stat progress poll; done_clients decides
            while svc.stats().ops.load(Ordering::Relaxed) < after_ops
                && done_clients.load(Ordering::Acquire) < clients
            {
                std::thread::sleep(Duration::from_micros(200));
            }
            svc.inject_stall(victim, true);
            *injected_at.lock().unwrap() = Some(monitor.now());
            // No manual retire: poll the health monitor until IT does
            // the drain→quiesce→retire. Hard wall bound so a policy
            // that never trips cannot hang the runner.
            let give_up = Instant::now() + Duration::from_secs(30);
            while svc.device_state(victim) != DeviceState::Retired
                && Instant::now() < give_up
            {
                monitor.poll_once(svc);
                std::thread::sleep(monitor.policy().tick);
            }
            svc.inject_stall(victim, false);
        });
    });
    let reports: Vec<ServiceTraceReport> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .collect::<std::result::Result<_, _>>()?;
    let injected = injected_at
        .into_inner()
        .unwrap()
        .expect("controller always injects");
    let recovery_us = monitor
        .events()
        .iter()
        .rev()
        .find_map(|e| match e.kind {
            HealthEventKind::Retired { .. } if e.device == victim => {
                Some(e.at.saturating_sub(injected).as_secs_f64() * 1e6)
            }
            _ => None,
        })
        .unwrap_or(0.0);
    // Phase 1 is fully drained: every stale name went through the
    // forwarding table, so the victim's heap is provably empty and the
    // readmit can re-mint its address window.
    let readmit = svc.readmit_device(victim)?;
    let allocs_before = svc.snapshot().devices[victim].allocs;
    let post = run_group_trace(svc, clients, trace, depth)?;
    let readmitted_allocs =
        svc.snapshot().devices[victim].allocs - allocs_before;
    Ok(SelfhealReport {
        reports,
        post_reports: post,
        events: monitor.events(),
        readmit,
        recovery_us,
        readmitted_allocs,
    })
}

/// Run the driver on `device`. `runtime` is required for `DataPhase::Xla`.
pub fn run_driver(
    device: &Device,
    cfg: &DriverConfig,
    runtime: Option<&Runtime>,
) -> Result<DriverReport> {
    device.reset_jit();
    let alloc = build_allocator(cfg.variant, &cfg.heap);
    let n = cfg.num_allocations;
    let addrs: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(u32::MAX)).collect();
    let mut iters = Vec::with_capacity(cfg.iterations);

    for iter in 0..cfg.iterations {
        let fails = AtomicU32::new(0);
        let seed = cfg.seed.wrapping_add(iter as i32);

        // ---- phase 1: allocate -------------------------------------------
        let alloc_ref = alloc.clone();
        let addrs_ref = &addrs;
        let fails_ref = &fails;
        let size = cfg.alloc_size;
        let st_alloc = device.launch("driver.malloc", Grid::new(n), move |w| {
            let lanes: Vec<u32> = w.active_lanes().collect();
            let sizes = vec![size; lanes.len()];
            let rs = warp_malloc(alloc_ref.as_ref(), w, &sizes);
            for (i, &lane) in lanes.iter().enumerate() {
                let tid = w.thread_id(lane) as usize;
                match rs[i] {
                    // ordering: Release; publish addr to the free pass
                    Ok(a) => addrs_ref[tid].store(a, Ordering::Release),
                    Err(_) => {
                        addrs_ref[tid].store(u32::MAX, Ordering::Release);
                        fails_ref.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                    }
                }
            }
        });

        // ---- phase 2: write + verify -------------------------------------
        let (write_us, verify_ok) = match cfg.data_phase {
            DataPhase::None => (0.0, true),
            DataPhase::Sim => data_phase_sim(device, &alloc, &addrs, size, seed),
            DataPhase::Xla => {
                let rt = runtime
                    .context("DataPhase::Xla requires a loaded Runtime")?;
                data_phase_xla(rt, &alloc, &addrs, size, seed)?
            }
        };

        // ---- phase 3: free -------------------------------------------------
        let alloc_ref = alloc.clone();
        let st_free = device.launch("driver.free", Grid::new(n), move |w| {
            let lanes: Vec<u32> = w.active_lanes().collect();
            let to_free: Vec<Option<u32>> = lanes
                .iter()
                .map(|&l| {
                    let a = addrs_ref[w.thread_id(l) as usize]
                        // ordering: AcqRel; claim slot + see the publish
                        .swap(u32::MAX, Ordering::AcqRel);
                    (a != u32::MAX).then_some(a)
                })
                .collect();
            for r in warp_free(alloc_ref.as_ref(), w, &to_free) {
                r.expect("driver free failed");
            }
        });

        let mut events = st_alloc.events;
        events.merge(&st_free.events);
        iters.push(IterTiming {
            alloc_us: st_alloc.device_us_with_jit,
            free_us: st_free.device_us_with_jit,
            write_us,
            verify_ok,
            // ordering: read after join; no concurrency left
            alloc_failures: fails.load(Ordering::Relaxed),
            timed_out: st_alloc.timed_out || st_free.timed_out,
            deadlocks: st_alloc.events.deadlocks + st_free.events.deadlocks,
            events,
            host_wall_us: st_alloc.host_wall_us + st_free.host_wall_us,
        });
    }

    Ok(DriverReport {
        variant: cfg.variant,
        backend: device.backend.id(),
        device: device.profile.name,
        alloc_size: cfg.alloc_size,
        num_allocations: n,
        iters,
    })
}

/// Outcome of [`run_federation_trace`]: the federation acceptance
/// scenario — spillover churn across groups with a whole-group
/// kill + snapshot-restore mid-trace, and an end-of-trace sweep that
/// proves no block was lost.
#[derive(Debug, Clone)]
pub struct FederationTraceReport {
    /// One report per federation client (blocking ops, so
    /// `max_inflight` is always 1); roll up with
    /// [`ServiceTraceReport::merged`].
    pub reports: Vec<ServiceTraceReport>,
    /// Federation counters at the end of the trace (spilled allocs,
    /// cross-group frees, restarts, …).
    pub fed_stats: FederationSnapshot,
    /// Spill / recovery / restart transitions, in order.
    pub events: Vec<FederationEvent>,
    /// Wall time of the mid-trace restart: teardown + forwarding/cursor
    /// snapshot + wire-format round-trip + rebuild, in µs. Traffic to
    /// the group blocks (does not fail) for this long.
    pub restart_us: u64,
    /// Blocks still live when the trace ended, freed by the closing
    /// sweep.
    pub leftover: u64,
    /// Sweep frees that failed — blocks the federation lost track of.
    /// Zero in a correct run, including across the restart.
    pub lost_blocks: u64,
}

/// One federation client's blocking walk of `trace`. Allocation
/// failures are tolerated and counted (the federation already water-
/// fills across groups before failing, so a failure here means the
/// whole federation was exhausted); a free hitting `DeviceRetired`
/// (hard-retired owner) is tolerated and counted as a retired op;
/// anything else is fatal. Returns the report plus every address still
/// live at the end.
fn run_federation_client(
    client: &super::federation::FederationClient,
    trace: &[TraceOp],
) -> std::result::Result<(ServiceTraceReport, Vec<GlobalAddr>), AllocError> {
    let nslots = trace
        .iter()
        .map(|op| match op {
            TraceOp::Alloc { slot, .. } | TraceOp::Free { slot } => *slot + 1,
        })
        .max()
        .unwrap_or(0);
    let mut addr: Vec<Option<GlobalAddr>> = vec![None; nslots];
    let mut rep = ServiceTraceReport {
        submitted: 0,
        allocs: 0,
        frees: 0,
        alloc_failures: 0,
        retired_ops: 0,
        max_inflight: 1,
        wall: Duration::ZERO,
    };
    let t0 = Instant::now();
    for op in trace {
        match *op {
            TraceOp::Alloc { slot, size } => {
                // An alloc into an occupied slot evicts the old block
                // first, so the walk conserves the live set exactly.
                if let Some(a) = addr[slot].take() {
                    rep.submitted += 1;
                    rep.frees += 1;
                    match client.free(a) {
                        Ok(()) => {}
                        Err(AllocError::DeviceRetired) => rep.retired_ops += 1,
                        Err(e) => return Err(e),
                    }
                }
                rep.submitted += 1;
                rep.allocs += 1;
                match client.alloc(size) {
                    Ok(a) => addr[slot] = Some(a),
                    Err(e) => {
                        rep.alloc_failures += 1;
                        if e == AllocError::DeviceRetired {
                            rep.retired_ops += 1;
                        }
                    }
                }
            }
            TraceOp::Free { slot } => {
                if let Some(a) = addr[slot].take() {
                    rep.submitted += 1;
                    rep.frees += 1;
                    match client.free(a) {
                        Ok(()) => {}
                        Err(AllocError::DeviceRetired) => rep.retired_ops += 1,
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }
    rep.wall = t0.elapsed();
    Ok((rep, addr.into_iter().flatten().collect()))
}

/// Drive `clients` concurrent federation handles (primaries assigned
/// round-robin across the groups) through `trace` — blocking ops, with
/// whole-group spillover and tag-routed cross-group frees — while a
/// controller **kills and restores group `victim` mid-trace**: once the
/// federation has served `after_ops` ops, the victim's service is torn
/// down through `prepare_handoff`, its durable snapshot round-tripped
/// through the `OUROSNAP` wire format (encode → decode → verify), and a
/// successor rebuilt over the *same heaps* via
/// [`AllocService::start_group_restored`]. Traffic to the victim blocks
/// at the slot lock for the duration (reported as `restart_us`); no op
/// fails because of the restart.
///
/// After the trace, every block still live is freed through the
/// federation; a sweep free that fails is a **lost block**
/// (`FederationTraceReport::lost_blocks` — zero in a correct run:
/// heaps, forwarding promises and group tags all survived the restart).
pub fn run_federation_trace(
    fed: &FederationRouter,
    clients: usize,
    trace: &[TraceOp],
    victim: usize,
    after_ops: u64,
) -> std::result::Result<FederationTraceReport, AllocError> {
    assert!(clients > 0, "need at least one client");
    assert!(victim < fed.group_count(), "victim group out of range");
    let results: Mutex<
        Vec<std::result::Result<(ServiceTraceReport, Vec<GlobalAddr>), AllocError>>,
    > = Mutex::new(Vec::with_capacity(clients));
    let restart: Mutex<Option<std::result::Result<u64, AllocError>>> =
        Mutex::new(None);
    let done_clients = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..clients {
            let c = fed.client();
            let results = &results;
            let done_clients = &done_clients;
            s.spawn(move || {
                let r = run_federation_client(&c, trace);
                results.lock().unwrap().push(r);
                // ordering: Release; pairs with the controller's Acquire
                done_clients.fetch_add(1, Ordering::Release);
            });
        }
        let restart = &restart;
        let done_clients = &done_clients;
        s.spawn(move || {
            // Trip the restart mid-trace (or at the end, for traces too
            // short to reach the trigger — the report stays complete).
            loop {
                let st = fed.stats();
                // ordering: Acquire pairs with the clients' Release adds
                if st.allocs + st.frees >= after_ops
                    || done_clients.load(Ordering::Acquire) >= clients
                {
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            let (route, policy) = match fed
                .with_group(victim, |svc| (svc.route_policy(), svc.batch_policy()))
            {
                Some(rp) => rp,
                None => {
                    *restart.lock().unwrap() =
                        Some(Err(AllocError::ServiceDown));
                    return;
                }
            };
            let t0 = Instant::now();
            let outcome = fed.restart_group(victim, move |handoff| {
                // Round-trip the durable state through the wire format
                // mid-trace: what a cross-process restart would read
                // back must be exactly what was captured.
                let decoded =
                    ServiceSnapshot::decode(handoff.snapshot.encode().as_bytes())?;
                if decoded != handoff.snapshot {
                    return Err(AllocError::SnapshotCorrupt);
                }
                AllocService::start_group_restored(
                    handoff.rebuild_members(),
                    policy,
                    route,
                    handoff,
                )
            });
            *restart.lock().unwrap() =
                Some(outcome.map(|()| t0.elapsed().as_micros() as u64));
        });
    });
    let restart_us = restart
        .into_inner()
        .unwrap()
        .expect("restart controller always reports")?;
    let mut reports = Vec::with_capacity(clients);
    let mut live: Vec<GlobalAddr> = Vec::new();
    for r in results.into_inner().unwrap() {
        let (rep, leftovers) = r?;
        reports.push(rep);
        live.extend(leftovers);
    }
    // Closing sweep: everything still live must free cleanly — through
    // group tags, across the restart, through restored forwarding.
    let sweeper = fed.client();
    let leftover = live.len() as u64;
    let mut lost_blocks = 0u64;
    for a in live {
        if sweeper.free(a).is_err() {
            lost_blocks += 1;
        }
    }
    Ok(FederationTraceReport {
        reports,
        fed_stats: fed.stats(),
        events: fed.events(),
        restart_us,
        leftover,
        lost_blocks,
    })
}

/// Simulated data phase: every lane writes its allocation's words through
/// the device and reads them back.
fn data_phase_sim(
    device: &Device,
    alloc: &Arc<dyn DeviceAllocator>,
    addrs: &[AtomicU32],
    size: u32,
    seed: i32,
) -> (f64, bool) {
    let n = addrs.len() as u32;
    let words = (size / 4).max(1);
    let ok = AtomicBool::new(true);
    let checksum_acc = AtomicU64::new(0);
    let heap = alloc.heap().clone();
    let st = device.launch("driver.touch", Grid::new(n), |w| {
        let _p = w.ctx.parallel_lanes(w.lane_count());
        for lane in w.active_lanes() {
            let tid = w.thread_id(lane) as usize;
            // ordering: Acquire; pairs with the alloc-pass publish
            let addr = addrs[tid].load(Ordering::Acquire);
            if addr == u32::MAX {
                continue;
            }
            let base = (addr / 4) as usize;
            // Write the pattern...
            for j in 0..words {
                let v = pattern::expected_word(addr as i32, j as i32, seed);
                heap.write_word(&w.ctx, base + j as usize, v as u32);
            }
            // ...and check it reads back correctly.
            let mut acc = 0i32;
            for j in 0..words {
                let got = heap.read_word(&w.ctx, base + j as usize) as i32;
                if got != pattern::expected_word(addr as i32, j as i32, seed) {
                    // ordering: monotonic false-latch; read after join
                    ok.store(false, Ordering::Relaxed);
                }
                acc = acc.wrapping_add(got);
            }
            if acc != pattern::expected_checksum(addr as i32, words, seed) {
                // ordering: monotonic false-latch; read after join
                ok.store(false, Ordering::Relaxed);
            }
            checksum_acc.fetch_add(acc as u32 as u64, Ordering::Relaxed);
        }
    });
    (st.device_us_with_jit, ok.load(Ordering::Relaxed)) // ordering: read after join
}

/// Full-stack data phase: the AOT Pallas kernel computes page images and
/// checksums through PJRT; rust writes the images into the heap, then
/// independently re-verifies both the checksums and the heap contents.
fn data_phase_xla(
    rt: &Runtime,
    alloc: &Arc<dyn DeviceAllocator>,
    addrs: &[AtomicU32],
    size: u32,
    seed: i32,
) -> Result<(f64, bool)> {
    let m = &rt.manifest;
    let batch = m.touch_pages as usize;
    let page_words = m.page_words as usize;
    let words = ((size / 4).max(1) as usize).min(page_words);
    let heap = alloc.heap();
    let live: Vec<i32> = addrs
        .iter()
        .map(|a| a.load(Ordering::Acquire)) // ordering: Acquire; pairs with the alloc-pass publish
        .filter(|&a| a != u32::MAX)
        .map(|a| a as i32)
        .collect();
    let mut ok = true;
    let t0 = std::time::Instant::now();
    // A throwaway ctx for the host-DMA heap writes (cycle costs of the
    // data phase are modeled by the Sim mode; this path measures the real
    // XLA execution).
    let b = crate::backend::Cuda::new();
    let ctx = crate::simt::DevCtx::new(&b, 1.0, u32::MAX);
    for chunk_of_pages in live.chunks(batch) {
        let mut offsets = vec![*chunk_of_pages.first().unwrap_or(&0); batch];
        offsets[..chunk_of_pages.len()].copy_from_slice(chunk_of_pages);
        let out = rt.workload_step(&offsets, seed)?;
        for (i, &off) in chunk_of_pages.iter().enumerate() {
            // Independent checksum verification (full page image).
            let want = pattern::expected_checksum(off, page_words as u32, seed);
            if out.checksums[i] != want
                || out.probe[i] != pattern::expected_word(off, 0, seed)
            {
                ok = false;
            }
            // DMA the page image into the heap, then read back a sample.
            let base = (off as u32 / 4) as usize;
            let row = &out.buf[i * page_words..(i + 1) * page_words];
            for j in 0..words {
                heap.write_word(&ctx, base + j, row[j] as u32);
            }
            for j in [0usize, words / 2, words - 1] {
                let got = heap.read_word(&ctx, base + j) as i32;
                if got != pattern::expected_word(off, j as i32, seed) {
                    ok = false;
                }
            }
        }
    }
    Ok((t0.elapsed().as_secs_f64() * 1e6, ok))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Acpp, Cuda, SyclOneapiNv};
    use crate::simt::DeviceProfile;
    use std::sync::Arc as StdArc;

    fn quick_cfg(variant: Variant) -> DriverConfig {
        DriverConfig {
            variant,
            alloc_size: 1000,
            num_allocations: 128,
            iterations: 3,
            data_phase: DataPhase::Sim,
            heap: HeapConfig::default(),
            seed: 1,
        }
    }

    #[test]
    fn driver_runs_all_variants_cuda() {
        let dev = Device::new(DeviceProfile::t2000(), StdArc::new(Cuda::new()));
        for v in Variant::all() {
            let rep = run_driver(&dev, &quick_cfg(v), None).unwrap();
            assert!(rep.verify_ok(), "{}: data verification failed", v.id());
            assert_eq!(rep.iters.len(), 3);
            assert!(rep.alloc_split().mean_subsequent > 0.0);
            assert_eq!(rep.iters[0].alloc_failures, 0, "{}", v.id());
        }
    }

    #[test]
    fn sycl_first_iteration_pays_jit() {
        let dev = Device::new(
            DeviceProfile::t2000(),
            StdArc::new(SyclOneapiNv::new()),
        );
        let rep = run_driver(&dev, &quick_cfg(Variant::Page), None).unwrap();
        let s = rep.alloc_split();
        // First iteration dominated by the SPIR-V->PTX JIT.
        assert!(s.first > 5.0 * s.mean_subsequent, "{s:?}");
        assert!(s.mean_all > s.mean_subsequent);
    }

    #[test]
    fn cuda_has_no_jit_gap() {
        let dev = Device::new(DeviceProfile::t2000(), StdArc::new(Cuda::new()));
        let rep = run_driver(&dev, &quick_cfg(Variant::Page), None).unwrap();
        let s = rep.alloc_split();
        assert!(s.first < 3.0 * s.mean_subsequent, "{s:?}");
    }

    #[test]
    fn acpp_times_out_under_contention() {
        let dev = Device::new(DeviceProfile::t2000(), StdArc::new(Acpp::new()));
        // Enough threads that growth rounds diverge some warp.
        let mut cfg = quick_cfg(Variant::Chunk);
        cfg.num_allocations = 2048;
        cfg.iterations = 2;
        let rep = run_driver(&dev, &cfg, None).unwrap();
        // The pathology must at least be *observable* at this scale
        // (deadlock events recorded), matching the paper's report.
        assert!(
            rep.total_deadlocks() > 0 || rep.any_timeout(),
            "expected acpp divergence pathology at 2048 threads"
        );
        // Correctness still holds (the simulator completes serially).
        assert!(rep.verify_ok());
    }

    #[test]
    fn data_none_skips_write() {
        let dev = Device::new(DeviceProfile::t2000(), StdArc::new(Cuda::new()));
        let mut cfg = quick_cfg(Variant::Page);
        cfg.data_phase = DataPhase::None;
        let rep = run_driver(&dev, &cfg, None).unwrap();
        assert!(rep.iters.iter().all(|i| i.write_us == 0.0));
    }

    fn trace_service(variant: Variant) -> crate::coordinator::AllocService {
        use crate::coordinator::batcher::BatchPolicy;
        let dev = Device::new(DeviceProfile::t2000(), StdArc::new(Cuda::new()));
        let alloc = build_allocator(variant, &HeapConfig::test_small());
        crate::coordinator::AllocService::start(
            dev,
            alloc,
            BatchPolicy::default(),
        )
    }

    #[test]
    fn service_trace_pipelined_drains_clean() {
        use crate::coordinator::workload::rolling_trace;
        let svc = trace_service(Variant::Page);
        let c = svc.client();
        let trace = rolling_trace(32, 200, 1000);
        let rep = run_service_trace(&c, &trace, 16).unwrap();
        assert_eq!(rep.allocs, 200);
        assert_eq!(rep.frees, 200);
        assert_eq!(rep.submitted, 400);
        assert_eq!(rep.alloc_failures, 0);
        assert!(rep.max_inflight >= 16, "window never filled");
        assert!(rep.ops_per_sec() > 0.0);
        let alloc = svc.allocator().clone();
        drop(svc);
        assert!(alloc.debug_consistent());
        assert_eq!(
            alloc.counters().mallocs.load(Ordering::Relaxed),
            alloc.counters().frees.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn service_trace_depth_one_is_blocking_equivalent() {
        use crate::coordinator::workload::rolling_trace;
        let svc = trace_service(Variant::Chunk);
        let c = svc.client();
        let trace = rolling_trace(8, 50, 256);
        let rep = run_service_trace(&c, &trace, 1).unwrap();
        assert_eq!(rep.allocs, 50);
        assert_eq!(rep.frees, 50);
        assert_eq!(rep.max_inflight, 1);
        let alloc = svc.allocator().clone();
        drop(svc);
        assert!(alloc.debug_consistent());
    }

    #[test]
    fn group_trace_spreads_over_devices_and_drains_clean() {
        use crate::coordinator::router::RoutePolicy;
        use crate::coordinator::service::AllocService;
        use crate::coordinator::workload::rolling_trace;
        use crate::ouroboros::HeapConfig;
        for route in RoutePolicy::all() {
            let svc = AllocService::start_named_group(
                &[("t2000", Variant::Page); 2],
                &HeapConfig::test_small(),
                crate::coordinator::batcher::BatchPolicy::default(),
                route,
                StdArc::new(Cuda::new()),
            );
            let trace = rolling_trace(16, 80, 1000);
            let reps = run_group_trace(&svc, 4, &trace, 8).unwrap();
            assert_eq!(reps.len(), 4, "{}", route.id());
            let agg = ServiceTraceReport::merged(&reps);
            assert_eq!(agg.allocs, 320, "{}", route.id());
            assert_eq!(agg.frees, 320, "{}", route.id());
            assert_eq!(agg.alloc_failures, 0, "{}", route.id());
            let snap = svc.snapshot();
            // Every policy must use both devices with 4 clients, and
            // frees must land on the device that served the alloc.
            for d in &snap.devices {
                assert!(d.allocs > 0, "{}: idle device {snap:?}", route.id());
                assert_eq!(d.allocs, d.frees, "{}: {snap:?}", route.id());
            }
            assert_eq!(
                snap.devices.iter().map(|d| d.allocs).sum::<u64>(),
                320,
                "{}",
                route.id()
            );
            let allocs = svc.allocators();
            drop(svc);
            for (i, a) in allocs.iter().enumerate() {
                assert!(a.debug_consistent(), "{}: device {i}", route.id());
                assert_eq!(
                    a.counters().mallocs.load(Ordering::Relaxed),
                    a.counters().frees.load(Ordering::Relaxed),
                    "{}: device {i} unbalanced",
                    route.id()
                );
            }
        }
    }

    #[test]
    fn service_trace_free_of_inflight_alloc_resolves() {
        // Depth exceeds the trace's live window, so every Free hits an
        // alloc that may still be in flight — the runner must reap it
        // first rather than submitting a free for an unknown address.
        use crate::coordinator::workload::rolling_trace;
        let svc = trace_service(Variant::Page);
        let c = svc.client();
        let trace = rolling_trace(4, 60, 128);
        let rep = run_service_trace(&c, &trace, 32).unwrap();
        assert_eq!(rep.allocs, 60);
        assert_eq!(rep.frees, 60);
        let alloc = svc.allocator().clone();
        drop(svc);
        assert!(alloc.debug_consistent());
    }
}
