//! Timing statistics matching the paper's §3 methodology: SYCL backends
//! JIT-compile on first launch, so the driver reports the mean over *all*
//! iterations and the mean over *subsequent* (all-but-first) iterations
//! separately — "a more apples-to-apples comparison".
//!
//! Also home to [`Gauge`], the pipeline-depth / ring-occupancy counter
//! the async ticket pipeline hangs off every lane ring.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonic high-water gauge: tracks a current level plus the maximum
/// level ever observed. The service's per-lane ticket rings use one to
/// report ring occupancy (in-flight ops), and the submit path samples
/// `current()` to accumulate the mean pipeline depth.
#[derive(Debug, Default)]
pub struct Gauge {
    cur: AtomicU64,
    high: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Gauge::default()
    }

    /// Raise the level by one; returns the new level.
    pub fn inc(&self) -> u64 {
        // ordering: occupancy gauge; stats-only role
        let v = self.cur.fetch_add(1, Ordering::Relaxed) + 1;
        self.high.fetch_max(v, Ordering::Relaxed);
        v
    }

    /// Lower the level by one.
    pub fn dec(&self) {
        self.cur.fetch_sub(1, Ordering::Relaxed); // ordering: occupancy gauge; stats-only role
    }

    pub fn current(&self) -> u64 {
        self.cur.load(Ordering::Relaxed) // ordering: occupancy gauge; stats-only role
    }

    /// Highest level ever reached.
    pub fn high_water(&self) -> u64 {
        self.high.load(Ordering::Relaxed) // ordering: occupancy gauge; stats-only role
    }
}

/// A lock-free log2-bucketed latency histogram: bucket `b` covers
/// `[2^(b-1), 2^b)` nanoseconds, so 64 buckets span any `u64` duration
/// with ≤ 2× quantisation error — plenty for p50/p99/p999 reporting
/// where the cached path and the ring path differ by orders of
/// magnitude. Recording is one relaxed `fetch_add` on the hot path.
#[derive(Debug)]
pub struct LatencyHist {
    buckets: [AtomicU64; 64],
}

impl Default for LatencyHist {
    fn default() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        LatencyHist { buckets: [ZERO; 64] }
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist::default()
    }

    #[inline]
    fn bucket(ns: u64) -> usize {
        // 0 ns -> bucket 0; [2^(b-1), 2^b) -> bucket b.
        (64 - ns.leading_zeros() as usize).min(63)
    }

    /// Record one operation's latency. Relaxed: histograms are a
    /// statistical rollup, not a synchronisation edge.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        // ordering: stat counter
        self.buckets[LatencyHist::bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        // ordering: stat counter
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// The latency (µs, bucket upper bound) at quantile `q` in `[0,1]`;
    /// 0 when empty.
    pub fn percentile_us(&self, q: f64) -> f64 {
        let counts: Vec<u64> =
            // ordering: stat counter
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if b == 0 { 0.0 } else { (2f64.powi(b as i32) - 1.0) / 1000.0 };
            }
        }
        f64::INFINITY
    }

    /// Plain copy for [`StatsSnapshot`].
    pub fn snapshot(&self) -> LatencyPercentiles {
        LatencyPercentiles {
            count: self.count(),
            p50_us: self.percentile_us(0.50),
            p99_us: self.percentile_us(0.99),
            p999_us: self.percentile_us(0.999),
        }
    }
}

/// A non-atomic percentile rollup of one [`LatencyHist`], embedded in
/// [`StatsSnapshot`] — the per-op latency view the bench reports next
/// to throughput (cached path vs ring path).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencyPercentiles {
    /// Operations recorded.
    pub count: u64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
}

/// Per-device rollup inside a [`StatsSnapshot`]: one group member's
/// share of the service traffic plus its modeled busy time, heap
/// occupancy gauge and failover lifecycle state.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSnapshot {
    /// Profile name of the simulated device (`quadro-t2000`, …).
    pub name: &'static str,
    pub batches: u64,
    pub ops: u64,
    pub allocs: u64,
    pub frees: u64,
    /// Alloc requests that completed with an error on this device —
    /// the health watchdog's error-rate numerator.
    pub alloc_errors: u64,
    /// Modeled device-busy time, microseconds (sum over this device's
    /// dispatched launches).
    pub device_us: f64,
    /// Heap occupancy in `[0, 1]` at snapshot time (live chunks over
    /// total) — the gauge `RoutePolicy::CapacityAware` routes by.
    pub heap_occupancy: f64,
    /// Failover lifecycle state id: `"healthy"`, `"draining"`,
    /// `"retired"` or `"readmitting"` (see the router's `DeviceState`).
    pub state: &'static str,
}

/// A plain (non-atomic) copy of the service counters, taken at one
/// instant, with the derived ratios precomputed — so benches and tests
/// read `snap.mean_batch` instead of hand-dividing raw atomics.
///
/// Not a consistent cut: individual counters are read with relaxed
/// loads while the service may still be running; quiesce first (drain
/// clients / shutdown) when exact cross-field invariants matter.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsSnapshot {
    pub batches: u64,
    pub ops: u64,
    pub allocs: u64,
    pub frees: u64,
    pub batched_ops: u64,
    pub invalid_frees: u64,
    pub submits: u64,
    /// Allocations moved between members by live-set migration
    /// (`AllocService::migrate` / `drain_device`).
    pub migrations: u64,
    /// Stale frees of migrated addresses rewritten through the
    /// forwarding table (each counted the one time it forwards).
    pub forwarded_frees: u64,
    /// In-flight ops failed with `DeviceRetired` when a member's lanes
    /// were drained by `retire_device`.
    pub retired_ops: u64,
    /// Members brought back through `AllocService::readmit_device`.
    pub readmits: u64,
    /// Blocking allocs transparently re-attempted by the client retry
    /// loop after a transient `DeviceRetired`.
    pub alloc_retries: u64,
    /// Lease spans minted for client caches (one ring alloc each).
    pub lease_mints: u64,
    /// Lease spans returned to their device (one ring free each).
    pub lease_returns: u64,
    /// Leases recalled by drain/retire before the owner released them.
    pub lease_recalls: u64,
    /// Allocations served from a client's local lease cache — zero
    /// ring traffic each.
    pub cached_allocs: u64,
    /// Frees absorbed by the lease registry (owner-local or delayed).
    pub cached_frees: u64,
    /// The cross-client subset of `cached_frees`: frees pushed onto a
    /// lease's delayed list for the owner to drain.
    pub delayed_frees: u64,
    /// Completion-side condvar broadcasts actually delivered by lane
    /// rings (eager notify, a registered blocking waiter, or the
    /// published `used_event` watermark crossed).
    pub wakeup_delivered: u64,
    /// Completion-side broadcasts skipped by the EVENT_IDX discipline:
    /// nobody was blocking and the reap index had not crossed the
    /// client-published watermark.
    pub wakeup_suppressed: u64,
    /// Submit-side doorbells rung into lane batchers (a worker was
    /// parked in phase 1, the fill crossed `avail_event`, or the
    /// batcher runs eager).
    pub doorbell_delivered: u64,
    /// Submit-side doorbells coalesced away while a worker was known
    /// to be mid-drain or already awake.
    pub doorbell_suppressed: u64,
    /// Per-op latency of the cached path (client-side serve).
    pub cached_latency: LatencyPercentiles,
    /// Per-op latency of the ring path (ticket claim → publish).
    pub ring_latency: LatencyPercentiles,
    /// Mean ops per dispatched device batch.
    pub mean_batch: f64,
    /// Mean lane-ring occupancy observed at submit time.
    pub mean_depth: f64,
    /// Per-lane dispatched batches, flat device-major lane order.
    pub lane_batches: Vec<u64>,
    /// Per-lane routed ops, flat device-major lane order.
    pub lane_ops: Vec<u64>,
    /// One rollup per device-group member.
    pub devices: Vec<DeviceSnapshot>,
}

impl StatsSnapshot {
    /// Modeled makespan of the group: the busiest device's modeled time
    /// (devices execute concurrently, so the group is done when the
    /// slowest member is). Members that never dispatched (a fresh
    /// group, or a member retired before its first dispatch) contribute
    /// zero and never poison the maximum.
    pub fn modeled_makespan_us(&self) -> f64 {
        self.devices
            .iter()
            .map(|d| d.device_us)
            .filter(|us| us.is_finite())
            .fold(0.0, f64::max)
    }

    /// Group throughput in the simulator's own time base: ops per
    /// modeled device-second. This is the scaling bench's figure of
    /// merit — host wall time measures the simulator, not the topology.
    ///
    /// Total by construction: a degenerate makespan (fresh group with
    /// zero dispatches, every member retired before first dispatch, or
    /// a non-finite per-device time) yields `0.0`, never `inf`/`NaN` —
    /// bench records and CI greps consume this number raw.
    pub fn modeled_ops_per_sec(&self) -> f64 {
        let makespan = self.modeled_makespan_us();
        if makespan <= 0.0 || !makespan.is_finite() {
            0.0
        } else {
            self.ops as f64 / makespan * 1e6
        }
    }
}

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation; 0 for < 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// The paper's all-vs-subsequent split over per-iteration timings, where
/// element 0 already includes any first-launch JIT cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JitSplit {
    pub mean_all: f64,
    pub mean_subsequent: f64,
    pub first: f64,
}

pub fn jit_split(samples: &[f64]) -> JitSplit {
    assert!(!samples.is_empty());
    JitSplit {
        mean_all: mean(samples),
        mean_subsequent: if samples.len() > 1 {
            mean(&samples[1..])
        } else {
            samples[0]
        },
        first: samples[0],
    }
}

/// Render per-lane counters (`ServiceStats::lane_batches` /
/// `lane_ops`) as a compact `lane0:… lane1:…` line, eliding idle
/// lanes. Labels are lane indices, not size classes — lane `i` only
/// coincides with class `i` when the service runs one lane per class
/// (`BatchPolicy { lanes: NUM_QUEUES, .. }`, the default).
pub fn render_lane_counts(counts: &[u64]) -> String {
    let mut parts: Vec<String> = counts
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(lane, c)| format!("lane{lane}:{c}"))
        .collect();
    if parts.is_empty() {
        parts.push("idle".into());
    }
    parts.join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        assert!((stddev(&[1.0, 3.0]) - std::f64::consts::SQRT_2).abs() < 1e-9);
    }

    #[test]
    fn jit_split_excludes_first_from_subsequent() {
        // First iteration dominated by JIT warmup, rest steady.
        let s = jit_split(&[100.0, 10.0, 10.0, 10.0]);
        assert_eq!(s.first, 100.0);
        assert_eq!(s.mean_subsequent, 10.0);
        assert_eq!(s.mean_all, 32.5);
        // The paper's observation: all-mean >> subsequent-mean for JIT
        // backends.
        assert!(s.mean_all > 3.0 * s.mean_subsequent);
    }

    #[test]
    fn jit_split_single_sample() {
        let s = jit_split(&[7.0]);
        assert_eq!(s.mean_all, 7.0);
        assert_eq!(s.mean_subsequent, 7.0);
    }

    fn dev(name: &'static str, ops: u64, us: f64) -> DeviceSnapshot {
        DeviceSnapshot {
            name,
            batches: 1,
            ops,
            allocs: ops,
            frees: 0,
            alloc_errors: 0,
            device_us: us,
            heap_occupancy: 0.0,
            state: "healthy",
        }
    }

    fn snap_with(ops: u64, devices: Vec<DeviceSnapshot>) -> StatsSnapshot {
        StatsSnapshot {
            batches: 2,
            ops,
            allocs: ops,
            frees: 0,
            batched_ops: ops,
            invalid_frees: 0,
            submits: ops,
            migrations: 0,
            forwarded_frees: 0,
            retired_ops: 0,
            readmits: 0,
            alloc_retries: 0,
            lease_mints: 0,
            lease_returns: 0,
            lease_recalls: 0,
            cached_allocs: 0,
            cached_frees: 0,
            delayed_frees: 0,
            wakeup_delivered: 0,
            wakeup_suppressed: 0,
            doorbell_delivered: 0,
            doorbell_suppressed: 0,
            cached_latency: LatencyPercentiles::default(),
            ring_latency: LatencyPercentiles::default(),
            mean_batch: 0.0,
            mean_depth: 0.0,
            lane_batches: vec![],
            lane_ops: vec![],
            devices,
        }
    }

    #[test]
    fn snapshot_modeled_throughput_uses_makespan() {
        let snap =
            snap_with(300, vec![dev("a", 100, 50.0), dev("b", 200, 200.0)]);
        assert_eq!(snap.modeled_makespan_us(), 200.0);
        // 300 ops over the 200 µs makespan -> 1.5 M ops/s.
        assert!((snap.modeled_ops_per_sec() - 1.5e6).abs() < 1.0);
    }

    #[test]
    fn empty_snapshot_throughput_is_zero() {
        let snap = snap_with(0, vec![]);
        assert_eq!(snap.modeled_ops_per_sec(), 0.0);
    }

    /// Regression: a group with traffic counted but a degenerate
    /// makespan (fresh members, a member retired before its first
    /// dispatch, or a poisoned per-device time) must report 0 modeled
    /// ops/s — never `inf`/`NaN`, which would flow raw into BENCH json.
    #[test]
    fn degenerate_makespan_reports_zero_not_inf() {
        // Ops recorded (e.g. failed at submit accounting) but no device
        // ever dispatched: makespan 0 with a non-zero numerator.
        let fresh = snap_with(64, vec![dev("a", 0, 0.0), dev("b", 0, 0.0)]);
        assert_eq!(fresh.modeled_makespan_us(), 0.0);
        assert_eq!(fresh.modeled_ops_per_sec(), 0.0);
        assert!(fresh.modeled_ops_per_sec().is_finite());

        // A member retired before first dispatch next to a live one:
        // the idle member must not drag the makespan to a degenerate
        // value, and the result stays finite.
        let mut retired = dev("dead", 0, 0.0);
        retired.state = "retired";
        let mixed = snap_with(100, vec![retired, dev("b", 100, 50.0)]);
        assert_eq!(mixed.modeled_makespan_us(), 50.0);
        assert!((mixed.modeled_ops_per_sec() - 2.0e6).abs() < 1.0);

        // Poisoned per-device time is filtered, not propagated.
        let poisoned = snap_with(
            10,
            vec![dev("nan", 0, f64::NAN), dev("inf", 0, f64::INFINITY)],
        );
        assert_eq!(poisoned.modeled_ops_per_sec(), 0.0);
        assert!(poisoned.modeled_ops_per_sec().is_finite());
    }

    #[test]
    fn lane_counts_render_elides_idle() {
        assert_eq!(render_lane_counts(&[0, 3, 0, 7]), "lane1:3 lane3:7");
        assert_eq!(render_lane_counts(&[0, 0]), "idle");
    }

    #[test]
    fn latency_hist_buckets_are_log2() {
        let h = LatencyHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_us(0.99), 0.0, "empty hist reports zero");
        // 100 fast ops at ~1 µs, one slow op at ~1 ms.
        for _ in 0..100 {
            h.record_ns(1_000);
        }
        h.record_ns(1_000_000);
        assert_eq!(h.count(), 101);
        let p50 = h.percentile_us(0.50);
        let p999 = h.percentile_us(0.999);
        // Bucket upper bounds: ~2.05 µs for the fast mass, ~2.1 ms for
        // the tail — log2 quantisation keeps each within 2x.
        assert!(p50 >= 1.0 && p50 < 4.0, "p50 {p50}");
        assert!(p999 >= 1_000.0 && p999 < 4_000.0, "p999 {p999}");
        assert!(h.percentile_us(0.0) <= p50);
        let snap = h.snapshot();
        assert_eq!(snap.count, 101);
        assert!(snap.p50_us <= snap.p99_us && snap.p99_us <= snap.p999_us);
    }

    #[test]
    fn latency_hist_zero_and_max_dont_overflow() {
        let h = LatencyHist::new();
        h.record_ns(0);
        assert_eq!(h.percentile_us(1.0), 0.0, "0 ns lands in bucket 0");
        h.record_ns(u64::MAX);
        let p = h.percentile_us(1.0);
        assert!(p.is_finite() && p > 0.0, "max duration stays finite: {p}");
    }

    #[test]
    fn gauge_tracks_high_water() {
        let g = Gauge::new();
        assert_eq!(g.current(), 0);
        assert_eq!(g.inc(), 1);
        assert_eq!(g.inc(), 2);
        g.dec();
        assert_eq!(g.current(), 1);
        assert_eq!(g.high_water(), 2);
        g.inc();
        g.inc();
        assert_eq!(g.high_water(), 3);
    }

    #[test]
    fn gauge_high_water_survives_drain() {
        let g = Gauge::new();
        for _ in 0..5 {
            g.inc();
        }
        for _ in 0..5 {
            g.dec();
        }
        assert_eq!(g.current(), 0);
        assert_eq!(g.high_water(), 5);
    }
}
