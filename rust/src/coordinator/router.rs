//! Placement routing for the device-group topology, including member
//! health (the failover state machine) and capacity-aware placement.
//!
//! The allocation service owns a *group* of simulated devices — possibly
//! heterogeneous (a `t2000` next to an `iris_xe`), each with its own
//! heap and its own full set of per-size-class ticket lanes. Placement
//! is decided **once, at submit time, for allocations only**:
//!
//! * **Allocs** are free to land anywhere — the router picks the device
//!   under the configured [`RoutePolicy`], and the completed address
//!   comes back device-tagged
//!   ([`crate::ouroboros::GlobalAddr`], device id in the high bits).
//! * **Frees** are *never* routed by policy: the address's device tag
//!   names the owning device, and the free travels to that device's
//!   lane regardless of which client handle submitted it or what policy
//!   placed the allocation. This is what makes cross-client,
//!   cross-device frees safe — a handle with affinity for device B can
//!   free memory living on device A and the op still reaches A's heap.
//!
//! # Member health
//!
//! Every member carries a [`DeviceState`]:
//!
//! ```text
//! Healthy ──mark_draining──▶ Draining ──mark_retired──▶ Retired
//!    ▲  └────────────mark_retired (hard kill)──────────────┘
//!    │                                                      │
//!    └── finish_readmit ──── Readmitting ◀── mark_readmitting
//! ```
//!
//! * **Healthy** — placeable; allocs and frees flow normally.
//! * **Draining** — *every* policy skips the member for new allocs, but
//!   frees (and the live-set migration built on them) still reach its
//!   heap. This is the window `AllocService::drain_device` migrates the
//!   live set in.
//! * **Retired** — dead. No placement, and the service rejects frees
//!   aimed at it with `AllocError::DeviceRetired` (after consulting the
//!   migration forwarding table). No longer terminal: a repaired member
//!   can be brought back through `AllocService::readmit_device`.
//! * **Readmitting** — the transient readmit window: lanes and workers
//!   are being rebuilt, the heap has been asserted empty. Not placeable
//!   and frees are still rejected (any address tagged for the member
//!   predates its retirement); the member only rejoins service when
//!   `finish_readmit` flips it Healthy. Under `CapacityAware` it
//!   re-enters *shedding* — the first occupancy probe readmits it once
//!   the gauge proves the heap really is empty.
//!
//! Policies (the Intel SHMEM / SYCL-portability placement shapes, host
//! side):
//!
//! * [`RoutePolicy::RoundRobin`] — a shared counter spreads successive
//!   allocations evenly; the balanced default, and the scaling bench's
//!   configuration.
//! * [`RoutePolicy::LeastLoaded`] — pick the device whose target
//!   size-class lane has the lowest **live ring occupancy** (in-flight
//!   ops, the submit-time backpressure signal). Adapts to skew: a
//!   device bogged down in a deep pipeline stops receiving new work.
//! * [`RoutePolicy::ClientAffinity`] — each client handle is pinned to
//!   one device (assigned round-robin at handle creation), giving
//!   per-client locality: one client's working set stays on one heap,
//!   which is the NUMA-ish shape a real multi-GPU deployment wants.
//!   When the pinned device is not healthy the handle falls forward to
//!   the next healthy member (rotating from its affinity), so a drained
//!   device never strands its clients.
//! * [`RoutePolicy::CapacityAware`] — route by per-heap **occupancy**
//!   (`Heap::occupancy`, live chunks over total) with hysteresis: a
//!   member whose heap rises past [`CapacityHysteresis::shed_above`]
//!   stops receiving allocs (it *sheds* load **before** it OOMs, not
//!   after) and is readmitted only once churn pulls it back under
//!   [`CapacityHysteresis::readmit_below`] — the gap prevents flapping
//!   at the threshold. Among non-shedding members the lowest-occupancy
//!   heap wins (coarse-quantised so near-ties rotate instead of piling
//!   onto one member); when every member is shedding the router
//!   water-fills by raw occupancy rather than refusing service.
//!
//! The router sits on the submit hot path in front of every lane: one
//! relaxed counter, one atomic state per member, and (for
//! `CapacityAware` only) one occupancy probe per member per alloc.

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};

/// Placement policy for new allocations across a device group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Spread successive allocations evenly via a shared counter.
    RoundRobin,
    /// Send each allocation to the device whose target-class lane has
    /// the lowest live ring occupancy (in-flight ops).
    LeastLoaded,
    /// Pin every client handle to one device (assigned round-robin at
    /// handle creation); all of a handle's allocations land there.
    ClientAffinity,
    /// Route by per-heap occupancy with shed/readmit hysteresis, so a
    /// nearly-full member stops receiving load before it OOMs.
    CapacityAware,
}

impl RoutePolicy {
    /// Every policy, for sweep-style tests and benches.
    pub fn all() -> [RoutePolicy; 4] {
        [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::ClientAffinity,
            RoutePolicy::CapacityAware,
        ]
    }

    /// Stable id for logs and bench records.
    pub fn id(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::ClientAffinity => "client-affinity",
            RoutePolicy::CapacityAware => "capacity-aware",
        }
    }
}

/// Lifecycle state of one device-group member (see the module docs for
/// the transition diagram).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceState {
    /// Placeable; serving allocs and frees.
    Healthy,
    /// Skipped by every placement policy; frees and migration still
    /// reach its heap.
    Draining,
    /// Dead: nothing is routed to it until it is readmitted.
    Retired,
    /// Being brought back: lanes rebuilding, heap asserted empty. Not
    /// placeable yet; frees still rejected.
    Readmitting,
}

impl DeviceState {
    /// Stable id for logs, snapshots and bench records.
    pub fn id(&self) -> &'static str {
        match self {
            DeviceState::Healthy => "healthy",
            DeviceState::Draining => "draining",
            DeviceState::Retired => "retired",
            DeviceState::Readmitting => "readmitting",
        }
    }
}

const STATE_HEALTHY: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_RETIRED: u8 = 2;
const STATE_READMITTING: u8 = 3;

/// Shed/readmit thresholds for [`RoutePolicy::CapacityAware`]. The gap
/// between the two is the hysteresis band: a member sheds when its heap
/// occupancy rises past `shed_above` and is only readmitted once it
/// falls below `readmit_below`, so occupancy noise at one threshold
/// cannot flap the placement decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityHysteresis {
    /// Occupancy at or above which a member stops receiving allocs.
    pub shed_above: f64,
    /// Occupancy below which a shedding member is readmitted.
    pub readmit_below: f64,
}

impl Default for CapacityHysteresis {
    fn default() -> Self {
        CapacityHysteresis { shed_above: 0.85, readmit_below: 0.70 }
    }
}

/// Occupancy quantisation for the capacity-aware minimum: members whose
/// heaps are within 1/64th of each other count as tied, and ties rotate
/// with the shared cursor instead of piling onto the lowest index.
const CAPACITY_BUCKETS: f64 = 64.0;

/// Submit-time placement engine: one per service, shared by every
/// client handle. Also the authority on member health — the service
/// consults `state()` on the free path and flips members through
/// `mark_draining` / `mark_retired` during failover.
#[derive(Debug)]
pub(crate) struct Router {
    policy: RoutePolicy,
    /// Round-robin cursor (relaxed: exact fairness under races doesn't
    /// matter, long-run balance does).
    rr: AtomicUsize,
    /// Per-member [`DeviceState`] discriminants. SeqCst: the drain
    /// quiesce protocol relies on a total order between the draining
    /// mark and the in-flight-alloc gauge (see `service.rs`).
    states: Vec<AtomicU8>,
    /// Capacity-aware shed latches (true = currently shedding).
    shedding: Vec<AtomicU8>,
    hysteresis: CapacityHysteresis,
    /// Per-member lease epoch — the client-visible recall signal for
    /// the lease cache (`coordinator/lease.rs`). Bumped whenever a
    /// member leaves placement (fresh drain, hard retire): a caching
    /// client re-checks the epoch under its serve pin and stops serving
    /// from any span minted under an older epoch, so drain/retire never
    /// races a cached allocation out of a span being recalled.
    lease_epochs: Vec<AtomicU64>,
}

impl Router {
    pub fn new(policy: RoutePolicy, devices: usize) -> Self {
        Router::with_hysteresis(policy, devices, CapacityHysteresis::default())
    }

    pub fn with_hysteresis(
        policy: RoutePolicy,
        devices: usize,
        hysteresis: CapacityHysteresis,
    ) -> Self {
        assert!(devices > 0);
        assert!(hysteresis.readmit_below <= hysteresis.shed_above);
        Router {
            policy,
            rr: AtomicUsize::new(0),
            states: (0..devices).map(|_| AtomicU8::new(STATE_HEALTHY)).collect(),
            shedding: (0..devices).map(|_| AtomicU8::new(0)).collect(),
            hysteresis,
            lease_epochs: (0..devices).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Current lease epoch of `device`. A caching client snapshots this
    /// when it mints a span there and re-checks it (under its serve
    /// pin) before every cached allocation; a mismatch means the member
    /// left placement since the mint and the span must be surrendered.
    pub fn lease_epoch(&self, device: usize) -> u64 {
        // ordering: SeqCst recall signal; pairs with the lease serve pin
        self.lease_epochs[device].load(Ordering::SeqCst)
    }

    /// Invalidate every lease minted on `device`: called on the fresh
    /// drain transition and on hard retire, *before* the live set is
    /// enumerated, so any cached serve racing the recall either
    /// completes before the bump or observes it and backs out.
    pub fn bump_lease_epoch(&self, device: usize) {
        // ordering: SeqCst recall signal; pairs with the lease serve pin
        self.lease_epochs[device].fetch_add(1, Ordering::SeqCst);
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// The capacity-aware shed/readmit thresholds this router was built
    /// with — the federation tier evaluates group saturation against
    /// the same bands its members shed by.
    pub fn hysteresis(&self) -> CapacityHysteresis {
        self.hysteresis
    }

    pub fn state(&self, device: usize) -> DeviceState {
        // ordering: SeqCst state lattice; pairs with in-flight gauge
        match self.states[device].load(Ordering::SeqCst) {
            STATE_HEALTHY => DeviceState::Healthy,
            STATE_DRAINING => DeviceState::Draining,
            STATE_READMITTING => DeviceState::Readmitting,
            _ => DeviceState::Retired,
        }
    }

    /// Healthy → Draining. Returns `false` (and changes nothing) if the
    /// member is retired or readmitting; marking an already-draining
    /// member is a no-op returning `true`.
    pub fn mark_draining(&self, device: usize) -> bool {
        self.begin_draining(device).is_some()
    }

    /// Healthy → Draining, reporting whether this call made the
    /// transition: `Some(true)` for a fresh drain (the caller should
    /// reset its migration cursor), `Some(false)` for a member already
    /// draining (resume), `None` for a retired or readmitting member.
    pub fn begin_draining(&self, device: usize) -> Option<bool> {
        let s = &self.states[device];
        if s.compare_exchange(
            STATE_HEALTHY,
            STATE_DRAINING,
            Ordering::SeqCst, // ordering: SeqCst state lattice; pairs with in-flight gauge
            Ordering::SeqCst,
        )
        .is_ok()
        {
            // A fresh drain is a recall of every lease on the member:
            // invalidate them before the drainer enumerates the live
            // set (lease spans are live blocks it will migrate).
            self.bump_lease_epoch(device);
            Some(true)
        // ordering: SeqCst state lattice; pairs with in-flight gauge
        } else if s.load(Ordering::SeqCst) == STATE_DRAINING {
            Some(false)
        } else {
            None
        }
    }

    /// Hard-kill transition; valid from any state. Reversible only via
    /// the readmit pair below.
    pub fn mark_retired(&self, device: usize) {
        // ordering: SeqCst state lattice; pairs with in-flight gauge
        self.states[device].store(STATE_RETIRED, Ordering::SeqCst);
        // Hard kill recalls leases too — a retire that skipped the
        // drain must still stop cached serves from the dead member.
        self.bump_lease_epoch(device);
    }

    /// Retired → Readmitting. `false` (nothing changes) from any other
    /// state — double readmits and readmit-while-draining are refused
    /// here.
    pub fn mark_readmitting(&self, device: usize) -> bool {
        self.states[device]
            .compare_exchange(
                STATE_RETIRED,
                STATE_READMITTING,
                Ordering::SeqCst, // ordering: SeqCst state lattice; pairs with in-flight gauge
                Ordering::SeqCst,
            )
            .is_ok()
    }

    /// Readmitting → Healthy. The member re-enters `CapacityAware`
    /// placement *shedding*: it only starts taking capacity-routed load
    /// once an occupancy probe proves the heap low — "trust the gauge,
    /// not the readmit". Other policies route to it immediately.
    pub fn finish_readmit(&self, device: usize) -> bool {
        // ordering: advisory shed hint; staleness tolerated
        self.shedding[device].store(1, Ordering::Relaxed);
        self.states[device]
            .compare_exchange(
                STATE_READMITTING,
                STATE_HEALTHY,
                Ordering::SeqCst, // ordering: SeqCst state lattice; pairs with in-flight gauge
                Ordering::SeqCst,
            )
            .is_ok()
    }

    fn placeable(&self, device: usize) -> bool {
        // ordering: SeqCst state lattice; pairs with in-flight gauge
        self.states[device].load(Ordering::SeqCst) == STATE_HEALTHY
    }

    /// Members currently accepting placements.
    pub fn healthy_count(&self) -> usize {
        (0..self.states.len()).filter(|&d| self.placeable(d)).count()
    }

    /// Pick the device for a fresh allocation, or `None` when no member
    /// is healthy. `ring_occupancy(d)` reports the live ring occupancy
    /// of the target size-class lane on device `d` (consulted by
    /// [`RoutePolicy::LeastLoaded`]); `heap_occupancy(d)` reports the
    /// heap occupancy gauge (consulted by
    /// [`RoutePolicy::CapacityAware`]). Ties rotate with the shared
    /// cursor rather than piling onto device 0 — blocking clients reap
    /// every op before the next submit, so they probe all-zero
    /// occupancy on every call and a fixed tie-break would silently
    /// degrade the policy to single-device. Frees never come through
    /// here — they follow their address's device tag.
    pub fn route_alloc<F, G>(
        &self,
        affinity: usize,
        ring_occupancy: F,
        heap_occupancy: G,
    ) -> Option<usize>
    where
        F: Fn(usize) -> u64,
        G: Fn(usize) -> f64,
    {
        let n = self.states.len();
        match self.policy {
            RoutePolicy::RoundRobin => {
                // ordering: round-robin ticket; uniqueness only
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                (0..n).map(|i| (start + i) % n).find(|&d| self.placeable(d))
            }
            RoutePolicy::LeastLoaded => {
                // ordering: round-robin ticket; uniqueness only
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                (0..n)
                    .map(|i| (start + i) % n)
                    .filter(|&d| self.placeable(d))
                    .min_by_key(|&d| ring_occupancy(d))
            }
            RoutePolicy::ClientAffinity => (0..n)
                .map(|i| (affinity + i) % n)
                .find(|&d| self.placeable(d)),
            RoutePolicy::CapacityAware => {
                // Probe each member's gauge once, refresh the shed
                // latches, then place on the emptiest non-shedding
                // member; if every healthy member is shedding,
                // water-fill by raw occupancy instead of refusing
                // service.
                // ordering: round-robin ticket; uniqueness only
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                let h = self.hysteresis;
                let occ: Vec<f64> = (0..n)
                    .map(|d| {
                        if !self.placeable(d) {
                            return f64::INFINITY;
                        }
                        let o = heap_occupancy(d);
                        if o >= h.shed_above {
                            // ordering: advisory shed hint; staleness tolerated
                            self.shedding[d].store(1, Ordering::Relaxed);
                        } else if o < h.readmit_below {
                            self.shedding[d].store(0, Ordering::Relaxed);
                        }
                        o
                    })
                    .collect();
                let admitted = |d: usize| {
                    self.placeable(d)
                        // ordering: advisory shed hint; staleness tolerated
                        && self.shedding[d].load(Ordering::Relaxed) == 0
                };
                let pick = (0..n)
                    .map(|i| (start + i) % n)
                    .filter(|&d| admitted(d))
                    .min_by_key(|&d| (occ[d] * CAPACITY_BUCKETS) as u64);
                pick.or_else(|| {
                    (0..n)
                        .map(|i| (start + i) % n)
                        .filter(|&d| self.placeable(d))
                        .min_by_key(|&d| {
                            (occ[d] * CAPACITY_BUCKETS * 16.0) as u64
                        })
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn route(r: &Router, aff: usize) -> Option<usize> {
        r.route_alloc(aff, |_| 0, |_| 0.0)
    }

    #[test]
    fn round_robin_cycles_devices() {
        let r = Router::new(RoutePolicy::RoundRobin, 4);
        let picks: Vec<usize> = (0..8).map(|_| route(&r, 0).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn least_loaded_picks_minimum_occupancy() {
        let r = Router::new(RoutePolicy::LeastLoaded, 3);
        let occ = [5u64, 2, 7];
        assert_eq!(r.route_alloc(0, |d| occ[d], |_| 0.0), Some(1));
    }

    #[test]
    fn least_loaded_all_tied_degenerates_to_round_robin() {
        // Blocking clients always probe all-zero occupancy; the rotating
        // tie-break must spread them instead of pinning device 0.
        let r = Router::new(RoutePolicy::LeastLoaded, 4);
        let picks: Vec<usize> = (0..4).map(|_| route(&r, 0).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn client_affinity_pins_to_handle() {
        let r = Router::new(RoutePolicy::ClientAffinity, 4);
        for _ in 0..3 {
            assert_eq!(route(&r, 2), Some(2));
        }
        // Affinities wrap around small groups.
        let r2 = Router::new(RoutePolicy::ClientAffinity, 2);
        assert_eq!(route(&r2, 5), Some(1));
    }

    #[test]
    fn single_device_group_is_trivial() {
        for policy in RoutePolicy::all() {
            let r = Router::new(policy, 1);
            for aff in 0..4 {
                assert_eq!(
                    r.route_alloc(aff, |_| 9, |_| 0.5),
                    Some(0),
                    "{}",
                    policy.id()
                );
            }
        }
    }

    #[test]
    fn policy_ids_stable() {
        let ids: Vec<&str> = RoutePolicy::all().iter().map(|p| p.id()).collect();
        assert_eq!(
            ids,
            vec!["round-robin", "least-loaded", "client-affinity", "capacity-aware"]
        );
    }

    #[test]
    fn state_machine_transitions() {
        let r = Router::new(RoutePolicy::RoundRobin, 2);
        assert_eq!(r.state(1), DeviceState::Healthy);
        assert_eq!(r.begin_draining(1), Some(true), "fresh drain");
        assert_eq!(r.state(1), DeviceState::Draining);
        assert!(r.mark_draining(1), "re-draining is a no-op, not an error");
        assert_eq!(r.begin_draining(1), Some(false), "resumed drain");
        r.mark_retired(1);
        assert_eq!(r.state(1), DeviceState::Retired);
        assert!(!r.mark_draining(1), "retired members cannot drain");
        assert_eq!(r.state(1), DeviceState::Retired);
        assert_eq!(r.healthy_count(), 1);
        let ids: Vec<&str> = [
            DeviceState::Healthy,
            DeviceState::Draining,
            DeviceState::Retired,
            DeviceState::Readmitting,
        ]
        .iter()
        .map(|s| s.id())
        .collect();
        assert_eq!(ids, vec!["healthy", "draining", "retired", "readmitting"]);
    }

    #[test]
    fn lease_epoch_bumps_on_drain_and_retire() {
        let r = Router::new(RoutePolicy::RoundRobin, 2);
        assert_eq!(r.lease_epoch(0), 0);
        assert_eq!(r.lease_epoch(1), 0);
        // Fresh drain bumps; resuming the same drain does not.
        assert_eq!(r.begin_draining(1), Some(true));
        assert_eq!(r.lease_epoch(1), 1);
        assert_eq!(r.begin_draining(1), Some(false));
        assert_eq!(r.lease_epoch(1), 1, "resume must not re-invalidate");
        // Hard retire bumps again; the untouched member is unaffected.
        r.mark_retired(1);
        assert_eq!(r.lease_epoch(1), 2);
        assert_eq!(r.lease_epoch(0), 0);
    }

    #[test]
    fn readmit_cycle_retired_to_healthy() {
        let r = Router::new(RoutePolicy::RoundRobin, 2);
        // Only a retired member may enter readmit.
        assert!(!r.mark_readmitting(1), "healthy member must refuse readmit");
        r.mark_draining(1);
        assert!(!r.mark_readmitting(1), "draining member must refuse readmit");
        r.mark_retired(1);
        assert!(r.mark_readmitting(1));
        assert_eq!(r.state(1), DeviceState::Readmitting);
        // Readmitting members are not placeable and cannot drain.
        assert_eq!(r.healthy_count(), 1);
        assert!(!r.mark_draining(1));
        assert!(!r.mark_readmitting(1), "double readmit refused");
        assert!(r.finish_readmit(1));
        assert_eq!(r.state(1), DeviceState::Healthy);
        assert_eq!(r.healthy_count(), 2);
        assert!(!r.finish_readmit(1), "finish without readmitting refused");
        // The full cycle is repeatable.
        r.mark_draining(1);
        r.mark_retired(1);
        assert!(r.mark_readmitting(1));
        assert!(r.finish_readmit(1));
        assert_eq!(r.state(1), DeviceState::Healthy);
    }

    #[test]
    fn readmitted_member_starts_shed_under_capacity_aware() {
        let r = Router::new(RoutePolicy::CapacityAware, 2);
        r.mark_retired(1);
        assert!(r.mark_readmitting(1));
        assert!(r.finish_readmit(1));
        // Inside the hysteresis band (not past shed, not under readmit)
        // the freshly readmitted member stays shed: the latch set by
        // finish_readmit holds until the gauge proves the heap low.
        let band = [0.20, 0.75];
        for _ in 0..4 {
            assert_eq!(r.route_alloc(0, |_| 0, |d| band[d]), Some(0));
        }
        // An occupancy probe below the readmit threshold re-opens it.
        let cool = [0.20, 0.10];
        let picks: Vec<usize> = (0..4)
            .map(|_| r.route_alloc(0, |_| 0, |d| cool[d]).unwrap())
            .collect();
        assert!(
            picks.contains(&1),
            "readmitted member must rejoin placement once the gauge \
             proves it empty: {picks:?}"
        );
    }

    #[test]
    fn every_policy_skips_unhealthy_members() {
        for policy in RoutePolicy::all() {
            let r = Router::new(policy, 3);
            r.mark_draining(1);
            for aff in 0..6 {
                let d = r.route_alloc(aff, |_| 0, |_| 0.0).unwrap();
                assert_ne!(d, 1, "{}: routed to a draining member", policy.id());
            }
            r.mark_retired(1);
            r.mark_retired(2);
            for aff in 0..6 {
                assert_eq!(
                    r.route_alloc(aff, |_| 0, |_| 0.0),
                    Some(0),
                    "{}",
                    policy.id()
                );
            }
            r.mark_retired(0);
            assert_eq!(
                r.route_alloc(0, |_| 0, |_| 0.0),
                None,
                "{}: no healthy member must mean no placement",
                policy.id()
            );
        }
    }

    #[test]
    fn affinity_falls_forward_past_dead_member() {
        let r = Router::new(RoutePolicy::ClientAffinity, 3);
        r.mark_retired(1);
        assert_eq!(route(&r, 1), Some(2), "rotate forward from the dead pin");
        assert_eq!(route(&r, 0), Some(0), "healthy pins unaffected");
    }

    #[test]
    fn capacity_aware_prefers_empty_heaps() {
        let r = Router::new(RoutePolicy::CapacityAware, 3);
        let occ = [0.80, 0.10, 0.50];
        for _ in 0..4 {
            assert_eq!(r.route_alloc(0, |_| 0, |d| occ[d]), Some(1));
        }
    }

    #[test]
    fn capacity_aware_sheds_before_oom_with_hysteresis() {
        let r = Router::new(RoutePolicy::CapacityAware, 2);
        // Device 0 crosses the shed threshold: all load moves to 1.
        let hot = [0.90, 0.20];
        for _ in 0..4 {
            assert_eq!(r.route_alloc(0, |_| 0, |d| hot[d]), Some(1));
        }
        // Back inside the hysteresis band (below shed, above readmit):
        // still shedding — the latch must not flap at the threshold.
        let band = [0.80, 0.20];
        for _ in 0..4 {
            assert_eq!(r.route_alloc(0, |_| 0, |d| band[d]), Some(1));
        }
        // Only falling below the readmit threshold re-opens the member
        // (equal occupancy, so the readmitted member joins the rotation).
        let cool = [0.20, 0.20];
        let picks: Vec<usize> = (0..4)
            .map(|_| r.route_alloc(0, |_| 0, |d| cool[d]).unwrap())
            .collect();
        assert!(
            picks.contains(&0),
            "readmitted member must receive load again: {picks:?}"
        );
    }

    #[test]
    fn capacity_aware_all_shedding_water_fills() {
        let r = Router::new(RoutePolicy::CapacityAware, 2);
        let occ = [0.95, 0.88];
        // Both members are past the shed threshold; rather than refusing
        // service the router water-fills into the emptier one.
        for _ in 0..3 {
            assert_eq!(r.route_alloc(0, |_| 0, |d| occ[d]), Some(1));
        }
    }

    #[test]
    fn capacity_aware_near_ties_rotate() {
        let r = Router::new(RoutePolicy::CapacityAware, 3);
        // Within one quantisation bucket of each other: rotate.
        let picks: Vec<usize> = (0..3)
            .map(|_| r.route_alloc(0, |_| 0, |_| 0.201).unwrap())
            .collect();
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "ties must rotate: {picks:?}");
    }
}
