//! Placement routing for the device-group topology.
//!
//! The allocation service owns a *group* of simulated devices — possibly
//! heterogeneous (a `t2000` next to an `iris_xe`), each with its own
//! heap and its own full set of per-size-class ticket lanes. Placement
//! is decided **once, at submit time, for allocations only**:
//!
//! * **Allocs** are free to land anywhere — the router picks the device
//!   under the configured [`RoutePolicy`], and the completed address
//!   comes back device-tagged
//!   ([`crate::ouroboros::GlobalAddr`], device id in the high bits).
//! * **Frees** are *never* routed by policy: the address's device tag
//!   names the owning device, and the free travels to that device's
//!   lane regardless of which client handle submitted it or what policy
//!   placed the allocation. This is what makes cross-client,
//!   cross-device frees safe — a handle with affinity for device B can
//!   free memory living on device A and the op still reaches A's heap.
//!
//! Policies (the Intel SHMEM / SYCL-portability placement shapes, host
//! side):
//!
//! * [`RoutePolicy::RoundRobin`] — a shared counter spreads successive
//!   allocations evenly; the balanced default, and the scaling bench's
//!   configuration.
//! * [`RoutePolicy::LeastLoaded`] — pick the device whose target
//!   size-class lane has the lowest **live ring occupancy** (in-flight
//!   ops, the submit-time backpressure signal). Adapts to skew: a
//!   device bogged down in a deep pipeline stops receiving new work.
//! * [`RoutePolicy::ClientAffinity`] — each client handle is pinned to
//!   one device (assigned round-robin at handle creation), giving
//!   per-client locality: one client's working set stays on one heap,
//!   which is the NUMA-ish shape a real multi-GPU deployment wants.
//!
//! The router is intentionally tiny and lock-free (one relaxed counter);
//! it sits on the submit hot path in front of every lane.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Placement policy for new allocations across a device group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Spread successive allocations evenly via a shared counter.
    RoundRobin,
    /// Send each allocation to the device whose target-class lane has
    /// the lowest live ring occupancy (in-flight ops).
    LeastLoaded,
    /// Pin every client handle to one device (assigned round-robin at
    /// handle creation); all of a handle's allocations land there.
    ClientAffinity,
}

impl RoutePolicy {
    /// Every policy, for sweep-style tests and benches.
    pub fn all() -> [RoutePolicy; 3] {
        [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::ClientAffinity,
        ]
    }

    /// Stable id for logs and bench records.
    pub fn id(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::ClientAffinity => "client-affinity",
        }
    }
}

/// Submit-time placement engine: one per service, shared by every
/// client handle.
#[derive(Debug)]
pub(crate) struct Router {
    policy: RoutePolicy,
    /// Round-robin cursor (relaxed: exact fairness under races doesn't
    /// matter, long-run balance does).
    rr: AtomicUsize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, rr: AtomicUsize::new(0) }
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    /// Pick the device for a fresh allocation. `occupancy(d)` reports
    /// the live ring occupancy of the target size-class lane on device
    /// `d` (only consulted by [`RoutePolicy::LeastLoaded`]). Ties
    /// rotate with the shared cursor rather than piling onto device 0 —
    /// blocking clients reap every op before the next submit, so they
    /// probe all-zero occupancy on every call and a fixed tie-break
    /// would silently degrade the policy to single-device. Frees never
    /// come through here — they follow their address's device tag.
    pub fn route_alloc<F>(&self, devices: usize, affinity: usize, occupancy: F) -> usize
    where
        F: Fn(usize) -> u64,
    {
        debug_assert!(devices > 0);
        match self.policy {
            RoutePolicy::RoundRobin => {
                self.rr.fetch_add(1, Ordering::Relaxed) % devices
            }
            RoutePolicy::LeastLoaded => {
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                (0..devices)
                    .map(|i| (start + i) % devices)
                    .min_by_key(|&d| occupancy(d))
                    .unwrap_or(0)
            }
            RoutePolicy::ClientAffinity => affinity % devices,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_devices() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let picks: Vec<usize> =
            (0..8).map(|_| r.route_alloc(4, 0, |_| 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn least_loaded_picks_minimum_occupancy() {
        let r = Router::new(RoutePolicy::LeastLoaded);
        let occ = [5u64, 2, 7];
        assert_eq!(r.route_alloc(3, 0, |d| occ[d]), 1);
    }

    #[test]
    fn least_loaded_all_tied_degenerates_to_round_robin() {
        // Blocking clients always probe all-zero occupancy; the rotating
        // tie-break must spread them instead of pinning device 0.
        let r = Router::new(RoutePolicy::LeastLoaded);
        let picks: Vec<usize> =
            (0..4).map(|_| r.route_alloc(4, 0, |_| 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn client_affinity_pins_to_handle() {
        let r = Router::new(RoutePolicy::ClientAffinity);
        for _ in 0..3 {
            assert_eq!(r.route_alloc(4, 2, |_| 0), 2);
        }
        // Affinities wrap around small groups.
        assert_eq!(r.route_alloc(2, 5, |_| 0), 1);
    }

    #[test]
    fn single_device_group_is_trivial() {
        for policy in RoutePolicy::all() {
            let r = Router::new(policy);
            for aff in 0..4 {
                assert_eq!(r.route_alloc(1, aff, |_| 9), 0, "{}", policy.id());
            }
        }
    }

    #[test]
    fn policy_ids_stable() {
        let ids: Vec<&str> = RoutePolicy::all().iter().map(|p| p.id()).collect();
        assert_eq!(ids, vec!["round-robin", "least-loaded", "client-affinity"]);
    }
}
