//! Client-side lease cache: mimalloc-style local free lists over the
//! ticket rings.
//!
//! Every service op crosses a ticket ring, so a single client's hot
//! loop is bounded by ring round-trips. The lease cache moves the hot
//! path into the client handle: the client **leases** a whole-chunk
//! span (one ring alloc of `CHUNK_SIZE`, class `NUM_QUEUES - 1`, so the
//! span is chunk-aligned by construction), carves it into
//! `pages_per_chunk(q)` blocks of its size class, and then serves
//! `alloc`/`free` from a per-handle free list with **zero ring
//! traffic**. Spans come back to the device as bulk frees when the
//! lease is released. This is mimalloc's heap/page-queue shape
//! (SNIPPETS.md snippet 2) grafted onto the device-tagged
//! [`GlobalAddr`] space: a leased span stays device-tagged, so the
//! cached path composes with group routing, migration and federation.
//!
//! # Lease lifecycle
//!
//! ```text
//!             mint (1 ring alloc)            owner drains
//!  ┌────────┐ ───────────────────▶ ┌────────┐ delayed frees ┌──────────┐
//!  │ unbacked│                     │ LEASED │ ─────────────▶ │ RENEWING │
//!  └────────┘                      └────────┘ ◀───────────── └──────────┘
//!                                    │    │       serve resumes
//!                  drain / retire    │    │ owner release,
//!                  (epoch bump +     │    │ all blocks free
//!                   recall quiesce)  ▼    ▼
//!                              ┌──────────┐    ┌──────────┐
//!                              │ RECALLED │ ─▶ │ RETURNED │ (1 ring free
//!                              └──────────┘    └──────────┘  of the span)
//! ```
//!
//! * **Leased** — the owner handle serves blocks from its local list.
//! * **Renewing** — the owner's local list ran dry and it drains the
//!   delayed-free bitmap (cross-client frees) back into it; this is the
//!   mimalloc "collect" step and the only synchronisation the owner
//!   ever does on the hot path.
//! * **Recalled** — drain/retire claimed the span. The recaller bumps
//!   the member's client-visible lease epoch
//!   (`Router::bump_lease_epoch`), sets the per-lease recall flag and
//!   quiesces the owner's serve **pin** before migrating the span, so
//!   no block is ever served from a span being copied away. Stale
//!   cached names keep resolving through the lease registry (the
//!   block-granular analogue of the migration forwarding table).
//! * **Returned** — every block is free again and the owner released
//!   the lease: exactly one thread wins the finalize CAS, unregisters
//!   the span and ring-frees it at its *current* home (post-migration
//!   if it was recalled).
//!
//! # Serve pin vs recall (the TOCTOU the `LeaseModel` checks)
//!
//! The owner's serve is: **pin → re-check epoch + recall flag → pop a
//! block → unpin**; the recaller is: **set recall flag (and bump the
//! epoch) → spin until pins reach zero → migrate**. Both sides are
//! SeqCst, so in the total order either the owner's re-check observes
//! the recall and backs out, or the recaller's quiesce observes the
//! pin and waits for the serve to finish — checking *before* pinning
//! (the `LeaseModel::buggy` mode) re-opens the window and the model
//! checker finds the served-from-recalled-span counterexample.
//!
//! # Shutdown ordering
//!
//! A lease is a live block: the service cannot tell a leased span from
//! any other allocation, so cached client handles must be dropped (or
//! `flush_cache`ed) **before** the service shuts down or a federation
//! group restarts. Under `OURO_SAN=1` a lease still registered at
//! shutdown panics as a leaked lease, with its full event history.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::check::lockgraph::{classes, OrderedMutex, OrderedRwLock};

use crate::ouroboros::params::{page_size, pages_per_chunk, CHUNK_SIZE, NUM_QUEUES};
use crate::ouroboros::{AllocError, GlobalAddr};

/// The size class whose pages are whole chunks — what a lease span is
/// allocated as, which is what makes every span chunk-aligned.
pub(crate) const SPAN_CLASS: usize = NUM_QUEUES - 1;

/// Upper bound on spans a client cache holds per size class; beyond it
/// cached allocation falls through to the ring path instead of leasing
/// more of the heap than one handle can plausibly churn.
pub(crate) const MAX_SPANS_PER_CLASS: usize = 32;

/// One leased span: a chunk-aligned `CHUNK_SIZE` allocation carved into
/// `pages_per_chunk(class)` blocks of `page_size(class)` bytes. Shared
/// (`Arc`) between the owning client's cache, the service-wide
/// [`LeaseRegistry`], and any recaller.
pub(crate) struct Lease {
    /// Process-unique lease identity. Cached-block names are
    /// origin-based and can collide with re-minted heap names after a
    /// relocation; the `OURO_LIN` recorder partitions by this id so
    /// the two histories never alias.
    id: u64,
    /// Size class of the carved blocks.
    class: usize,
    /// Block count (`pages_per_chunk(class)`).
    blocks: u32,
    /// The home member's `Router::lease_epoch` at mint time; a serve
    /// observing a newer epoch surrenders the lease.
    epoch: u64,
    /// Every home the span has had: `homes[0]` is the origin (the name
    /// space cached blocks were handed out in — serves stop at recall,
    /// so no block name ever derives from a later home), the last entry
    /// is the current home (where the finalize ring-free goes).
    homes: OrderedMutex<Vec<GlobalAddr>>,
    /// Authoritative per-block free mask (bit set = block free). Any
    /// path may set a bit (free); only the pinned owner clears one
    /// (serve). A free finding its bit already set is a double free.
    free_bits: Vec<AtomicU64>,
    /// Cross-client delayed-free mask: set together with `free_bits`
    /// by non-owner frees, consumed exactly once by the owner's
    /// `drain_delayed` swap.
    delayed_bits: Vec<AtomicU64>,
    /// Serve pins held by the owner; the recaller quiesces this to
    /// zero after setting `recalled` and before migrating.
    pins: AtomicU32,
    recalled: AtomicBool,
    /// Hard retire: the span's backing heap is gone — finalize
    /// unregisters but must not ring-free, and block frees report
    /// `DeviceRetired` like any other address on the dead member.
    dead: AtomicBool,
    /// Owner surrendered the lease (drop/flush/recall); a fully-free
    /// released lease is finalizable.
    released: AtomicBool,
    /// Finalize latch: exactly one winner returns the span.
    finalized: AtomicBool,
}

impl Lease {
    pub fn new(span: GlobalAddr, class: usize, epoch: u64) -> Arc<Lease> {
        assert!(class < SPAN_CLASS, "span class itself is never cached");
        debug_assert_eq!(span.chunk_offset(), 0, "lease spans are chunk-aligned");
        let blocks = pages_per_chunk(class);
        let words = Lease::words(blocks);
        let free_bits: Vec<AtomicU64> = (0..words)
            .map(|w| AtomicU64::new(Lease::full_mask(blocks, w)))
            .collect();
        static NEXT_LEASE_ID: AtomicU64 = AtomicU64::new(1);
        Arc::new(Lease {
            // ordering: Relaxed — a unique-id mint; nothing is
            // published through it.
            id: NEXT_LEASE_ID.fetch_add(1, Ordering::Relaxed),
            class,
            blocks,
            epoch,
            homes: OrderedMutex::new(&classes::LEASE_HOMES, vec![span]),
            free_bits,
            delayed_bits: (0..words).map(|_| AtomicU64::new(0)).collect(),
            pins: AtomicU32::new(0),
            recalled: AtomicBool::new(false),
            dead: AtomicBool::new(false),
            released: AtomicBool::new(false),
            finalized: AtomicBool::new(false),
        })
    }

    fn words(blocks: u32) -> usize {
        ((blocks + 63) / 64) as usize
    }

    /// The all-free mask of bitmap word `w` for a `blocks`-block lease.
    fn full_mask(blocks: u32, w: usize) -> u64 {
        let lo = (w as u32) * 64;
        let n = blocks.saturating_sub(lo).min(64);
        if n == 0 {
            0
        } else if n == 64 {
            u64::MAX
        } else {
            (1u64 << n) - 1
        }
    }

    /// Process-unique lease identity (see the field doc).
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn class(&self) -> usize {
        self.class
    }

    pub fn blocks(&self) -> u32 {
        self.blocks
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The span's original home — the address space every cached block
    /// name is carved from.
    pub fn origin(&self) -> GlobalAddr {
        self.homes.lock().unwrap()[0]
    }

    /// Where the span lives now (== `origin()` unless recalled and
    /// migrated) — the address the finalize ring-free targets.
    pub fn current_span(&self) -> GlobalAddr {
        *self.homes.lock().unwrap().last().unwrap()
    }

    /// Every home the span has had (origin first).
    pub fn homes(&self) -> Vec<GlobalAddr> {
        self.homes.lock().unwrap().clone()
    }

    /// The name of carved block `i` (origin-based: serves stop at
    /// recall, so names never derive from a post-migration home).
    pub fn block_addr(&self, i: u32) -> GlobalAddr {
        self.origin().block(self.class, i)
    }

    /// Resolve a cached block name to its index, against any home the
    /// span has had.
    pub fn index_for(&self, addr: GlobalAddr) -> Option<u32> {
        self.homes
            .lock()
            .unwrap()
            .iter()
            .find_map(|h| h.block_index(self.class, addr))
    }

    /// Owner-side serve pin. Returns `false` (pin dropped) if the lease
    /// is already recalled — the caller must surrender the lease, not
    /// serve from it.
    pub fn try_pin(&self) -> bool {
        // ordering: SeqCst pin; total order vs the recaller's flag+quiesce
        self.pins.fetch_add(1, Ordering::SeqCst);
        // ordering: SeqCst recall flag; pairs with begin_recall store
        if self.recalled.load(Ordering::SeqCst) {
            self.unpin();
            return false;
        }
        true
    }

    pub fn unpin(&self) {
        // ordering: SeqCst pin release; recaller's quiesce must observe it
        self.pins.fetch_sub(1, Ordering::SeqCst);
    }

    /// Recaller half of the serve/recall handshake: latch the recall
    /// flag, then spin until every in-flight serve pin drains. After
    /// this returns no new block can be served from the span and the
    /// caller may migrate it. Idempotent.
    pub fn begin_recall(&self) {
        // ordering: SeqCst recall flag; pairs with try_pin re-check
        self.recalled.store(true, Ordering::SeqCst);
        // ordering: SeqCst pin quiesce; pairs with try_pin/unpin
        while self.pins.load(Ordering::SeqCst) != 0 {
            std::hint::spin_loop();
        }
    }

    pub fn is_recalled(&self) -> bool {
        // ordering: SeqCst recall flag; pairs with begin_recall store
        self.recalled.load(Ordering::SeqCst)
    }

    /// Record the span's new home after a recall migrated it. Refused
    /// (`false`, nothing recorded) when the lease was finalized while
    /// the copy was in flight: the finalize winner is already returning
    /// the span under its old name (its ring-free forwards to the
    /// copy), so the copy must live on as a plain block, not a lease
    /// home. The homes lock serialises this check against the finalize
    /// latch in [`Lease::try_finalize`].
    pub fn relocate(&self, new_span: GlobalAddr) -> bool {
        debug_assert!(self.is_recalled(), "relocation without recall");
        let mut homes = self.homes.lock().unwrap();
        if self.is_finalized() {
            return false;
        }
        homes.push(new_span);
        true
    }

    /// Hard-retire the lease: the backing heap is gone (stranded).
    pub fn mark_dead(&self) {
        // ordering: Release latch; readers take the DeviceRetired path after
        self.dead.store(true, Ordering::Release);
    }

    pub fn is_dead(&self) -> bool {
        // ordering: Acquire latch; pairs with mark_dead
        self.dead.load(Ordering::Acquire)
    }

    /// Owner surrendered the lease; a fully-free released lease may be
    /// finalized by whichever free completes it.
    pub fn release(&self) {
        // ordering: Release; finalize eligibility after the owner is out
        self.released.store(true, Ordering::Release);
    }

    pub fn is_released(&self) -> bool {
        // ordering: Acquire; pairs with release()
        self.released.load(Ordering::Acquire)
    }

    /// Owner serve: claim block `i` (clears its free bit). The caller
    /// holds a pin and took `i` off its local list, so the bit must be
    /// set.
    pub fn take_block(&self, i: u32) {
        let (w, bit) = (i as usize / 64, 1u64 << (i % 64));
        // ordering: SeqCst block claim; ordered after the pinned recall check
        let old = self.free_bits[w].fetch_and(!bit, Ordering::SeqCst);
        debug_assert_ne!(old & bit, 0, "serving block {i} that was not free");
    }

    /// Free block `i` back into the lease. `delayed` marks a non-owner
    /// free (pushed for the owner to drain). A bit already set is a
    /// double free.
    pub fn free_block(&self, i: u32, delayed: bool) -> Result<(), AllocError> {
        let (w, bit) = (i as usize / 64, 1u64 << (i % 64));
        // ordering: SeqCst free publish; double-free detection needs the old bit
        let old = self.free_bits[w].fetch_or(bit, Ordering::SeqCst);
        if old & bit != 0 {
            return Err(AllocError::InvalidFree(self.block_addr(i).raw()));
        }
        if delayed {
            // ordering: SeqCst delayed push; consumed exactly once by drain swap
            self.delayed_bits[w].fetch_or(bit, Ordering::SeqCst);
        }
        Ok(())
    }

    /// Owner drain of the delayed-free list (the lease-renewal step):
    /// atomically consume every delayed bit, returning the block
    /// indices. Each delayed free is observed exactly once across all
    /// drains — the swap is the consumption.
    pub fn drain_delayed(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for (w, word) in self.delayed_bits.iter().enumerate() {
            // ordering: SeqCst drain swap; exactly-once hand-off from free_block
            let mut bits = word.swap(0, Ordering::SeqCst);
            while bits != 0 {
                let b = bits.trailing_zeros();
                out.push(w as u32 * 64 + b);
                bits &= bits - 1;
            }
        }
        out
    }

    /// Every carved block is free again.
    pub fn all_free(&self) -> bool {
        self.free_bits.iter().enumerate().all(|(w, word)| {
            // ordering: SeqCst bitmap read; finalize decision
            word.load(Ordering::SeqCst) == Lease::full_mask(self.blocks, w)
        })
    }

    /// Count of currently-free blocks (diagnostics/tests).
    pub fn free_count(&self) -> u32 {
        self.free_bits
            .iter()
            // ordering: stat read; advisory only
            .map(|w| w.load(Ordering::SeqCst).count_ones())
            .sum()
    }

    /// Indices of blocks currently carved out (served, not yet freed) —
    /// what a hard retire must strand along with the span.
    pub fn live_block_indices(&self) -> Vec<u32> {
        let mut out = Vec::new();
        for (w, word) in self.free_bits.iter().enumerate() {
            // ordering: stat read; retire holds the rebalance lock
            let mut live = Lease::full_mask(self.blocks, w)
                & !word.load(Ordering::SeqCst);
            while live != 0 {
                let b = live.trailing_zeros();
                out.push(w as u32 * 64 + b);
                live &= live - 1;
            }
        }
        out
    }

    /// Try to win the return of a released, fully-free lease. Exactly
    /// one caller gets `true` and must unregister the lease and (unless
    /// it is dead) ring-free `current_span()`.
    pub fn try_finalize(&self) -> bool {
        if !self.is_released() || !self.all_free() {
            return false;
        }
        // The homes lock serialises the latch against `relocate`: a
        // relocation either lands before the latch (the winner then
        // returns the span at its new home — `current_span` is stable
        // once finalized) or is refused after it (the migration keeps
        // the copy as a plain block). No third interleaving exists
        // where both sides free the same old name.
        let _homes = self.homes.lock().unwrap();
        self.finalized
            .compare_exchange(
                false,
                true,
                // ordering: AcqRel finalize latch; single winner returns the span
                Ordering::AcqRel,
                Ordering::Acquire,
            )
            .is_ok()
    }

    pub fn is_finalized(&self) -> bool {
        // ordering: Acquire; pairs with the finalize CAS
        self.finalized.load(Ordering::Acquire)
    }
}

/// Service-wide index of live leases, keyed by `(device, chunk)` of
/// every home a span has had. Because spans are chunk-aligned whole
/// chunks, any address inside a leased chunk resolves here in O(1) —
/// the registry is the block-granular analogue of the migration
/// forwarding table, and it is consulted on every free while any lease
/// is live (`is_active` gates the cost away otherwise).
pub(crate) struct LeaseRegistry {
    /// Live (registered) lease count — the free-path fast gate.
    active: AtomicUsize,
    /// Per-device `chunk -> lease` maps.
    by_chunk: Vec<OrderedRwLock<HashMap<u32, Arc<Lease>>>>,
}

impl LeaseRegistry {
    pub fn new(devices: usize) -> Self {
        LeaseRegistry {
            active: AtomicUsize::new(0),
            by_chunk: (0..devices)
                .map(|_| {
                    OrderedRwLock::new(&classes::LEASE_REGISTRY, HashMap::new())
                })
                .collect(),
        }
    }

    /// Any lease registered? One load on the free hot path; when false
    /// the free proceeds straight to the ring.
    pub fn is_active(&self) -> bool {
        // ordering: Acquire gate; pairs with the register Release
        self.active.load(Ordering::Acquire) != 0
    }

    pub fn live_leases(&self) -> usize {
        // ordering: Acquire gate; pairs with the register Release
        self.active.load(Ordering::Acquire)
    }

    /// Register a freshly minted lease under its origin key.
    pub fn register(&self, lease: &Arc<Lease>) {
        let span = lease.origin();
        self.by_chunk[span.device() as usize]
            .write()
            .unwrap()
            .insert(span.chunk(), Arc::clone(lease));
        // ordering: Release gate; the lease is resolvable before the gate opens
        self.active.fetch_add(1, Ordering::Release);
    }

    /// Add a post-migration home key so `(device, chunk)` lookups of
    /// the span's new location (drain enumeration, hard retire) still
    /// find the lease. Does not change the live count.
    pub fn register_home(&self, lease: &Arc<Lease>, span: GlobalAddr) {
        self.by_chunk[span.device() as usize]
            .write()
            .unwrap()
            .insert(span.chunk(), Arc::clone(lease));
    }

    /// Drop every key of a finalized lease.
    pub fn unregister(&self, lease: &Arc<Lease>) {
        for home in lease.homes() {
            let mut map = self.by_chunk[home.device() as usize].write().unwrap();
            if map.get(&home.chunk()).is_some_and(|l| Arc::ptr_eq(l, lease)) {
                map.remove(&home.chunk());
            }
        }
        // ordering: Release gate; symmetric with register
        self.active.fetch_sub(1, Ordering::Release);
    }

    /// The lease (if any) whose span covers `(device, chunk)`.
    pub fn lookup(&self, device: u32, chunk: u32) -> Option<Arc<Lease>> {
        if device as usize >= self.by_chunk.len() {
            return None;
        }
        self.by_chunk[device as usize].read().unwrap().get(&chunk).cloned()
    }

    /// Resolve an arbitrary address to `(lease, block index)` if it
    /// names a cached block. Group-tagged addresses never resolve (the
    /// registry lives inside one group, like the rest of the service).
    pub fn resolve(&self, addr: GlobalAddr) -> Option<(Arc<Lease>, u32)> {
        if addr.group() != 0 {
            return None;
        }
        let lease = self.lookup(addr.device(), addr.chunk())?;
        let i = lease.index_for(addr)?;
        Some((lease, i))
    }

    /// Whether any lease — live and relocated away, or dead and
    /// stranded — still has a home key on `device`. Readmission must
    /// refuse while one exists: the member's re-minted address window
    /// would alias origin-based cached-block names.
    pub fn names_device(&self, device: usize) -> bool {
        device < self.by_chunk.len()
            && !self.by_chunk[device].read().unwrap().is_empty()
    }

    /// Every distinct lease whose *current* span sits on `device` — the
    /// hard-retire recall set.
    pub fn leases_on(&self, device: u32) -> Vec<Arc<Lease>> {
        if device as usize >= self.by_chunk.len() {
            return Vec::new();
        }
        let map = self.by_chunk[device as usize].read().unwrap();
        let mut out: Vec<Arc<Lease>> = Vec::new();
        for lease in map.values() {
            if lease.current_span().device() == device
                && !out.iter().any(|l| Arc::ptr_eq(l, lease))
            {
                out.push(Arc::clone(lease));
            }
        }
        out
    }
}

/// One span actively serving an owner's size class: the lease plus the
/// owner-private list of free block indices (the mimalloc page free
/// list — no atomics, the owner is the only reader/writer).
pub(crate) struct ActiveLease {
    pub lease: Arc<Lease>,
    pub local: Vec<u32>,
}

/// The per-handle cache: one small span queue per size class (mimalloc
/// page queues). Lives under the client handle's mutex; every method is
/// owner-only.
#[derive(Default)]
pub(crate) struct ClientCache {
    spans: Vec<Vec<ActiveLease>>,
}

/// Outcome of one cached-serve attempt, plus any leases the attempt
/// surrendered (recalled or stale-epoch spans the caller must release
/// and try to finalize).
pub(crate) struct ServeOutcome {
    pub addr: Option<GlobalAddr>,
    pub surrendered: Vec<Arc<Lease>>,
}

impl ClientCache {
    pub fn new() -> Self {
        ClientCache { spans: (0..NUM_QUEUES).map(|_| Vec::new()).collect() }
    }

    /// Spans currently held for `class`.
    pub fn span_count(&self, class: usize) -> usize {
        self.spans[class].len()
    }

    /// Room for another span mint in `class`?
    pub fn can_mint(&self, class: usize) -> bool {
        self.spans[class].len() < MAX_SPANS_PER_CLASS
    }

    /// Adopt a freshly minted span for `class` with every block free.
    pub fn install(&mut self, lease: Arc<Lease>) {
        let local: Vec<u32> = (0..lease.blocks()).collect();
        self.spans[lease.class()].push(ActiveLease { lease, local });
    }

    /// Serve one block of `class` from the active spans, newest first.
    /// `epoch_of(device)` is the router's current lease epoch — a span
    /// whose member drained/retired since its mint is surrendered, as
    /// is any span whose recall flag trips the pin. The serve itself is
    /// the pinned sequence described in the module docs.
    pub fn serve(
        &mut self,
        class: usize,
        epoch_of: impl Fn(u32) -> u64,
    ) -> ServeOutcome {
        let mut surrendered = Vec::new();
        let list = &mut self.spans[class];
        let mut idx = list.len();
        while idx > 0 {
            idx -= 1;
            let entry = &mut list[idx];
            let lease = Arc::clone(&entry.lease);
            if !lease.try_pin() {
                list.remove(idx);
                lease.release();
                surrendered.push(lease);
                continue;
            }
            if epoch_of(lease.origin().device()) != lease.epoch() {
                lease.unpin();
                list.remove(idx);
                lease.release();
                surrendered.push(lease);
                continue;
            }
            if entry.local.is_empty() {
                entry.local.extend(lease.drain_delayed());
            }
            match entry.local.pop() {
                Some(i) => {
                    lease.take_block(i);
                    lease.unpin();
                    return ServeOutcome {
                        addr: Some(lease.block_addr(i)),
                        surrendered,
                    };
                }
                None => lease.unpin(),
            }
        }
        ServeOutcome { addr: None, surrendered }
    }

    /// Whether this cache currently holds `lease` in a span queue (the
    /// owner test deciding local vs delayed free).
    pub fn holds(&self, lease: &Arc<Lease>) -> bool {
        self.spans[lease.class()]
            .iter()
            .any(|e| Arc::ptr_eq(&e.lease, lease))
    }

    /// Owner-side free: if this cache holds `lease`, push block `i`
    /// onto its local list and report `true`; the caller then records
    /// the free as owner-local rather than delayed.
    pub fn local_push(&mut self, lease: &Arc<Lease>, i: u32) -> bool {
        for entry in &mut self.spans[lease.class()] {
            if Arc::ptr_eq(&entry.lease, lease) {
                entry.local.push(i);
                return true;
            }
        }
        false
    }

    /// Surrender every span (handle drop / explicit flush): releases
    /// each lease and returns them for the caller to drain + finalize.
    pub fn drain_all(&mut self) -> Vec<Arc<Lease>> {
        let mut out = Vec::new();
        for list in &mut self.spans {
            for entry in list.drain(..) {
                entry.lease.release();
                out.push(entry.lease);
            }
        }
        out
    }

    /// Total spans held across all classes.
    pub fn total_spans(&self) -> usize {
        self.spans.iter().map(|l| l.len()).sum()
    }
}

/// Size classes eligible for caching: everything below the span class
/// (a whole-chunk request gains nothing from carving a whole chunk).
pub(crate) fn cacheable_class(size: u32) -> Option<usize> {
    match crate::ouroboros::params::queue_for_size(size) {
        Some(q) if q < SPAN_CLASS => Some(q),
        _ => None,
    }
}

/// `page_size` re-exported for the service's span-mint request.
pub(crate) fn span_bytes() -> u32 {
    debug_assert_eq!(page_size(SPAN_CLASS), CHUNK_SIZE);
    CHUNK_SIZE
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(device: u32, chunk: u32) -> GlobalAddr {
        GlobalAddr::new(device, chunk * CHUNK_SIZE)
    }

    #[test]
    fn carve_and_bitmaps_roundtrip() {
        let l = Lease::new(span(1, 3), 6, 0);
        assert_eq!(l.blocks(), 8);
        assert_eq!(l.free_count(), 8);
        assert!(l.all_free());
        l.take_block(3);
        assert!(!l.all_free());
        assert_eq!(l.free_count(), 7);
        l.free_block(3, false).unwrap();
        assert!(l.all_free());
        // Double free of a free block is detected with the block name.
        let err = l.free_block(3, false).unwrap_err();
        assert_eq!(err, AllocError::InvalidFree(l.block_addr(3).raw()));
    }

    #[test]
    fn q0_masks_cover_512_blocks() {
        let l = Lease::new(span(0, 0), 0, 0);
        assert_eq!(l.blocks(), 512);
        assert!(l.all_free());
        for i in 0..512 {
            l.take_block(i);
        }
        assert_eq!(l.free_count(), 0);
        for i in 0..512 {
            l.free_block(i, i % 2 == 0).unwrap();
        }
        assert!(l.all_free());
        let drained = l.drain_delayed();
        assert_eq!(drained.len(), 256, "every even block was delayed");
    }

    #[test]
    fn delayed_frees_consumed_exactly_once() {
        let l = Lease::new(span(0, 1), 6, 0);
        l.take_block(0);
        l.take_block(1);
        l.free_block(0, true).unwrap();
        l.free_block(1, true).unwrap();
        let first = l.drain_delayed();
        assert_eq!(first, vec![0, 1]);
        assert_eq!(l.drain_delayed(), Vec::<u32>::new(), "second drain empty");
        // The free bits stay set (the drain consumes the hand-off, not
        // the free itself).
        assert!(l.all_free());
    }

    #[test]
    fn recall_blocks_future_pins() {
        let l = Lease::new(span(2, 5), 4, 0);
        assert!(l.try_pin());
        l.unpin();
        l.begin_recall();
        assert!(!l.try_pin(), "recalled lease must refuse the serve pin");
        assert!(l.is_recalled());
        l.begin_recall(); // idempotent
    }

    #[test]
    fn recall_quiesce_waits_for_pin() {
        let l = Lease::new(span(0, 2), 6, 0);
        assert!(l.try_pin());
        let l2 = Arc::clone(&l);
        let recaller = std::thread::spawn(move || {
            l2.begin_recall();
            std::time::Instant::now()
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        let before_unpin = std::time::Instant::now();
        l.unpin();
        let quiesced_at = recaller.join().unwrap();
        assert!(
            quiesced_at >= before_unpin,
            "recall must not complete while a serve pin is held"
        );
    }

    #[test]
    fn finalize_single_winner_and_eligibility() {
        let l = Lease::new(span(0, 4), 6, 0);
        l.take_block(0);
        l.release();
        assert!(!l.try_finalize(), "a live block blocks finalize");
        l.free_block(0, false).unwrap();
        assert!(l.try_finalize());
        assert!(!l.try_finalize(), "finalize must have exactly one winner");
        assert!(l.is_finalized());
    }

    #[test]
    fn relocate_refused_after_finalize() {
        let l = Lease::new(span(0, 4), 6, 0);
        l.take_block(0);
        l.release();
        l.begin_recall();
        l.free_block(0, false).unwrap();
        assert!(l.try_finalize());
        assert!(
            !l.relocate(span(1, 5)),
            "finalize won the span; the copy stays a plain block"
        );
        assert_eq!(l.current_span(), span(0, 4), "home list unchanged");
    }

    #[test]
    fn relocation_keeps_origin_names_resolvable() {
        let l = Lease::new(span(0, 3), 6, 0);
        let name = l.block_addr(2);
        l.begin_recall();
        assert!(l.relocate(span(1, 7)));
        assert_eq!(l.current_span(), span(1, 7));
        assert_eq!(l.origin(), span(0, 3));
        assert_eq!(l.index_for(name), Some(2), "stale names resolve by origin");
        assert_eq!(l.index_for(span(1, 7).block(6, 2)), Some(2), "new home too");
        assert_eq!(l.index_for(span(2, 3).block(6, 2)), None);
    }

    #[test]
    fn registry_resolves_and_gates() {
        let reg = LeaseRegistry::new(2);
        assert!(!reg.is_active());
        let l = Lease::new(span(1, 6), 6, 0);
        reg.register(&l);
        assert!(reg.is_active());
        assert_eq!(reg.live_leases(), 1);
        let (hit, i) = reg.resolve(l.block_addr(5)).unwrap();
        assert!(Arc::ptr_eq(&hit, &l));
        assert_eq!(i, 5);
        // Misses: other chunk, other device, group-tagged, misaligned.
        assert!(reg.resolve(span(1, 7)).is_none());
        assert!(reg.resolve(span(0, 6)).is_none());
        assert!(reg.resolve(l.block_addr(5).with_group(1)).is_none());
        assert!(reg
            .resolve(GlobalAddr::new(1, 6 * CHUNK_SIZE + 100))
            .is_none());
        reg.unregister(&l);
        assert!(!reg.is_active());
        assert!(reg.resolve(l.block_addr(5)).is_none());
    }

    #[test]
    fn registry_tracks_relocated_homes() {
        let reg = LeaseRegistry::new(3);
        let l = Lease::new(span(0, 2), 6, 0);
        reg.register(&l);
        l.begin_recall();
        assert!(l.relocate(span(2, 9)));
        reg.register_home(&l, span(2, 9));
        assert_eq!(reg.live_leases(), 1, "extra home keys are not extra leases");
        // Both keys resolve; the hard-retire recall set follows the
        // *current* home.
        assert!(reg.lookup(0, 2).is_some());
        assert!(reg.lookup(2, 9).is_some());
        assert!(reg.leases_on(0).is_empty(), "origin device no longer hosts it");
        assert_eq!(reg.leases_on(2).len(), 1);
        reg.unregister(&l);
        assert!(reg.lookup(0, 2).is_none());
        assert!(reg.lookup(2, 9).is_none());
        assert!(!reg.is_active());
    }

    #[test]
    fn cache_serve_mints_pops_and_exhausts() {
        let mut c = ClientCache::new();
        let out = c.serve(6, |_| 0);
        assert!(out.addr.is_none(), "empty cache has nothing to serve");
        let l = Lease::new(span(0, 1), 6, 7);
        c.install(Arc::clone(&l));
        assert_eq!(c.span_count(6), 1);
        let mut served = Vec::new();
        for _ in 0..8 {
            let out = c.serve(6, |_| 7);
            served.push(out.addr.expect("block available"));
            assert!(out.surrendered.is_empty());
        }
        assert_eq!(l.free_count(), 0);
        assert!(c.serve(6, |_| 7).addr.is_none(), "span exhausted");
        // A cross-client delayed free refills the local list via the
        // renewal drain.
        let (back, i) = (served[3], l.index_for(served[3]).unwrap());
        l.free_block(i, true).unwrap();
        let out = c.serve(6, |_| 7);
        assert_eq!(out.addr, Some(back), "renewal drains the delayed free");
    }

    #[test]
    fn cache_surrenders_on_epoch_bump_and_recall() {
        let mut c = ClientCache::new();
        let stale = Lease::new(span(0, 1), 6, 0);
        c.install(Arc::clone(&stale));
        // Epoch moved on: the span is surrendered, released, unserved.
        let out = c.serve(6, |_| 1);
        assert!(out.addr.is_none());
        assert_eq!(out.surrendered.len(), 1);
        assert!(stale.is_released());
        assert_eq!(c.span_count(6), 0);
        // A recalled span trips the pin the same way.
        let recalled = Lease::new(span(0, 2), 6, 0);
        c.install(Arc::clone(&recalled));
        recalled.begin_recall();
        let out = c.serve(6, |_| 0);
        assert!(out.addr.is_none());
        assert_eq!(out.surrendered.len(), 1);
        assert!(recalled.is_released());
    }

    #[test]
    fn cache_local_push_only_for_held_leases() {
        let mut c = ClientCache::new();
        let held = Lease::new(span(0, 1), 6, 0);
        let foreign = Lease::new(span(0, 2), 6, 0);
        c.install(Arc::clone(&held));
        let a = c.serve(6, |_| 0).addr.unwrap();
        let i = held.index_for(a).unwrap();
        held.free_block(i, false).unwrap();
        assert!(c.local_push(&held, i));
        assert!(!c.local_push(&foreign, 0));
        // The pushed block serves again without a delayed drain.
        assert_eq!(c.serve(6, |_| 0).addr, Some(a));
    }

    #[test]
    fn cache_drain_all_releases_everything() {
        let mut c = ClientCache::new();
        for chunk in 0..3 {
            c.install(Lease::new(span(0, chunk), 6, 0));
        }
        c.install(Lease::new(span(0, 9), 2, 0));
        assert_eq!(c.total_spans(), 4);
        let drained = c.drain_all();
        assert_eq!(drained.len(), 4);
        assert!(drained.iter().all(|l| l.is_released()));
        assert_eq!(c.total_spans(), 0);
    }

    #[test]
    fn cacheable_class_excludes_span_class() {
        assert_eq!(cacheable_class(1000), Some(6));
        assert_eq!(cacheable_class(16), Some(0));
        assert_eq!(cacheable_class(4097), None, "q9 requests stay on the ring");
        assert_eq!(cacheable_class(CHUNK_SIZE), None);
        assert_eq!(cacheable_class(0), None);
        assert_eq!(span_bytes(), CHUNK_SIZE);
    }
}
