//! Group resilience: device failover, live-set migration and the stale
//! free forwarding table.
//!
//! PR 3 made the allocation service a device group; this module makes
//! the group survive losing a member. Three pieces:
//!
//! * **Failover** — [`AllocService::retire_device`] marks a member dead
//!   in the router (every [`super::router::RoutePolicy`] skips it from
//!   then on), stops its lanes, and fails every still-queued ticket
//!   with the deterministic
//!   [`AllocError::DeviceRetired`](crate::ouroboros::AllocError) —
//!   waiters get an error completion of the right kind, never a hang.
//! * **Live-set migration** — [`AllocService::migrate`] copies one
//!   allocation onto a healthy member (`Heap::clone_block` moves the
//!   payload words), frees the source page, and records the old→new
//!   mapping in the [`ForwardingTable`]; [`AllocService::drain_device`]
//!   bulk-migrates a retiring member's whole live set.
//! * **Forwarding** — a client holding a migrated address does not know
//!   it moved. Its stale free is rewritten to the new address **exactly
//!   once**, provided it arrives within a configurable grace window
//!   ([`AllocService::set_forwarding_grace`]); after the window — or a
//!   second stale free of the same address — the free is rejected with
//!   a tagged `InvalidFree`.
//!
//! # The member state machine
//!
//! ```text
//!            drain_device                retire_device
//! Healthy ────────────────▶ Draining ────────────────▶ Retired
//!    │                         │
//!    │  placement: all         │  placement: skipped; frees and
//!    │  policies eligible      │  migration still reach the heap
//!    └─────────────────────────┴──▶ (retire_device may also be called
//!                                    directly — a hard kill that
//!                                    strands whatever was not drained)
//! ```
//!
//! The drain protocol against concurrent client traffic:
//!
//! 1. mark the member Draining — no *new* allocs are placed on it (the
//!    submit path re-checks the state after its ring claim, so a
//!    placement that raced the mark backs out and re-routes);
//! 2. quiesce — wait until the member's in-flight-alloc gauge reaches
//!    zero, so every allocation ever placed on it has hit its heap;
//! 3. enumerate the live set from the heap's chunk-occupancy bitmaps
//!    (exact now: placements stopped, in-flight allocs landed; only
//!    concurrent *frees* can still race, and they only clear bits);
//! 4. migrate each page: allocate + copy on a healthy member, publish
//!    the forwarding entry, then **claim** the source page by freeing
//!    it. A concurrent client free of the same page lands in exactly
//!    one of three windows: before the entry exists and before our
//!    claim (our claim fails ⇒ roll the copy back, drop the entry);
//!    after the entry is published, at submit time (⇒ forwarded to the
//!    new address); or **already queued in the member's lanes** when
//!    the claim wins — that free finds the page gone at dispatch, and
//!    the dispatcher consults the table again (*late forwarding*, see
//!    `service.rs`) and delivers it to the migrated copy. Every path
//!    frees the block exactly once, on exactly one member.
//!
//! A forwarding entry dies early if its old name — or the new address
//! it points to — is re-minted by a later allocation (the service's
//! dispatch path invalidates re-used names), so a stale free can never
//! be forwarded into somebody else's allocation.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ouroboros::chunk::STATE_OWNED;
use crate::ouroboros::params::{page_size, pages_per_chunk};
use crate::ouroboros::{AllocError, GlobalAddr, Heap};
use crate::simt::Grid;

use super::router::DeviceState;
use super::service::AllocService;

/// Default grace window for forwarding stale frees of migrated
/// addresses (override per service with
/// [`AllocService::set_forwarding_grace`]).
pub const DEFAULT_FORWARD_GRACE: Duration = Duration::from_secs(5);

/// What the forwarding table says about a submitted free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardVerdict {
    /// Not a migrated address: route normally.
    Miss,
    /// Migrated, inside the grace window, first free: deliver to the
    /// new address instead.
    Forward(GlobalAddr),
    /// Migrated but already forwarded once, or the grace window
    /// elapsed: reject with a tagged `InvalidFree`.
    Stale,
}

#[derive(Debug, Clone, Copy)]
struct ForwardEntry {
    to: GlobalAddr,
    at: Instant,
    consumed: bool,
}

/// Old→new address map for migrated allocations. Read-mostly: the free
/// submit path takes the read lock only while the table is non-empty
/// (one relaxed flag probe otherwise), and only upgrades to the write
/// lock to consume a hit.
pub struct ForwardingTable {
    grace_nanos: AtomicU64,
    active: AtomicBool,
    map: RwLock<HashMap<u32, ForwardEntry>>,
}

impl Default for ForwardingTable {
    fn default() -> Self {
        Self::new()
    }
}

impl ForwardingTable {
    pub fn new() -> Self {
        ForwardingTable {
            grace_nanos: AtomicU64::new(DEFAULT_FORWARD_GRACE.as_nanos() as u64),
            active: AtomicBool::new(false),
            map: RwLock::new(HashMap::new()),
        }
    }

    /// Whether any entry was ever published (the free path's fast-path
    /// gate: a service that never migrated pays one relaxed load).
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Relaxed)
    }

    pub fn set_grace(&self, grace: Duration) {
        self.grace_nanos
            .store(grace.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    pub fn grace(&self) -> Duration {
        Duration::from_nanos(self.grace_nanos.load(Ordering::Relaxed))
    }

    /// Entries currently held (consumed and expired entries linger as
    /// tombstones so repeat stale frees stay deterministic).
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Publish `old → to`. Called by migration *before* the source page
    /// is freed, so a racing stale free can never fall in the gap.
    /// Refuses (returns `false`, changing nothing) when a **live**
    /// entry — unconsumed and inside the grace window — already exists
    /// for `old`: that means another migration already moved this name,
    /// and clobbering its entry would leak the winner's copy. Dead
    /// tombstones (consumed or expired) are replaced.
    fn try_insert(&self, old: u32, to: GlobalAddr) -> bool {
        let grace = self.grace();
        let mut m = self.map.write().unwrap();
        if let Some(e) = m.get(&old) {
            if !e.consumed && e.at.elapsed() <= grace {
                return false;
            }
        }
        m.insert(old, ForwardEntry { to, at: Instant::now(), consumed: false });
        self.active.store(true, Ordering::Release);
        true
    }

    /// Roll back an entry whose migration lost the race to a concurrent
    /// client free (the client freed the original, so there is nothing
    /// left to forward).
    fn remove(&self, old: u32) {
        let mut m = self.map.write().unwrap();
        m.remove(&old);
        self.active.store(!m.is_empty(), Ordering::Release);
    }

    /// Undo a consumption whose forwarded free never executed (e.g. the
    /// submit was rejected because the forwarded-to member retired):
    /// the one permitted forward must not be burned by a free that
    /// freed nothing.
    pub fn unconsume(&self, raw: u32) {
        if let Some(e) = self.map.write().unwrap().get_mut(&raw) {
            e.consumed = false;
        }
    }

    /// The free-path probe: forward at most once, inside the grace
    /// window; stale thereafter.
    pub fn lookup(&self, raw: u32) -> ForwardVerdict {
        if !self.is_active() {
            return ForwardVerdict::Miss;
        }
        let grace = self.grace();
        {
            let m = self.map.read().unwrap();
            match m.get(&raw) {
                None => return ForwardVerdict::Miss,
                Some(e) if e.consumed || e.at.elapsed() > grace => {
                    return ForwardVerdict::Stale;
                }
                Some(_) => {}
            }
        }
        // Upgrade to consume; re-check, another free may have won.
        let mut m = self.map.write().unwrap();
        match m.get_mut(&raw) {
            None => ForwardVerdict::Miss,
            Some(e) if e.consumed || e.at.elapsed() > grace => {
                ForwardVerdict::Stale
            }
            Some(e) => {
                e.consumed = true;
                ForwardVerdict::Forward(e.to)
            }
        }
    }

    /// Kill every entry whose old name, or forwarded-to address, is in
    /// `minted` — those names were just re-issued by fresh allocations,
    /// and forwarding through them would free someone else's memory.
    /// The same sweep prunes dead tombstones (entries past the grace
    /// window, which can never forward again) and clears the fast-path
    /// flag once the table empties, so a service that failed over once
    /// does not pay an ever-growing scan on every later alloc batch.
    pub fn invalidate_reused(&self, minted: &[u32]) {
        if minted.is_empty() || !self.is_active() {
            return;
        }
        let grace = self.grace();
        let set: HashSet<u32> = minted.iter().copied().collect();
        // Probe under the shared read lock first: in the common case
        // (no intersection, nothing expired) concurrent lane workers
        // must not serialize on the write lock just to discover there
        // is nothing to do.
        {
            let m = self.map.read().unwrap();
            let dirty = m.iter().any(|(old, e)| {
                set.contains(old)
                    || set.contains(&e.to.raw())
                    || e.at.elapsed() > grace
            });
            if !dirty {
                return;
            }
        }
        let mut m = self.map.write().unwrap();
        m.retain(|old, e| {
            !set.contains(old)
                && !set.contains(&e.to.raw())
                && e.at.elapsed() <= grace
        });
        self.active.store(!m.is_empty(), Ordering::Release);
    }
}

/// One migrated allocation: where it lived, where it lives now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationRecord {
    pub from: GlobalAddr,
    pub to: GlobalAddr,
}

/// Outcome of [`AllocService::drain_device`].
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// The drained member.
    pub device: usize,
    /// Old→new pairs for every migrated allocation.
    pub migrated: Vec<MigrationRecord>,
    /// Pages that a concurrent client free claimed mid-migration — the
    /// block was already freed, nothing was lost.
    pub skipped_freed: u64,
    /// Pages that could not be placed on any healthy member (target
    /// OOM, or no healthy member left). These remain on the draining
    /// member: retiring it strands them.
    pub failed: u64,
    /// Allocations still marked in flight toward this member when the
    /// quiesce deadline expired. They may land *after* the live-set
    /// enumeration and are therefore not covered by `migrated` /
    /// `skipped_freed` / `failed` — a drain is only "fully rehomed"
    /// when both `failed` and `unquiesced` are zero.
    pub unquiesced: u64,
}

/// Outcome of [`AllocService::retire_device`].
#[derive(Debug, Clone, Copy)]
pub struct RetireReport {
    /// The retired member.
    pub device: usize,
    /// In-flight ops on the member's lanes that were failed with
    /// `DeviceRetired` by the final drain.
    pub failed_inflight: u64,
}

impl AllocService {
    /// This member's failover lifecycle state.
    pub fn device_state(&self, device: usize) -> DeviceState {
        self.inner.router.state(device)
    }

    /// Members currently accepting placements.
    pub fn healthy_devices(&self) -> usize {
        self.inner.router.healthy_count()
    }

    /// Grace window within which a stale free of a migrated address is
    /// forwarded to its new home (exactly once). Beyond it, stale frees
    /// are rejected with a tagged `InvalidFree`.
    pub fn set_forwarding_grace(&self, grace: Duration) {
        self.inner.forwarding.set_grace(grace);
    }

    /// Forwarding entries currently held (incl. consumed tombstones).
    pub fn forwarding_entries(&self) -> usize {
        self.inner.forwarding.len()
    }

    /// Move one allocation onto the healthiest other member (lowest
    /// heap occupancy): copy the payload, free the source page, publish
    /// a forwarding entry for stale frees, and return the new address.
    /// The caller should adopt the returned address; the old one stays
    /// freeable only within the forwarding grace window.
    ///
    /// # Ownership contract
    ///
    /// Like `realloc`, migrating a block on a **healthy** source member
    /// requires that the caller own it: no concurrent free of `addr`
    /// may race this call, because on a healthy member a freed page can
    /// be re-minted to a new owner at any time, and the claim step
    /// cannot distinguish the re-minted page from the original (it
    /// would free the new owner's block). The drain path has no such
    /// caveat — a *draining* source takes no new placements, so pages
    /// freed mid-migration are never re-minted and every interleaving
    /// with concurrent frees is handled (see the module docs).
    pub fn migrate(&self, addr: GlobalAddr) -> Result<GlobalAddr, AllocError> {
        let inner = &self.inner;
        if !addr.device_in(inner.members.len()) {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        let src = addr.device() as usize;
        let n = inner.members.len();
        let mut targets: Vec<usize> = (0..n)
            .filter(|&d| {
                d != src && inner.router.state(d) == DeviceState::Healthy
            })
            .collect();
        targets.sort_by(|&a, &b| {
            let oa = inner.members[a].alloc.heap().occupancy();
            let ob = inner.members[b].alloc.heap().occupancy();
            oa.partial_cmp(&ob).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut last_err = AllocError::DeviceRetired; // no healthy target
        for t in targets {
            match self.migrate_to(addr, t) {
                Ok(new) => return Ok(new),
                // The source page vanished (freed concurrently or
                // invalid): no other target can change that.
                Err(e @ AllocError::InvalidFree(_)) => return Err(e),
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Move one allocation onto a specific healthy member. See
    /// [`AllocService::migrate`] for the semantics; errors are
    /// `InvalidFree` (the address is not a live allocation — possibly
    /// because its owner freed it mid-migration), `DeviceRetired` (the
    /// target is not healthy, or the source is already retired), or the
    /// target allocator's failure (e.g. `OutOfMemory`).
    pub fn migrate_to(
        &self,
        addr: GlobalAddr,
        target: usize,
    ) -> Result<GlobalAddr, AllocError> {
        let inner = &self.inner;
        // One migration at a time (control plane): concurrent drains of
        // the same member enumerate the same bitmap, and without this
        // two of them could race to re-home the same block.
        let _plane = inner.rebalance_lock.lock().unwrap();
        let n = inner.members.len();
        if !addr.device_in(n) {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        let src = addr.device() as usize;
        if target >= n
            || target == src
            || inner.router.state(target) != DeviceState::Healthy
            || inner.router.state(src) == DeviceState::Retired
        {
            return Err(AllocError::DeviceRetired);
        }
        let src_heap = inner.members[src].alloc.heap().clone();
        // Full host-side validation (bounds + chunk ownership +
        // alignment) names the class; the page bit itself is only
        // claimed at step 3.
        let (src_chunk, _) = src_heap
            .check_addr(addr.local())
            .map_err(|_| AllocError::InvalidFree(addr.raw()))?;
        let q = src_heap.header(src_chunk).queue();

        // 1. Allocate a same-class page on the target and copy the
        //    payload device-side. The source data stays intact even if
        //    its owner frees it mid-copy: a draining member takes no
        //    new placements, and on a healthy source the worst case is
        //    copying a freed (but not yet re-minted) page that step 3
        //    then rolls back.
        let tgt = &inner.members[target];
        let tgt_alloc = tgt.alloc.clone();
        let src_heap2 = src_heap.clone();
        let result: Mutex<Option<Result<u32, AllocError>>> = Mutex::new(None);
        let st = tgt.device.launch(
            &format!("service.migrate.q{q}"),
            Grid::new(1),
            |w| {
                let r = tgt_alloc.malloc(&w.ctx, page_size(q)).and_then(|dst| {
                    tgt_alloc
                        .heap()
                        .clone_block(&w.ctx, &src_heap2, addr.local(), dst)
                        .map(|_| dst)
                });
                *result.lock().unwrap() = Some(r);
            },
        );
        inner.stats.device_ns[target]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed);
        let new_local = match result.into_inner().unwrap() {
            Some(Ok(local)) => local,
            Some(Err(e)) => return Err(e),
            None => return Err(AllocError::QueueCorrupt),
        };
        let new = GlobalAddr::new(target as u32, new_local);

        // 2. Publish the forwarding entry *before* claiming the source:
        //    from here on a stale free of `addr` is delivered to `new`.
        //    A refusal means another migration already owns this name
        //    (its entry is live) — back out without touching it.
        if !inner.forwarding.try_insert(addr.raw(), new) {
            let tgt_alloc2 = tgt.alloc.clone();
            let _ = tgt.device.launch(
                "service.migrate.rollback",
                Grid::new(1),
                |w| {
                    let _ = tgt_alloc2.free(&w.ctx, new_local);
                },
            );
            return Err(AllocError::InvalidFree(addr.raw()));
        }

        // 3. Claim the source page by freeing it through its own
        //    allocator. Failure means the owner freed it first — the
        //    migration never happened as far as the world is concerned,
        //    so roll the copy back and drop the entry.
        let src_member = &inner.members[src];
        let src_alloc = src_member.alloc.clone();
        let freed: Mutex<Option<Result<(), AllocError>>> = Mutex::new(None);
        let st = src_member.device.launch(
            &format!("service.migrate.claim.q{q}"),
            Grid::new(1),
            |w| {
                *freed.lock().unwrap() =
                    Some(src_alloc.free(&w.ctx, addr.local()));
            },
        );
        inner.stats.device_ns[src]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed);
        match freed.into_inner().unwrap() {
            Some(Ok(())) => {
                inner.stats.migrations.fetch_add(1, Ordering::Relaxed);
                Ok(new)
            }
            _ => {
                inner.forwarding.remove(addr.raw());
                let _ = tgt.device.launch(
                    "service.migrate.rollback",
                    Grid::new(1),
                    |w| {
                        // Best-effort: the copy was never published, so
                        // nobody else can hold it; tolerate rather than
                        // panic a drain on pathological input.
                        let _ = tgt_alloc.free(&w.ctx, new_local);
                    },
                );
                Err(AllocError::InvalidFree(addr.raw()))
            }
        }
    }

    /// Bulk-migrate a member's whole live set onto the healthy rest of
    /// the group, leaving the member Draining (no new placements; frees
    /// still served) — the precursor to [`AllocService::retire_device`].
    /// Safe under concurrent client traffic: see the module docs for
    /// the quiesce/claim protocol. Errors with `DeviceRetired` if the
    /// member was already retired.
    pub fn drain_device(
        &self,
        device: usize,
    ) -> Result<DrainReport, AllocError> {
        let inner = &self.inner;
        assert!(device < inner.members.len(), "no such group member");
        if !inner.router.mark_draining(device) {
            return Err(AllocError::DeviceRetired);
        }
        // Quiesce: every alloc ever placed on this member must have hit
        // the heap before the live set is enumerated. Bounded wait — a
        // wedged lane surfaces as a non-zero `unquiesced` count in the
        // report instead of hanging the drain forever.
        let deadline = Instant::now() + Duration::from_secs(5);
        while inner.alloc_inflight[device].load(Ordering::SeqCst) != 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_micros(100));
        }

        let heap = inner.members[device].alloc.heap().clone();
        let mut report = DrainReport {
            device,
            migrated: Vec::new(),
            skipped_freed: 0,
            failed: 0,
            unquiesced: inner.alloc_inflight[device].load(Ordering::SeqCst),
        };
        for chunk in 0..heap.num_chunks() {
            let h = heap.header(chunk);
            if h.state() != STATE_OWNED {
                continue; // free, or virtual-queue storage: no client data
            }
            let q = h.queue();
            let bm = h.snapshot_bitmap();
            for page in 0..pages_per_chunk(q) {
                let (w, bit) = ((page / 32) as usize, page % 32);
                if bm[w] & (1u32 << bit) == 0 {
                    continue;
                }
                let old = GlobalAddr::new(
                    device as u32,
                    Heap::addr_of(chunk, q, page),
                );
                match self.migrate(old) {
                    Ok(new) => {
                        report.migrated.push(MigrationRecord { from: old, to: new });
                    }
                    // Claimed by a concurrent client free mid-drain.
                    Err(AllocError::InvalidFree(_)) => report.skipped_freed += 1,
                    Err(_) => report.failed += 1,
                }
            }
        }
        Ok(report)
    }

    /// Kill a member: mark it Retired (all policies skip it; frees
    /// aimed at it are rejected with `DeviceRetired` after the
    /// forwarding table had its say), stop its lanes, fail every
    /// still-queued ticket with the deterministic `DeviceRetired`, and
    /// join its workers. Call [`AllocService::drain_device`] first to
    /// preserve the live set — a direct retire strands it. Idempotent.
    pub fn retire_device(&self, device: usize) -> RetireReport {
        let inner = &self.inner;
        assert!(device < inner.members.len(), "no such group member");
        // Serialised with migrations and other retires: the
        // `failed_inflight` delta over the shared counter below must
        // attribute to this retire alone.
        let _plane = inner.rebalance_lock.lock().unwrap();
        let before = inner.stats.retired_ops.load(Ordering::Relaxed);
        inner.router.mark_draining(device);
        inner.router.mark_retired(device);
        let n = inner.lanes_per_device;
        for lane in device * n..(device + 1) * n {
            // Order matters: workers re-check `retired` per batch, so
            // setting it before the stop means the final drain fails
            // everything still queued instead of dispatching it.
            inner.lanes[lane].retired.store(true, Ordering::Release);
            inner.lanes[lane].batcher.stop();
        }
        let victims: Vec<JoinHandle<()>> = {
            let mut ws = self.workers.lock().unwrap();
            let mut keep = Vec::with_capacity(ws.len());
            let mut take = Vec::new();
            for (lane, handle) in ws.drain(..) {
                if lane / n == device {
                    take.push(handle);
                } else {
                    keep.push((lane, handle));
                }
            }
            *ws = keep;
            take
        };
        for handle in victims {
            let _ = handle.join();
        }
        RetireReport {
            device,
            failed_inflight: inner.stats.retired_ops.load(Ordering::Relaxed)
                - before,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forwarding_forwards_exactly_once_then_stale() {
        let t = ForwardingTable::new();
        assert!(!t.is_active());
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        let new = GlobalAddr::new(1, 0x80);
        assert!(t.try_insert(0x40, new));
        assert!(t.is_active());
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale, "second free");
        assert_eq!(t.lookup(0x44), ForwardVerdict::Miss);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn forwarding_expires_after_grace() {
        let t = ForwardingTable::new();
        t.set_grace(Duration::ZERO);
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
        // A fresh entry under a real grace window still forwards.
        t.set_grace(Duration::from_secs(30));
        assert!(t.try_insert(0x50, GlobalAddr::new(1, 0x90)));
        assert!(matches!(t.lookup(0x50), ForwardVerdict::Forward(_)));
    }

    #[test]
    fn live_entries_refuse_overwrite_dead_ones_replace() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        // A concurrent (losing) migration must not clobber the live
        // entry — its copy would orphan the winner's.
        assert!(!t.try_insert(0x40, GlobalAddr::new(2, 0x90)));
        assert_eq!(
            t.lookup(0x40),
            ForwardVerdict::Forward(GlobalAddr::new(1, 0x80))
        );
        // Consumed: the tombstone is replaceable (the name could only
        // be migrated again after being legitimately re-minted).
        assert!(t.try_insert(0x40, GlobalAddr::new(2, 0x90)));
        assert_eq!(
            t.lookup(0x40),
            ForwardVerdict::Forward(GlobalAddr::new(2, 0x90))
        );
    }

    #[test]
    fn unconsume_restores_the_single_forward() {
        let t = ForwardingTable::new();
        let new = GlobalAddr::new(1, 0x80);
        assert!(t.try_insert(0x40, new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        // The forwarded free never executed (e.g. target retired):
        // restore the one permitted forward.
        t.unconsume(0x40);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
    }

    #[test]
    fn reminted_names_invalidate_entries() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        assert!(t.try_insert(0x50, GlobalAddr::new(1, 0x90)));
        // 0x40 re-minted as a key; the second entry's *target* re-minted.
        t.invalidate_reused(&[0x40, GlobalAddr::new(1, 0x90).raw()]);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        assert_eq!(t.lookup(0x50), ForwardVerdict::Miss);
        assert!(t.is_empty());
        assert!(!t.is_active(), "empty table must clear the fast path");
    }

    #[test]
    fn invalidation_prunes_dead_tombstones() {
        let t = ForwardingTable::new();
        t.set_grace(Duration::ZERO);
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        std::thread::sleep(Duration::from_millis(2));
        t.unconsume(0x40); // no-op on an unconsumed entry
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale); // expired
        // An unrelated alloc batch sweeps it out.
        t.invalidate_reused(&[0x9999]);
        assert!(t.is_empty(), "expired tombstones must not accumulate");
        assert!(!t.is_active());
    }

    #[test]
    fn rollback_remove_clears_entry() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        t.remove(0x40);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        assert!(!t.is_active());
    }
}
