//! Group resilience: the self-healing control plane — failure
//! *detection* (health watchdog), incremental background rebalancing
//! (paced live-set migration), member retirement and **readmit**, plus
//! the stale-free forwarding table underneath it all.
//!
//! PR 3 made the allocation service a device group; PR 4 taught it to
//! survive losing a member under *operator* control. This layer closes
//! the loop: the group now detects a sick member on its own, drains it
//! incrementally while serving traffic, retires it, and can later take
//! the repaired member back.
//!
//! # The member state machine
//!
//! ```text
//!            drain_device /                  retire_device /
//!            begin_drain                     watchdog fire
//! Healthy ────────────────▶ Draining ────────────────▶ Retired
//!    ▲                         │                          │
//!    │  placement: all         │  placement: skipped;     │ readmit_device
//!    │  policies eligible      │  frees and migration     ▼
//!    │                         │  still reach the heap  Readmitting
//!    │                         └──▶ (retire may also hit   │
//!    │                               Healthy directly — a  │ lanes rebuilt,
//!    │                               hard kill that        │ heap asserted
//!    │                               strands whatever was  │ empty
//!    │                               not drained)          │
//!    └─────────────────────────────────────────────────────┘
//! ```
//!
//! * **Healthy** — placeable; allocs and frees flow normally.
//! * **Draining** — no new placements; frees and the migration sweep
//!   still reach the heap. Entered by an operator (`drain_device`,
//!   `begin_drain`) or by the watchdog when a member trips its policy.
//! * **Retired** — lanes stopped, workers joined, in-flight ops failed
//!   with the deterministic `DeviceRetired` (queued frees whose blocks
//!   already migrated are *rescued* to the copy instead — see below).
//! * **Readmitting** — the transient repair window: `readmit_device`
//!   asserts the heap live-set is empty, rebuilds the member's rings,
//!   batchers and workers, then flips it Healthy. Under
//!   `RoutePolicy::CapacityAware` the member re-enters *shedding*: it
//!   takes capacity-routed load only once an occupancy probe proves the
//!   heap low.
//!
//! # How detection, pacing and readmit compose (operator walkthrough)
//!
//! The full self-heal cycle, end to end:
//!
//! 1. **Detect.** A [`HealthMonitor`] scores every healthy member from
//!    per-device heartbeats on each poll: lane dispatch-progress
//!    counters vs. *unserved* ring descriptors (claimed-not-completed
//!    ops with no batch progress for [`HealthPolicy::stall_window`] ⇒
//!    *stalled*; served tickets a slow client has not reaped yet never
//!    count as a stall) and the
//!    alloc error rate over [`HealthPolicy::min_ops`]-sized windows
//!    (≥ [`HealthPolicy::error_rate`] ⇒ *error storm*). A bad verdict
//!    must persist for [`HealthPolicy::probation`] before the monitor
//!    acts — one noisy sample never kills a member. Drive polls from a
//!    background thread ([`AllocService::spawn_watchdog`]) in
//!    production, or deterministically from a test via
//!    [`HealthMonitor::poll_once`] with a [`FakeClock`].
//! 2. **Drain, paced.** The tripped member is marked Draining
//!    ([`AllocService::begin_drain`], quiescing the in-flight-alloc
//!    gauge up to [`HealthPolicy::quiesce`] — a wedged member surfaces
//!    as a non-zero `unquiesced` count instead of hanging the
//!    watchdog), then its live set is migrated **incrementally**:
//!    each [`AllocService::drain_tick`] moves at most
//!    [`DrainPacing::blocks_per_tick`] blocks from a persistent
//!    per-member cursor, under the rebalance lock, and yields
//!    ([`DrainPacing::tick_pause`]) so client traffic interleaves.
//!    The cursor survives interruption: a later tick — or a later
//!    paced drain — resumes where the sweep stopped.
//!    [`AllocService::drain_device`] remains the stop-the-world
//!    baseline (one unbounded tick).
//! 3. **Retire.** After the sweep the controller waits for the
//!    member's rings to go quiet ([`AllocService::wait_lanes_quiet`],
//!    an event-driven condvar wait, not a poll) and calls
//!    `retire_device`: routing drops the member everywhere, its
//!    batchers stop, and the workers' final drain fails still-queued
//!    ops with `DeviceRetired` — except queued *frees* whose block the
//!    drain already moved, which are delivered to the migrated copy
//!    (the service accepted them before the retire; losing them would
//!    leak the copy).
//! 4. **Readmit.** Once repaired, [`AllocService::readmit_device`]
//!    takes the member back: only from Retired (double readmits and
//!    readmit-while-draining are refused with
//!    [`AllocError::ReadmitRefused`]), and only after asserting the
//!    heap live-set is **empty** — the member's address window is
//!    re-minted, so stranded blocks would alias fresh names. Lanes get
//!    new rings/batchers/workers, every `RoutePolicy` sees the member
//!    again (CapacityAware starts it shed until occupancy proves
//!    otherwise), and stale forwarding entries keyed in the window die
//!    naturally when fresh allocations re-mint their names.
//!
//! # Forwarding (stale frees of migrated addresses)
//!
//! A client holding a migrated address does not know it moved. The
//! verdict for its free is decided **exactly once, at submit**:
//! forwarded to the new home if an unconsumed entry is inside the
//! grace window ([`AllocService::set_forwarding_grace`]), rejected with
//! a tagged `InvalidFree` after. The verdict travels on the ring
//! descriptor (`Payload::ForwardedFree`), so dispatch never re-probes
//! the window — re-probing was a TOCTOU where the grace could expire
//! between submit and dispatch and fail an op the service had already
//! accepted. The *other* direction — a free accepted **before** its
//! block migrated, parked in a lane while the drain claimed the block —
//! is rescued at dispatch through the grace-exempt
//! [`ForwardingTable::take_queued`]: such an op was never "stale" in
//! the client-visible sense, it merely raced the drain. Unconsumed
//! entries are therefore retained past the client grace window (by
//! `QUEUED_RETENTION`) so a parked op can still find its entry.
//!
//! The drain protocol against concurrent client traffic:
//!
//! 1. mark the member Draining — no *new* allocs are placed on it (the
//!    submit path re-checks the state after its ring claim, so a
//!    placement that raced the mark backs out and re-routes);
//! 2. quiesce — wait until the member's in-flight-alloc gauge reaches
//!    zero, so every allocation ever placed on it has hit its heap;
//! 3. enumerate the live set from the heap's chunk-occupancy bitmaps
//!    (exact now: placements stopped, in-flight allocs landed; only
//!    concurrent *frees* can still race, and they only clear bits);
//! 4. migrate each page: allocate + copy on a healthy member, publish
//!    the forwarding entry, then **claim** the source page by freeing
//!    it. A concurrent client free of the same page lands in exactly
//!    one of three windows: before the entry exists and before our
//!    claim (our claim fails ⇒ roll the copy back, drop the entry);
//!    after the entry is published, at submit time (⇒ forwarded to the
//!    new address); or **already queued in the member's lanes** when
//!    the claim wins — that free finds the page gone at dispatch (or
//!    the lane retired) and is delivered to the migrated copy via
//!    `take_queued`. Every path frees the block exactly once, on
//!    exactly one member.
//!
//! A forwarding entry dies early if its old name — or the new address
//! it points to — is re-minted by a later allocation (the service's
//! dispatch path invalidates re-used names), so a stale free can never
//! be forwarded into somebody else's allocation.
//!
//! # Durability (surviving a service restart)
//!
//! The forwarding table and the per-member drain cursors are the only
//! control-plane state that *must* outlive the service process: lose
//! the table across a restart and every stale name a client still
//! holds becomes a lost block; lose the cursors and an interrupted
//! paced drain re-enumerates (or worse, skips) part of the live set.
//! Both therefore export to a versioned, checksummed snapshot
//! (`coordinator/snapshot.rs` — format spec lives there) via
//! [`ForwardingTable::export`] / [`ForwardingTable::restore`] and the
//! service-level `AllocService::prepare_handoff` /
//! `AllocService::start_group_restored` pair. Entry timestamps are
//! serialized as **ages** (nanoseconds already elapsed), so a restored
//! entry resumes its grace countdown where it left off rather than
//! getting a fresh window. The restart runbook is in
//! `coordinator/federation.rs`.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::check::history::{OpKind, OpRecord};
use crate::check::lockgraph::{classes, OrderedMutex, OrderedRwLock};
use crate::ouroboros::chunk::STATE_OWNED;
use crate::ouroboros::params::{page_size, pages_per_chunk};
use crate::ouroboros::{AllocError, GlobalAddr, Heap};
use crate::simt::Grid;

use super::router::DeviceState;
use super::service::{AllocService, Inner};

/// Default grace window for forwarding stale frees of migrated
/// addresses (override per service with
/// [`AllocService::set_forwarding_grace`]).
pub const DEFAULT_FORWARD_GRACE: Duration = Duration::from_secs(5);

/// Extra retention, beyond the client-facing grace window, for
/// **unconsumed** forwarding entries: a free the service accepted
/// *before* its block migrated may sit queued in a lane (batcher
/// window, or a stalled member's whole detection-to-retire cycle) and
/// must still find its entry at dispatch time. Only after this much
/// additional age may a sweep reclaim an unconsumed entry.
const QUEUED_RETENTION: Duration = Duration::from_secs(5);

/// What the forwarding table says about a submitted free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForwardVerdict {
    /// Not a migrated address: route normally.
    Miss,
    /// Migrated, inside the grace window, first free: deliver to the
    /// new address instead.
    Forward(GlobalAddr),
    /// Migrated but already forwarded once, or the grace window
    /// elapsed: reject with a tagged `InvalidFree`.
    Stale,
}

#[derive(Debug, Clone, Copy)]
struct ForwardEntry {
    to: GlobalAddr,
    at: Instant,
    consumed: bool,
}

/// Old→new address map for migrated allocations. Read-mostly: the free
/// submit path takes the read lock only while the table is non-empty
/// (one relaxed flag probe otherwise), and only upgrades to the write
/// lock to consume a hit.
pub struct ForwardingTable {
    grace_nanos: AtomicU64,
    active: AtomicBool,
    map: OrderedRwLock<HashMap<u32, ForwardEntry>>,
}

impl Default for ForwardingTable {
    fn default() -> Self {
        Self::new()
    }
}

impl ForwardingTable {
    pub fn new() -> Self {
        ForwardingTable {
            grace_nanos: AtomicU64::new(DEFAULT_FORWARD_GRACE.as_nanos() as u64),
            active: AtomicBool::new(false),
            map: OrderedRwLock::new(&classes::FORWARDING, HashMap::new()),
        }
    }

    /// Whether any entry was ever published (the free path's fast-path
    /// gate: a service that never migrated pays one relaxed load).
    pub fn is_active(&self) -> bool {
        // ordering: advisory fast-path gate; table mutex is the sync
        self.active.load(Ordering::Relaxed)
    }

    pub fn set_grace(&self, grace: Duration) {
        self.grace_nanos
            // ordering: standalone tunable; no paired state
            .store(grace.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    pub fn grace(&self) -> Duration {
        // ordering: standalone tunable; no paired state
        Duration::from_nanos(self.grace_nanos.load(Ordering::Relaxed))
    }

    /// Entries currently held (consumed and expired entries linger as
    /// tombstones so repeat stale frees stay deterministic).
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Publish `old → to`. Called by migration *before* the source page
    /// is freed, so a racing stale free can never fall in the gap.
    /// Refuses (returns `false`, changing nothing) when a **live**
    /// entry — unconsumed and inside its retention window — already
    /// exists for `old`: that means another migration already moved
    /// this name, and clobbering its entry would leak the winner's
    /// copy. Dead tombstones (consumed or long-expired) are replaced.
    fn try_insert(&self, old: u32, to: GlobalAddr) -> bool {
        let keep = self.grace() + QUEUED_RETENTION;
        let mut m = self.map.write().unwrap();
        if let Some(e) = m.get(&old) {
            if !e.consumed && e.at.elapsed() <= keep {
                return false;
            }
        }
        m.insert(old, ForwardEntry { to, at: Instant::now(), consumed: false });
        // ordering: Release after the mutexed table update
        self.active.store(true, Ordering::Release);
        true
    }

    /// Roll back an entry whose migration lost the race to a concurrent
    /// client free (the client freed the original, so there is nothing
    /// left to forward).
    fn remove(&self, old: u32) {
        let mut m = self.map.write().unwrap();
        m.remove(&old);
        // ordering: Release after the mutexed table update
        self.active.store(!m.is_empty(), Ordering::Release);
    }

    /// Undo a consumption whose forwarded free never executed (e.g. the
    /// submit was rejected because the forwarded-to member retired):
    /// the one permitted forward must not be burned by a free that
    /// freed nothing.
    pub fn unconsume(&self, raw: u32) {
        if let Some(e) = self.map.write().unwrap().get_mut(&raw) {
            e.consumed = false;
        }
    }

    /// The free-path probe: forward at most once, inside the grace
    /// window; stale thereafter. This is the **client-facing** verdict,
    /// decided at submit and carried on the descriptor from there.
    pub fn lookup(&self, raw: u32) -> ForwardVerdict {
        if !self.is_active() {
            return ForwardVerdict::Miss;
        }
        let grace = self.grace();
        {
            let m = self.map.read().unwrap();
            match m.get(&raw) {
                None => return ForwardVerdict::Miss,
                Some(e) if e.consumed || e.at.elapsed() > grace => {
                    return ForwardVerdict::Stale;
                }
                Some(_) => {}
            }
        }
        // Upgrade to consume; re-check, another free may have won.
        let mut m = self.map.write().unwrap();
        match m.get_mut(&raw) {
            None => ForwardVerdict::Miss,
            Some(e) if e.consumed || e.at.elapsed() > grace => {
                ForwardVerdict::Stale
            }
            Some(e) => {
                e.consumed = true;
                ForwardVerdict::Forward(e.to)
            }
        }
    }

    /// Dispatch-time probe for a free the service **accepted before its
    /// block migrated** (the op was already parked in the owner's lane
    /// when the drain claimed the page). The accept decision predates
    /// the entry, so the client grace window deliberately does *not*
    /// apply — forward if an unconsumed entry exists, whatever its age,
    /// consuming it (exactly-once still holds: a name's one forward
    /// goes either to the submit path or to the queued op, never both).
    pub fn take_queued(&self, raw: u32) -> Option<GlobalAddr> {
        if !self.is_active() {
            return None;
        }
        let mut m = self.map.write().unwrap();
        match m.get_mut(&raw) {
            Some(e) if !e.consumed => {
                e.consumed = true;
                Some(e.to)
            }
            _ => None,
        }
    }

    /// Kill every entry whose old name, or forwarded-to address, is in
    /// `minted` — those names were just re-issued by fresh allocations,
    /// and forwarding through them would free someone else's memory.
    /// The same sweep prunes dead tombstones — consumed entries past
    /// the grace window, and unconsumed ones past the extended
    /// `QUEUED_RETENTION` (an unconsumed entry may still owe a rescue
    /// to a parked free, so it outlives the client window) — and clears
    /// the fast-path flag once the table empties, so a service that
    /// failed over once does not pay an ever-growing scan on every
    /// later alloc batch.
    pub fn invalidate_reused(&self, minted: &[u32]) {
        if minted.is_empty() || !self.is_active() {
            return;
        }
        let grace = self.grace();
        let dead = |e: &ForwardEntry| {
            if e.consumed {
                e.at.elapsed() > grace
            } else {
                e.at.elapsed() > grace + QUEUED_RETENTION
            }
        };
        let set: HashSet<u32> = minted.iter().copied().collect();
        // Probe under the shared read lock first: in the common case
        // (no intersection, nothing expired) concurrent lane workers
        // must not serialize on the write lock just to discover there
        // is nothing to do.
        {
            let m = self.map.read().unwrap();
            let dirty = m.iter().any(|(old, e)| {
                set.contains(old) || set.contains(&e.to.raw()) || dead(e)
            });
            if !dirty {
                return;
            }
        }
        let mut m = self.map.write().unwrap();
        m.retain(|old, e| {
            !set.contains(old) && !set.contains(&e.to.raw()) && !dead(e)
        });
        // ordering: Release after the mutexed table update
        self.active.store(!m.is_empty(), Ordering::Release);
    }

    /// Durable view of the table for a restart snapshot: every entry
    /// with its age (elapsed nanoseconds, not a wall-clock instant — a
    /// restored table must resume each grace countdown, not restart
    /// it). Consumed tombstones are included so forwarded-exactly-once
    /// survives the restart: dropping them would re-arm a name that
    /// already spent its one forward.
    pub fn export(&self) -> Vec<ForwardExport> {
        let m = self.map.read().unwrap();
        m.iter()
            .map(|(&old, e)| ForwardExport {
                old,
                to: e.to,
                age_nanos: e.at.elapsed().as_nanos().min(u64::MAX as u128) as u64,
                consumed: e.consumed,
            })
            .collect()
    }

    /// Rebuild the table from a snapshot's exported entries. Each age
    /// is re-anchored against the current instant; entries already past
    /// the full retention horizon (grace + queued retention) are
    /// dropped on the floor — they could never forward again. Replaces
    /// whatever the table held (restore targets a freshly started
    /// service).
    pub fn restore(&self, entries: &[ForwardExport]) {
        let keep = self.grace() + QUEUED_RETENTION;
        let now = Instant::now();
        let mut m = self.map.write().unwrap();
        m.clear();
        for e in entries {
            let age = Duration::from_nanos(e.age_nanos);
            if !e.consumed && age > keep {
                continue;
            }
            if e.consumed && age > self.grace() {
                continue;
            }
            // An Instant can't always rewind past process start; when
            // checked_sub fails the entry is treated as freshly minted.
            // That can only *lengthen* a grace window — exactly-once is
            // carried by `consumed`, which is preserved verbatim, so a
            // spent forward can never re-arm.
            let at = now.checked_sub(age).unwrap_or(now);
            m.insert(e.old, ForwardEntry { to: e.to, at, consumed: e.consumed });
        }
        // ordering: Release after the mutexed table update
        self.active.store(!m.is_empty(), Ordering::Release);
    }
}

/// One forwarding entry as exported for a durability snapshot: the old
/// (pre-migration) raw name, the address its one permitted free
/// forwards to, how long the entry had already existed at export time,
/// and whether its forward was already consumed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ForwardExport {
    pub old: u32,
    pub to: GlobalAddr,
    pub age_nanos: u64,
    pub consumed: bool,
}

/// One migrated allocation: where it lived, where it lives now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationRecord {
    pub from: GlobalAddr,
    pub to: GlobalAddr,
}

/// Outcome of [`AllocService::drain_device`] /
/// [`AllocService::drain_device_paced`].
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// The drained member.
    pub device: usize,
    /// Old→new pairs for every migrated allocation.
    pub migrated: Vec<MigrationRecord>,
    /// Pages that a concurrent client free claimed mid-migration — the
    /// block was already freed, nothing was lost.
    pub skipped_freed: u64,
    /// Pages that could not be placed on any healthy member (target
    /// OOM, or no healthy member left). These remain on the draining
    /// member: retiring it strands them.
    pub failed: u64,
    /// Allocations still marked in flight toward this member when the
    /// quiesce deadline expired. They may land *after* the live-set
    /// enumeration and are therefore not covered by `migrated` /
    /// `skipped_freed` / `failed` — a drain is only "fully rehomed"
    /// when both `failed` and `unquiesced` are zero. (Ops parked on a
    /// *stalled* member never land at all: the retire fails them and
    /// releases the gauge.)
    pub unquiesced: u64,
}

/// One increment of a paced drain: what [`AllocService::drain_tick`]
/// did this tick.
#[derive(Debug, Clone)]
pub struct DrainTick {
    /// Old→new pairs migrated this tick.
    pub migrated: Vec<MigrationRecord>,
    /// Live bits that vanished under a concurrent client free.
    pub skipped_freed: u64,
    /// Blocks that could not be placed on any healthy member.
    pub failed: u64,
    /// The persistent cursor swept past the end of the heap: the live
    /// set is fully enumerated and no further ticks are needed.
    pub complete: bool,
}

/// Pacing for incremental background rebalancing: each tick migrates at
/// most `blocks_per_tick` live blocks, then the driver sleeps
/// `tick_pause` so client traffic interleaves with the sweep.
#[derive(Debug, Clone, Copy)]
pub struct DrainPacing {
    /// Maximum live blocks handled per [`AllocService::drain_tick`].
    pub blocks_per_tick: usize,
    /// Pause between ticks (client traffic runs unimpeded meanwhile).
    pub tick_pause: Duration,
}

impl Default for DrainPacing {
    fn default() -> Self {
        DrainPacing {
            blocks_per_tick: 32,
            tick_pause: Duration::from_micros(500),
        }
    }
}

/// Persistent paced-drain position for one member: the incremental
/// sweep resumes here after an interrupted tick sequence. Lives in the
/// service's `Inner` so the cursor survives whichever controller —
/// operator call, watchdog, test — drives the ticks.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct DrainCursor {
    chunk: u32,
    page: u32,
    /// The sweep ran off the end of the heap: the drain is complete
    /// until the cursor is reset (fresh drain or readmit).
    exhausted: bool,
}

impl DrainCursor {
    /// Snapshot view: `(chunk, page, exhausted)`.
    pub(crate) fn parts(self) -> (u32, u32, bool) {
        (self.chunk, self.page, self.exhausted)
    }

    /// Rebuild a cursor from its snapshotted parts (restart restore).
    pub(crate) fn from_parts(chunk: u32, page: u32, exhausted: bool) -> Self {
        DrainCursor { chunk, page, exhausted }
    }
}

/// Outcome of [`AllocService::retire_device`].
#[derive(Debug, Clone, Copy)]
pub struct RetireReport {
    /// The retired member.
    pub device: usize,
    /// In-flight ops on the member's lanes that were failed with
    /// `DeviceRetired` by the final drain (rescued frees — queued frees
    /// delivered to their migrated copies — are not failures and are
    /// not counted here).
    pub failed_inflight: u64,
}

/// Outcome of [`AllocService::readmit_device`].
#[derive(Debug, Clone, Copy)]
pub struct ReadmitReport {
    /// The readmitted member.
    pub device: usize,
    /// Lanes whose rings, batchers and workers were rebuilt.
    pub lanes: usize,
}

/// Quiesce deadline for the drain entry points (how long to wait for
/// in-flight allocs to land before enumerating the live set), read
/// from `OURO_DRAIN_QUIESCE_MS` (default 5000 ms) so loaded CI — or an
/// operator who knows the member is wedged — can tune it without a
/// rebuild.
pub fn drain_quiesce_timeout() -> Duration {
    let ms = std::env::var("OURO_DRAIN_QUIESCE_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5_000u64);
    Duration::from_millis(ms)
}

// ---------------------------------------------------------------------------
// Control plane on Inner: shared by the owning AllocService handle and
// the health watchdog's background thread (which holds only Arc<Inner>).
// ---------------------------------------------------------------------------

impl Inner {
    /// Target selection + single-block migration, **assuming the
    /// rebalance lock is already held** by the caller.
    fn migrate_unlocked(&self, addr: GlobalAddr) -> Result<GlobalAddr, AllocError> {
        if !addr.device_in(self.members.len()) {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        let src = addr.device() as usize;
        let n = self.members.len();
        let mut targets: Vec<usize> = (0..n)
            .filter(|&d| {
                d != src && self.router.state(d) == DeviceState::Healthy
            })
            .collect();
        targets.sort_by(|&a, &b| {
            let oa = self.members[a].alloc.heap().occupancy();
            let ob = self.members[b].alloc.heap().occupancy();
            oa.partial_cmp(&ob).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut last_err = AllocError::DeviceRetired; // no healthy target
        for t in targets {
            match self.migrate_to_unlocked(addr, t) {
                Ok(new) => return Ok(new),
                // The source page vanished (freed concurrently or
                // invalid): no other target can change that.
                Err(e @ AllocError::InvalidFree(_)) => return Err(e),
                Err(e) => last_err = e,
            }
        }
        Err(last_err)
    }

    /// Move one allocation onto a specific healthy member, **assuming
    /// the rebalance lock is already held**. See
    /// [`AllocService::migrate`] for semantics.
    fn migrate_to_unlocked(
        &self,
        addr: GlobalAddr,
        target: usize,
    ) -> Result<GlobalAddr, AllocError> {
        let n = self.members.len();
        if !addr.device_in(n) {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        let src = addr.device() as usize;
        if target >= n
            || target == src
            || self.router.state(target) != DeviceState::Healthy
            || self.router.state(src) == DeviceState::Retired
        {
            return Err(AllocError::DeviceRetired);
        }
        let src_heap = self.members[src].alloc.heap().clone();
        // Full host-side validation (bounds + chunk ownership +
        // alignment) names the class; the page bit itself is only
        // claimed at step 3.
        let (src_chunk, _) = src_heap
            .check_addr(addr.local())
            .map_err(|_| AllocError::InvalidFree(addr.raw()))?;
        let q = src_heap.header(src_chunk).queue();
        // OURO_LIN: stamp the invocation before the lease lookup so a
        // recorded recall always overlaps any racing return it spins
        // out (a wider interval only weakens ordering constraints —
        // sound for the checker, never a false positive).
        let lin_inv = super::ring::mono_ns();

        // A leased span is client-cache state, not just a live block:
        // recall the lease first (the SeqCst pin/recall handshake in
        // `super::lease` spins out in-flight serves), then move the
        // payload and re-home the lease below. Origin-named cached
        // blocks keep resolving through the registry; the span's own
        // stale name is covered by the forwarding entry like any other
        // migrated block.
        let lease = self
            .leases
            .lookup(src as u32, src_chunk)
            .filter(|l| l.current_span() == addr && !l.is_dead() && !l.is_finalized());
        if let Some(l) = &lease {
            if self.router.state(src) != DeviceState::Draining {
                // A leased span only moves as part of a drain. A
                // healthy source keeps placing allocations, so it
                // could re-mint the origin chunk this relocation frees
                // — and the lease serves origin-based names out of
                // that chunk for its whole life. Draining members take
                // no placements, and readmission refuses while any
                // lease still names the window (`names_device`), so
                // the drain-only rule keeps origin names unambiguous.
                return Err(AllocError::DeviceRetired);
            }
            self.stats.lease_recalls.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            l.begin_recall();
            if let Some(san) = &self.san {
                san.on_lease_recall(addr);
            }
            if let Some(lin) = &self.lin {
                lin.record(OpRecord {
                    inv_ns: lin_inv,
                    res_ns: super::ring::mono_ns(),
                    client: 0,
                    kind: OpKind::LeaseRecall,
                    device: src as u32,
                    class: q as u32,
                    addr: addr.raw(),
                    lease_id: l.id(),
                });
            }
        }

        // 1. Allocate a same-class page on the target and copy the
        //    payload device-side. The source data stays intact even if
        //    its owner frees it mid-copy: a draining member takes no
        //    new placements, and on a healthy source the worst case is
        //    copying a freed (but not yet re-minted) page that step 3
        //    then rolls back.
        let tgt = &self.members[target];
        let tgt_alloc = tgt.alloc.clone();
        let src_heap2 = src_heap.clone();
        let result: OrderedMutex<Option<Result<u32, AllocError>>> =
            OrderedMutex::new(&classes::LAUNCH_RESULT, None);
        let st = tgt.device.launch(
            &format!("service.migrate.q{q}"),
            Grid::new(1),
            |w| {
                let r = tgt_alloc.malloc(&w.ctx, page_size(q)).and_then(|dst| {
                    tgt_alloc
                        .heap()
                        .clone_block(&w.ctx, &src_heap2, addr.local(), dst)
                        .map(|_| dst)
                });
                *result.lock().unwrap() = Some(r);
            },
        );
        self.stats.device_ns[target]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed); // ordering: stat counter
        let new_local = match result.into_inner().unwrap() {
            Some(Ok(local)) => local,
            Some(Err(e)) => return Err(e),
            None => return Err(AllocError::QueueCorrupt),
        };
        let new = GlobalAddr::new(target as u32, new_local);
        // Shadow the copy as a mint: until step 3 commits, it is just
        // a fresh allocation on the target (the rollbacks below free
        // it like one). A leased span is tracked in the shadow heap's
        // span table instead — its relocation is recorded wholesale on
        // commit, so no block record is minted here.
        if lease.is_none() {
            if let Some(san) = &self.san {
                san.on_mint(new);
            }
        }

        // 2. Publish the forwarding entry *before* claiming the source:
        //    from here on a stale free of `addr` is delivered to `new`.
        //    A refusal means another migration already owns this name
        //    (its entry is live) — back out without touching it.
        if !self.forwarding.try_insert(addr.raw(), new) {
            let tgt_alloc2 = tgt.alloc.clone();
            let _ = tgt.device.launch(
                "service.migrate.rollback",
                Grid::new(1),
                |w| {
                    let _ = tgt_alloc2.free(&w.ctx, new_local);
                },
            );
            if lease.is_none() {
                if let Some(san) = &self.san {
                    san.on_free(new, target as u32);
                }
            }
            return Err(AllocError::InvalidFree(addr.raw()));
        }

        // 3. Claim the source page by freeing it through its own
        //    allocator. Failure means the owner freed it first — the
        //    migration never happened as far as the world is concerned,
        //    so roll the copy back and drop the entry.
        let src_member = &self.members[src];
        let src_alloc = src_member.alloc.clone();
        let freed: OrderedMutex<Option<Result<(), AllocError>>> =
            OrderedMutex::new(&classes::LAUNCH_RESULT, None);
        let st = src_member.device.launch(
            &format!("service.migrate.claim.q{q}"),
            Grid::new(1),
            |w| {
                *freed.lock().unwrap() =
                    Some(src_alloc.free(&w.ctx, addr.local()));
            },
        );
        self.stats.device_ns[src]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed); // ordering: stat counter
        match freed.into_inner().unwrap() {
            Some(Ok(())) => {
                // The claim committed: the old name is re-homed, not
                // freed — a direct free of it from here on is a bug
                // (forwarded frees are shadowed against `new`).
                let mut as_lease = false;
                if let Some(l) = &lease {
                    // Re-home the lease: cached frees still resolve
                    // through origin-based names, span finalization
                    // now targets `new`, and a later drain of the
                    // *target* finds the lease at its new chunk. A
                    // concurrent finalize can win the span while the
                    // copy was in flight — `relocate` refuses after
                    // the latch; the finalize ring-free then forwards
                    // to the copy, which lives on as a plain block
                    // (minted into the shadow heap here, since step 1
                    // skipped the mint for the lease path).
                    if l.relocate(new) {
                        as_lease = true;
                        self.leases.register_home(l, new);
                        if let Some(san) = &self.san {
                            san.on_lease_relocate(addr, new);
                        }
                    } else if let Some(san) = &self.san {
                        san.on_mint(new);
                    }
                } else if let Some(san) = &self.san {
                    san.on_migrate(addr, new);
                }
                if let Some(lin) = &self.lin {
                    // Partition-local records: the old name leaves the
                    // source heap's partition and the new name joins
                    // the target's. A relocated lease additionally
                    // moves its lease identity (return + carve) so the
                    // lease partitions stay self-contained too.
                    let now = super::ring::mono_ns();
                    let lid = lease.as_ref().map_or(0, |l| l.id());
                    let mut rec = |kind: OpKind, device: u32, a: u32, lease_id: u64| {
                        lin.record(OpRecord {
                            inv_ns: lin_inv,
                            res_ns: now,
                            client: 0,
                            kind,
                            device,
                            class: q as u32,
                            addr: a,
                            lease_id,
                        });
                    };
                    rec(OpKind::MigrateOut, src as u32, addr.raw(), 0);
                    rec(OpKind::MigrateIn, target as u32, new.raw(), 0);
                    if as_lease {
                        rec(OpKind::LeaseReturn, src as u32, addr.raw(), lid);
                        rec(OpKind::LeaseCarve, target as u32, new.raw(), lid);
                    }
                }
                self.stats.migrations.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                Ok(new)
            }
            _ => {
                self.forwarding.remove(addr.raw());
                let _ = tgt.device.launch(
                    "service.migrate.rollback",
                    Grid::new(1),
                    |w| {
                        // Best-effort: the copy was never published, so
                        // nobody else can hold it; tolerate rather than
                        // panic a drain on pathological input.
                        let _ = tgt_alloc.free(&w.ctx, new_local);
                    },
                );
                if lease.is_none() {
                    if let Some(san) = &self.san {
                        san.on_free(new, target as u32);
                    }
                }
                Err(AllocError::InvalidFree(addr.raw()))
            }
        }
    }

    /// Mark `device` Draining and quiesce its in-flight-alloc gauge
    /// (bounded by `quiesce`). Returns the residual gauge value — zero
    /// for a clean quiesce. A *fresh* drain (the member was Healthy)
    /// resets the paced-drain cursor; beginning on an already-draining
    /// member resumes its cursor. Errors with `DeviceRetired` for a
    /// retired or readmitting member.
    pub(crate) fn begin_drain(
        &self,
        device: usize,
        quiesce: Duration,
    ) -> Result<u64, AllocError> {
        assert!(device < self.members.len(), "no such group member");
        let fresh = match self.router.begin_draining(device) {
            Some(f) => f,
            None => return Err(AllocError::DeviceRetired),
        };
        if fresh {
            *self.drain_cursors[device].lock().unwrap() =
                DrainCursor::default();
        }
        // Bounded wait — a wedged lane surfaces as a non-zero residual
        // count in the report instead of hanging the controller.
        let deadline = Instant::now() + quiesce;
        // ordering: SeqCst quiesce; pairs with submit gauge raise
        while self.alloc_inflight[device].load(Ordering::SeqCst) != 0
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_micros(100));
        }
        // ordering: SeqCst quiesce; pairs with submit gauge raise
        Ok(self.alloc_inflight[device].load(Ordering::SeqCst))
    }

    /// One paced-drain increment: migrate at most `max_blocks` live
    /// blocks from the member's persistent cursor, under the rebalance
    /// lock. Requires the member to be Draining (`begin_drain` first);
    /// errors with `DeviceRetired` otherwise.
    pub(crate) fn drain_tick(
        &self,
        device: usize,
        max_blocks: usize,
    ) -> Result<DrainTick, AllocError> {
        assert!(device < self.members.len(), "no such group member");
        let _plane = self.rebalance_lock.lock().unwrap();
        if self.router.state(device) != DeviceState::Draining {
            return Err(AllocError::DeviceRetired);
        }
        let heap = self.members[device].alloc.heap().clone();
        let mut cur = self.drain_cursors[device].lock().unwrap();
        let mut tick = DrainTick {
            migrated: Vec::new(),
            skipped_freed: 0,
            failed: 0,
            complete: false,
        };
        if cur.exhausted {
            tick.complete = true;
            return Ok(tick);
        }
        let max_blocks = max_blocks.max(1);
        let mut handled = 0usize;
        while cur.chunk < heap.num_chunks() {
            let h = heap.header(cur.chunk);
            if h.state() != STATE_OWNED {
                cur.chunk += 1;
                cur.page = 0;
                continue; // free, or virtual-queue storage: no client data
            }
            let q = h.queue();
            let bm = h.snapshot_bitmap();
            let npages = pages_per_chunk(q);
            while cur.page < npages {
                let page = cur.page;
                cur.page += 1;
                let (w, bit) = ((page / 32) as usize, page % 32);
                if bm[w] & (1u32 << bit) == 0 {
                    continue;
                }
                let old = GlobalAddr::new(
                    device as u32,
                    Heap::addr_of(cur.chunk, q, page),
                );
                match self.migrate_unlocked(old) {
                    Ok(new) => tick
                        .migrated
                        .push(MigrationRecord { from: old, to: new }),
                    // Claimed by a concurrent client free mid-drain.
                    Err(AllocError::InvalidFree(_)) => tick.skipped_freed += 1,
                    Err(_) => tick.failed += 1,
                }
                handled += 1;
                if handled >= max_blocks {
                    // Budget spent: the cursor already points at the
                    // next page, so the next tick resumes exactly here.
                    return Ok(tick);
                }
            }
            cur.chunk += 1;
            cur.page = 0;
        }
        cur.exhausted = true;
        tick.complete = true;
        Ok(tick)
    }

    /// Stop-the-world drain: `begin_drain` + one unbounded tick, always
    /// rescanning from the top of the heap.
    pub(crate) fn drain_device(
        &self,
        device: usize,
    ) -> Result<DrainReport, AllocError> {
        let unquiesced = self.begin_drain(device, drain_quiesce_timeout())?;
        // Full-sweep semantics: a repeated stop-the-world drain re-scans
        // (already-migrated pages have cleared bits, so a rescan is
        // cheap and finds only what is genuinely still live).
        *self.drain_cursors[device].lock().unwrap() = DrainCursor::default();
        let tick = self.drain_tick(device, usize::MAX)?;
        Ok(DrainReport {
            device,
            migrated: tick.migrated,
            skipped_freed: tick.skipped_freed,
            failed: tick.failed,
            unquiesced,
        })
    }

    /// Paced drain: `begin_drain`, then ticks of
    /// `pacing.blocks_per_tick` with `pacing.tick_pause` sleeps in
    /// between, resuming an interrupted sweep from its cursor.
    pub(crate) fn drain_device_paced(
        &self,
        device: usize,
        pacing: DrainPacing,
    ) -> Result<DrainReport, AllocError> {
        let unquiesced = self.begin_drain(device, drain_quiesce_timeout())?;
        {
            let mut cur = self.drain_cursors[device].lock().unwrap();
            if cur.exhausted {
                *cur = DrainCursor::default();
            }
        }
        let mut report = DrainReport {
            device,
            migrated: Vec::new(),
            skipped_freed: 0,
            failed: 0,
            unquiesced,
        };
        loop {
            let tick = self.drain_tick(device, pacing.blocks_per_tick)?;
            report.migrated.extend(tick.migrated);
            report.skipped_freed += tick.skipped_freed;
            report.failed += tick.failed;
            if tick.complete {
                return Ok(report);
            }
            std::thread::sleep(pacing.tick_pause);
        }
    }

    /// Kill a member: see [`AllocService::retire_device`].
    pub(crate) fn retire_device(&self, device: usize) -> RetireReport {
        assert!(device < self.members.len(), "no such group member");
        // Serialised with migrations and other retires: the
        // `failed_inflight` delta over the shared counter below must
        // attribute to this retire alone.
        let _plane = self.rebalance_lock.lock().unwrap();
        // ordering: stat read under the rebalance lock
        let before = self.stats.retired_ops.load(Ordering::Relaxed);
        self.router.mark_draining(device);
        self.router.mark_retired(device);
        let n = self.lanes_per_device;
        for lane in device * n..(device + 1) * n {
            // Order matters: workers re-check `retired` per batch, so
            // setting it before the stop means the final drain fails
            // everything still queued instead of dispatching it.
            // ordering: Release; workers Acquire re-check per batch
            self.lanes[lane].retired.store(true, Ordering::Release);
            self.lanes[lane].batcher.stop();
        }
        let victims: Vec<JoinHandle<()>> = {
            let mut ws = self.workers.lock().unwrap();
            let mut keep = Vec::with_capacity(ws.len());
            let mut take = Vec::new();
            for (lane, handle) in ws.drain(..) {
                if lane / n == device {
                    take.push(handle);
                } else {
                    keep.push((lane, handle));
                }
            }
            *ws = keep;
            take
        };
        for handle in victims {
            let _ = handle.join();
        }
        // Leases whose span currently lives on the dead member die
        // with it: recall each (the owner surrenders it at its next
        // serve) and mark it dead, so every cached block under it
        // answers `DeviceRetired` — the same deterministic verdict as
        // any other address on a retired member. A *relocated* lease's
        // block records carry its origin device, which the shadow
        // heap's device sweep below misses — strand those by name.
        for l in self.leases.leases_on(device as u32) {
            self.stats.lease_recalls.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            l.begin_recall();
            l.mark_dead();
            if let Some(san) = &self.san {
                if l.origin().device() != device as u32 {
                    for i in l.live_block_indices() {
                        san.strand_cached_block(
                            l.block_addr(i),
                            device as u32,
                        );
                    }
                }
            }
        }
        // The lanes are joined: every dispatch-side shadow event for
        // this member has been recorded. Anything still live on a
        // hard-retired member is stranded by decision — frees of it
        // fail `DeviceRetired` and readmission refuses while it exists
        // — so classify it apart from genuine leaks.
        if let Some(san) = &self.san {
            san.on_retire(device as u32);
        }
        RetireReport {
            device,
            // ordering: stat read under the rebalance lock
            failed_inflight: self.stats.retired_ops.load(Ordering::Relaxed)
                - before,
        }
    }

    /// Bring a retired member back: see
    /// [`AllocService::readmit_device`].
    pub(crate) fn readmit_device(
        self: &Arc<Self>,
        device: usize,
    ) -> Result<ReadmitReport, AllocError> {
        assert!(device < self.members.len(), "no such group member");
        let _plane = self.rebalance_lock.lock().unwrap();
        if !self.router.mark_readmitting(device) {
            // Double readmit, readmit of a healthy member, or readmit
            // while a drain is still running.
            return Err(AllocError::ReadmitRefused);
        }
        // The member's address window is re-minted from here on, so the
        // heap live-set must be provably empty: stranded blocks (a hard
        // retire that skipped the drain) would alias fresh names.
        let heap = self.members[device].alloc.heap().clone();
        let mut live = 0u64;
        for chunk in 0..heap.num_chunks() {
            let h = heap.header(chunk);
            if h.state() != STATE_OWNED {
                continue;
            }
            live += h
                .snapshot_bitmap()
                .iter()
                .map(|w| u64::from(w.count_ones()))
                .sum::<u64>();
        }
        if live != 0 {
            // Roll back: the member stays retired, its live set intact.
            self.router.mark_retired(device);
            return Err(AllocError::ReadmitRefused);
        }
        if self.leases.names_device(device) {
            // Some lease — live and relocated away, or dead and
            // stranded — still names this member's address window with
            // origin-based cached blocks. Re-minting the window would
            // alias those names, so the member stays retired until the
            // leases finalize.
            self.router.mark_retired(device);
            return Err(AllocError::ReadmitRefused);
        }
        let n = self.lanes_per_device;
        let wpl = self.policy.workers_per_lane.max(1);
        for lane in device * n..(device + 1) * n {
            let l = &self.lanes[lane];
            l.ring.reopen();
            l.batcher.restart();
            // ordering: Release; lane reset visible to new workers
            l.workers_alive.store(wpl, Ordering::Release);
            l.retired.store(false, Ordering::Release);
        }
        *self.drain_cursors[device].lock().unwrap() = DrainCursor::default();
        // ordering: Release; chaos flag seen by worker Acquire
        self.stall_inject[device].store(false, Ordering::Release);
        {
            let mut ws = self.workers.lock().unwrap();
            for lane in device * n..(device + 1) * n {
                for w in 0..wpl {
                    let inner2 = Arc::clone(self);
                    let l = lane % n;
                    ws.push((
                        lane,
                        std::thread::Builder::new()
                            .name(format!("ouro-alloc-d{device}l{l}w{w}r"))
                            .spawn(move || Inner::run_lane(inner2, lane))
                            .expect("spawning readmitted lane worker"),
                    ));
                }
            }
        }
        // Only now does routing see the member again; CapacityAware
        // re-enters it shedding until an occupancy probe clears it.
        self.router.finish_readmit(device);
        self.stats.readmits.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        Ok(ReadmitReport { device, lanes: n })
    }

    /// Event-driven quiesce over one member's lane rings: wait (condvar,
    /// not a poll) until every ring has zero in-flight descriptors or
    /// `timeout` passes. Returns whether all lanes went quiet.
    pub(crate) fn wait_lanes_quiet(
        &self,
        device: usize,
        timeout: Duration,
    ) -> bool {
        let n = self.lanes_per_device;
        let deadline = Instant::now() + timeout;
        let mut all = true;
        for lane in device * n..(device + 1) * n {
            all &= self.lanes[lane].ring.wait_quiet(deadline);
        }
        all
    }
}

// ---------------------------------------------------------------------------
// Public control-plane API on AllocService.
// ---------------------------------------------------------------------------

impl AllocService {
    /// This member's failover lifecycle state.
    pub fn device_state(&self, device: usize) -> DeviceState {
        self.inner.router.state(device)
    }

    /// Members currently accepting placements.
    pub fn healthy_devices(&self) -> usize {
        self.inner.router.healthy_count()
    }

    /// The capacity-aware shed/readmit thresholds this service routes
    /// by — the federation tier scores whole-group saturation against
    /// the same bands.
    pub fn capacity_hysteresis(&self) -> super::router::CapacityHysteresis {
        self.inner.router.hysteresis()
    }

    /// Grace window within which a stale free of a migrated address is
    /// forwarded to its new home (exactly once). Beyond it, stale frees
    /// are rejected with a tagged `InvalidFree`. The verdict is decided
    /// once, at submit; ops already queued when their block migrates
    /// are grace-exempt (see the module docs).
    pub fn set_forwarding_grace(&self, grace: Duration) {
        self.inner.forwarding.set_grace(grace);
    }

    /// Forwarding entries currently held (incl. consumed tombstones).
    pub fn forwarding_entries(&self) -> usize {
        self.inner.forwarding.len()
    }

    /// Move one allocation onto the healthiest other member (lowest
    /// heap occupancy): copy the payload, free the source page, publish
    /// a forwarding entry for stale frees, and return the new address.
    /// The caller should adopt the returned address; the old one stays
    /// freeable only within the forwarding grace window.
    ///
    /// # Ownership contract
    ///
    /// Like `realloc`, migrating a block on a **healthy** source member
    /// requires that the caller own it: no concurrent free of `addr`
    /// may race this call, because on a healthy member a freed page can
    /// be re-minted to a new owner at any time, and the claim step
    /// cannot distinguish the re-minted page from the original (it
    /// would free the new owner's block). The drain path has no such
    /// caveat — a *draining* source takes no new placements, so pages
    /// freed mid-migration are never re-minted and every interleaving
    /// with concurrent frees is handled (see the module docs).
    pub fn migrate(&self, addr: GlobalAddr) -> Result<GlobalAddr, AllocError> {
        let _plane = self.inner.rebalance_lock.lock().unwrap();
        self.inner.migrate_unlocked(addr)
    }

    /// Move one allocation onto a specific healthy member. See
    /// [`AllocService::migrate`] for the semantics; errors are
    /// `InvalidFree` (the address is not a live allocation — possibly
    /// because its owner freed it mid-migration), `DeviceRetired` (the
    /// target is not healthy, or the source is already retired), or the
    /// target allocator's failure (e.g. `OutOfMemory`).
    pub fn migrate_to(
        &self,
        addr: GlobalAddr,
        target: usize,
    ) -> Result<GlobalAddr, AllocError> {
        // One migration at a time (control plane): concurrent drains of
        // the same member enumerate the same bitmap, and without this
        // two of them could race to re-home the same block.
        let _plane = self.inner.rebalance_lock.lock().unwrap();
        self.inner.migrate_to_unlocked(addr, target)
    }

    /// Mark a member Draining and quiesce its in-flight allocs (bounded
    /// by `quiesce`; the residual gauge value is returned — zero means
    /// clean). The entry point for caller-paced drains: follow with
    /// [`AllocService::drain_tick`] until it reports `complete`.
    pub fn begin_drain(
        &self,
        device: usize,
        quiesce: Duration,
    ) -> Result<u64, AllocError> {
        self.inner.begin_drain(device, quiesce)
    }

    /// One increment of a paced drain: migrate at most `max_blocks`
    /// live blocks from the member's persistent cursor (resumable
    /// across interruptions — the cursor lives with the service, not
    /// the caller). Requires [`AllocService::begin_drain`] first.
    pub fn drain_tick(
        &self,
        device: usize,
        max_blocks: usize,
    ) -> Result<DrainTick, AllocError> {
        self.inner.drain_tick(device, max_blocks)
    }

    /// Bulk-migrate a member's whole live set onto the healthy rest of
    /// the group in one stop-the-world sweep, leaving the member
    /// Draining (no new placements; frees still served) — the precursor
    /// to [`AllocService::retire_device`]. Safe under concurrent client
    /// traffic: see the module docs for the quiesce/claim protocol.
    /// Errors with `DeviceRetired` if the member was already retired.
    /// Prefer [`AllocService::drain_device_paced`] when client traffic
    /// should keep flowing at full rate during the sweep.
    pub fn drain_device(
        &self,
        device: usize,
    ) -> Result<DrainReport, AllocError> {
        self.inner.drain_device(device)
    }

    /// Incremental background drain: like
    /// [`AllocService::drain_device`], but migrating at most
    /// [`DrainPacing::blocks_per_tick`] blocks per tick with
    /// [`DrainPacing::tick_pause`] yields in between, so live traffic
    /// interleaves with the sweep instead of queueing behind one long
    /// stop-the-world pass. Resumes an interrupted sweep from its
    /// persistent cursor.
    pub fn drain_device_paced(
        &self,
        device: usize,
        pacing: DrainPacing,
    ) -> Result<DrainReport, AllocError> {
        self.inner.drain_device_paced(device, pacing)
    }

    /// Kill a member: mark it Retired (all policies skip it; frees
    /// aimed at it are rejected with `DeviceRetired` after the
    /// forwarding table had its say), stop its lanes, fail every
    /// still-queued ticket with the deterministic `DeviceRetired`
    /// (queued frees whose blocks were already migrated are delivered
    /// to the copies instead), and join its workers. Call
    /// [`AllocService::drain_device`] first to preserve the live set —
    /// a direct retire strands it. Idempotent.
    pub fn retire_device(&self, device: usize) -> RetireReport {
        self.inner.retire_device(device)
    }

    /// Take a repaired member back into the group: rebuild its lanes
    /// (fresh rings, restarted batchers, new workers), re-register it
    /// with every `RoutePolicy` (`CapacityAware` starts it shed until
    /// occupancy proves otherwise), and re-mint its address window —
    /// only after asserting the heap live-set is empty. Errors with
    /// [`AllocError::ReadmitRefused`] if the member is not Retired
    /// (double readmit / readmit-while-draining) or stranded live
    /// blocks remain on its heap.
    pub fn readmit_device(
        &self,
        device: usize,
    ) -> Result<ReadmitReport, AllocError> {
        self.inner.readmit_device(device)
    }

    /// Event-driven wait for a member's lane rings to go quiet (all
    /// in-flight ops completed and reaped) — the quiesce step between
    /// drain and retire. Returns whether every lane emptied before
    /// `timeout`.
    pub fn wait_lanes_quiet(&self, device: usize, timeout: Duration) -> bool {
        self.inner.wait_lanes_quiet(device, timeout)
    }

    /// Build a health monitor for this service with an injectable
    /// clock — the deterministic-test constructor (pair with
    /// [`FakeClock`] and drive [`HealthMonitor::poll_once`] by hand).
    pub fn monitor_with_clock(
        &self,
        policy: HealthPolicy,
        clock: Arc<dyn Clock>,
    ) -> HealthMonitor {
        HealthMonitor::new(self.device_count(), policy, clock)
    }

    /// Spawn the watchdog thread: polls the health monitor every
    /// [`HealthPolicy::tick`] on the system clock and auto-heals
    /// tripped members (drain→quiesce→retire, paced per
    /// [`HealthPolicy::pace`]). Stop (or drop) the returned handle
    /// before shutting the service down.
    pub fn spawn_watchdog(&self, policy: HealthPolicy) -> HealthWatchdog {
        let tick = policy.tick;
        let monitor = Arc::new(HealthMonitor::new(
            self.device_count(),
            policy,
            Arc::new(SystemClock::new()),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let inner = self.inner.clone();
        let m2 = monitor.clone();
        let s2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("ouro-health-watchdog".into())
            .spawn(move || {
                while !s2.load(Ordering::Acquire) { // ordering: Acquire; pairs with stop() Release
                    m2.poll_inner(&inner);
                    std::thread::sleep(tick);
                }
            })
            .expect("spawning health watchdog");
        HealthWatchdog { monitor, stop, thread: Some(thread) }
    }
}

// ---------------------------------------------------------------------------
// Health watchdog: automatic failure detection + self-heal.
// ---------------------------------------------------------------------------

/// Monotonic time source for the health monitor. Injectable so tests
/// drive detection deterministically: probation and stall windows are
/// measured on *this* clock, and paced-drain sleeps go through it too
/// (a [`FakeClock`] turns them into instant advances).
pub trait Clock: Send + Sync {
    /// Monotonic elapsed time since an arbitrary epoch.
    fn now(&self) -> Duration;
    /// Sleep (or, for a fake clock, advance) by `d`.
    fn sleep(&self, d: Duration);
}

/// Wall-clock [`Clock`] backed by [`Instant`].
#[derive(Debug)]
pub struct SystemClock {
    epoch: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        SystemClock { epoch: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Deterministic test clock: time moves only when the test says so.
/// `sleep` advances the clock instead of blocking, so a monitor-driven
/// paced drain completes instantly under test while still exercising
/// the pacing arithmetic.
#[derive(Debug, Default)]
pub struct FakeClock {
    nanos: AtomicU64,
}

impl FakeClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(
            d.as_nanos().min(u64::MAX as u128) as u64,
            // ordering: Relaxed; a single monotonic counter read across
            // threads needs atomicity only — nothing else is published
            // with it (audited down from SeqCst).
            Ordering::Relaxed,
        );
    }
}

impl Clock for FakeClock {
    fn now(&self) -> Duration {
        // ordering: Relaxed; same single-counter argument as advance().
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

/// Thresholds for watchdog-driven retirement. All injectable so tests
/// (and differently-loaded deployments) drive detection exactly.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// A member with **unserved** ring descriptors (claimed, not yet
    /// completed — served-but-unreaped tickets don't count) and no
    /// dispatched-batch progress for this long is *stalled*.
    pub stall_window: Duration,
    /// Alloc-error fraction at or above which a window counts as an
    /// *error storm* (e.g. `0.5` = half the window's allocs failed).
    pub error_rate: f64,
    /// Minimum allocs in a window before the error rate is evaluated —
    /// below it the previous verdict carries (one early error must not
    /// read as a 100% failure rate).
    pub min_ops: u64,
    /// How long a bad verdict must persist before the monitor fires —
    /// one noisy poll never retires a member.
    pub probation: Duration,
    /// Watchdog poll cadence ([`AllocService::spawn_watchdog`] mode).
    pub tick: Duration,
    /// Quiesce budget for the auto-drain (in-flight-alloc gauge, then
    /// ring-quiet wait before the retire). A wedged member's parked ops
    /// simply fail at the retire, so this bounds patience, not safety.
    pub quiesce: Duration,
    /// Pacing for the auto-drain's incremental migration.
    pub pace: DrainPacing,
    /// When `false`, the monitor only records trip events (observe
    /// mode); no drain or retire is initiated.
    pub auto_heal: bool,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            stall_window: Duration::from_millis(50),
            error_rate: 0.5,
            min_ops: 64,
            probation: Duration::from_millis(50),
            tick: Duration::from_millis(5),
            quiesce: Duration::from_millis(250),
            pace: DrainPacing::default(),
            auto_heal: true,
        }
    }
}

/// Per-poll health classification of one member.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthVerdict {
    Ok,
    /// Claimed ring descriptors with no dispatch progress past the
    /// stall window.
    Stalled,
    /// Alloc error rate at or above the policy threshold over a full
    /// observation window.
    ErrorStorm,
}

/// What the watchdog did, and when (monitor-clock timestamps).
#[derive(Debug, Clone, PartialEq)]
pub enum HealthEventKind {
    /// The member's bad verdict outlived probation.
    Tripped(HealthVerdict),
    /// The auto-drain finished (paced migration totals).
    Drained { migrated: u64, skipped_freed: u64, failed: u64, unquiesced: u64 },
    /// The member was retired; `failed_inflight` ops got
    /// `DeviceRetired` (rescued frees not included).
    Retired { failed_inflight: u64 },
}

/// One watchdog action, timestamped on the monitor's clock.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthEvent {
    pub device: usize,
    pub kind: HealthEventKind,
    pub at: Duration,
}

/// Per-member detection state between polls.
#[derive(Debug, Clone)]
struct MemberHealth {
    last_batches: u64,
    last_progress: Duration,
    last_allocs: u64,
    last_errors: u64,
    tripped_at: Option<Duration>,
    verdict: HealthVerdict,
}

/// The watchdog's scoring engine: samples per-device heartbeats (lane
/// dispatch-progress counters, alloc error rates, ring-occupancy stall
/// detection), holds bad verdicts through probation, and — in auto-heal
/// mode — runs the drain→quiesce→retire sequence on a member that
/// trips its [`HealthPolicy`]. Drive it from
/// [`AllocService::spawn_watchdog`] (background thread, system clock)
/// or call [`HealthMonitor::poll_once`] yourself with a [`FakeClock`]
/// for deterministic tests.
pub struct HealthMonitor {
    policy: HealthPolicy,
    clock: Arc<dyn Clock>,
    members: OrderedMutex<Vec<MemberHealth>>,
    events: OrderedMutex<Vec<HealthEvent>>,
}

impl HealthMonitor {
    fn new(devices: usize, policy: HealthPolicy, clock: Arc<dyn Clock>) -> Self {
        let now = clock.now();
        HealthMonitor {
            policy,
            clock,
            members: OrderedMutex::new(
                &classes::MONITOR_MEMBERS,
                (0..devices)
                    .map(|_| MemberHealth {
                        last_batches: 0,
                        last_progress: now,
                        last_allocs: 0,
                        last_errors: 0,
                        tripped_at: None,
                        verdict: HealthVerdict::Ok,
                    })
                    .collect(),
            ),
            events: OrderedMutex::new(&classes::MONITOR_EVENTS, Vec::new()),
        }
    }

    /// The thresholds this monitor scores against.
    pub fn policy(&self) -> &HealthPolicy {
        &self.policy
    }

    /// Current monitor-clock time (for callers correlating their own
    /// timestamps — e.g. stall-injection time — with event timestamps).
    pub fn now(&self) -> Duration {
        self.clock.now()
    }

    /// Everything the watchdog has done so far, in order.
    pub fn events(&self) -> Vec<HealthEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Last verdict recorded for `device`.
    pub fn verdict(&self, device: usize) -> HealthVerdict {
        self.members.lock().unwrap()[device].verdict
    }

    fn push_event(&self, device: usize, kind: HealthEventKind) {
        self.events.lock().unwrap().push(HealthEvent {
            device,
            kind,
            at: self.clock.now(),
        });
    }

    /// One watchdog tick against `svc`: score every healthy member and
    /// auto-heal whichever tripped its policy. Deterministic when
    /// driven with a [`FakeClock`]: nothing in here reads wall time
    /// except the bounded quiesce waits.
    pub fn poll_once(&self, svc: &AllocService) {
        self.poll_inner(&svc.inner);
    }

    pub(crate) fn poll_inner(&self, inner: &Arc<Inner>) {
        let p = &self.policy;
        let now = self.clock.now();
        let n_lanes = inner.lanes_per_device;
        let mut fire: Vec<(usize, HealthVerdict)> = Vec::new();
        {
            let mut members = self.members.lock().unwrap();
            for (d, m) in members.iter_mut().enumerate() {
                if inner.router.state(d) != DeviceState::Healthy {
                    m.tripped_at = None;
                    m.verdict = HealthVerdict::Ok;
                    continue;
                }
                // Stall heartbeat: *unserved* descriptors (claimed but
                // not yet completed) with no batch progress. Rings with
                // no unserved work count as progress by definition —
                // completed tickets a slow client has not reaped yet
                // are the client's pace, never a device stall.
                let batches =
                    // ordering: watchdog stat sampling
                    inner.stats.device_batches[d].load(Ordering::Relaxed);
                let unserved: u64 = (d * n_lanes..(d + 1) * n_lanes)
                    .map(|l| inner.lanes[l].ring.unserved())
                    .sum();
                let progressed = unserved == 0 || batches != m.last_batches;
                if progressed {
                    m.last_batches = batches;
                    m.last_progress = now;
                }
                let stalled = !progressed
                    && now.saturating_sub(m.last_progress) >= p.stall_window;
                // Error-rate heartbeat, evaluated over >= min_ops
                // windows; between windows the previous verdict is
                // sticky (a storm cannot hide by going quiet).
                let allocs =
                    // ordering: watchdog stat sampling
                    inner.stats.device_allocs[d].load(Ordering::Relaxed);
                let errors =
                    inner.stats.device_alloc_errors[d].load(Ordering::Relaxed);
                let d_allocs = allocs.saturating_sub(m.last_allocs);
                let d_errors = errors.saturating_sub(m.last_errors);
                let storm = if d_allocs >= p.min_ops {
                    m.last_allocs = allocs;
                    m.last_errors = errors;
                    d_errors as f64 >= p.error_rate * d_allocs as f64
                } else {
                    m.verdict == HealthVerdict::ErrorStorm
                };
                let verdict = if stalled {
                    HealthVerdict::Stalled
                } else if storm {
                    HealthVerdict::ErrorStorm
                } else {
                    HealthVerdict::Ok
                };
                m.verdict = verdict;
                if verdict == HealthVerdict::Ok {
                    m.tripped_at = None;
                } else {
                    let t0 = *m.tripped_at.get_or_insert(now);
                    if now.saturating_sub(t0) >= p.probation {
                        fire.push((d, verdict));
                        // Fresh evidence required for any later trip.
                        m.tripped_at = None;
                    }
                }
            }
        }
        // Heal outside the members lock: a drain can take a while and
        // later polls must not block on it to keep scoring others.
        for (d, verdict) in fire {
            self.push_event(d, HealthEventKind::Tripped(verdict));
            if !p.auto_heal {
                continue;
            }
            let unquiesced = match inner.begin_drain(d, p.quiesce) {
                Ok(u) => u,
                // Lost the race to an operator-driven drain/retire.
                Err(_) => continue,
            };
            let (mut migrated, mut skipped, mut failed) = (0u64, 0u64, 0u64);
            loop {
                match inner.drain_tick(d, p.pace.blocks_per_tick) {
                    Ok(t) => {
                        migrated += t.migrated.len() as u64;
                        skipped += t.skipped_freed;
                        failed += t.failed;
                        if t.complete {
                            break;
                        }
                    }
                    Err(_) => break,
                }
                self.clock.sleep(p.pace.tick_pause);
            }
            self.push_event(
                d,
                HealthEventKind::Drained {
                    migrated,
                    skipped_freed: skipped,
                    failed,
                    unquiesced,
                },
            );
            // Let reapable work clear the rings, then kill. Bounded: a
            // stalled member's parked ops never clear — they fail at
            // the retire instead.
            inner.wait_lanes_quiet(d, p.quiesce);
            let report = inner.retire_device(d);
            self.push_event(
                d,
                HealthEventKind::Retired {
                    failed_inflight: report.failed_inflight,
                },
            );
        }
    }
}

/// Handle to the background watchdog thread spawned by
/// [`AllocService::spawn_watchdog`]. Stops and joins the thread on
/// [`HealthWatchdog::stop`] or drop; stop it before shutting the
/// service down.
pub struct HealthWatchdog {
    monitor: Arc<HealthMonitor>,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl HealthWatchdog {
    /// The monitor driving this watchdog (events, verdicts, clock).
    pub fn monitor(&self) -> &HealthMonitor {
        &self.monitor
    }

    /// Stop the watchdog thread and return everything it did.
    pub fn stop(mut self) -> Vec<HealthEvent> {
        self.halt();
        self.monitor.events()
    }

    fn halt(&mut self) {
        // ordering: Release; pairs with the watchdog Acquire
        self.stop.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HealthWatchdog {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forwarding_forwards_exactly_once_then_stale() {
        let t = ForwardingTable::new();
        assert!(!t.is_active());
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        let new = GlobalAddr::new(1, 0x80);
        assert!(t.try_insert(0x40, new));
        assert!(t.is_active());
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale, "second free");
        assert_eq!(t.lookup(0x44), ForwardVerdict::Miss);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn forwarding_expires_after_grace() {
        let t = ForwardingTable::new();
        t.set_grace(Duration::ZERO);
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
        // A fresh entry under a real grace window still forwards.
        t.set_grace(Duration::from_secs(30));
        assert!(t.try_insert(0x50, GlobalAddr::new(1, 0x90)));
        assert!(matches!(t.lookup(0x50), ForwardVerdict::Forward(_)));
    }

    #[test]
    fn live_entries_refuse_overwrite_dead_ones_replace() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        // A concurrent (losing) migration must not clobber the live
        // entry — its copy would orphan the winner's.
        assert!(!t.try_insert(0x40, GlobalAddr::new(2, 0x90)));
        assert_eq!(
            t.lookup(0x40),
            ForwardVerdict::Forward(GlobalAddr::new(1, 0x80))
        );
        // Consumed: the tombstone is replaceable (the name could only
        // be migrated again after being legitimately re-minted).
        assert!(t.try_insert(0x40, GlobalAddr::new(2, 0x90)));
        assert_eq!(
            t.lookup(0x40),
            ForwardVerdict::Forward(GlobalAddr::new(2, 0x90))
        );
    }

    #[test]
    fn unconsume_restores_the_single_forward() {
        let t = ForwardingTable::new();
        let new = GlobalAddr::new(1, 0x80);
        assert!(t.try_insert(0x40, new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        // The forwarded free never executed (e.g. target retired):
        // restore the one permitted forward.
        t.unconsume(0x40);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
    }

    #[test]
    fn reminted_names_invalidate_entries() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        assert!(t.try_insert(0x50, GlobalAddr::new(1, 0x90)));
        // 0x40 re-minted as a key; the second entry's *target* re-minted.
        t.invalidate_reused(&[0x40, GlobalAddr::new(1, 0x90).raw()]);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        assert_eq!(t.lookup(0x50), ForwardVerdict::Miss);
        assert!(t.is_empty());
        assert!(!t.is_active(), "empty table must clear the fast path");
    }

    #[test]
    fn invalidation_prunes_dead_tombstones() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        // Consume the one forward, then expire the tombstone.
        assert!(matches!(t.lookup(0x40), ForwardVerdict::Forward(_)));
        t.set_grace(Duration::ZERO);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
        // An unrelated alloc batch sweeps it out.
        t.invalidate_reused(&[0x9999]);
        assert!(t.is_empty(), "expired tombstones must not accumulate");
        assert!(!t.is_active());
    }

    /// The TOCTOU satellite, table-level: a free accepted before its
    /// block migrated is grace-exempt at dispatch — the entry must
    /// survive client-window expiry (QUEUED_RETENTION) and still hand
    /// out its one forward via `take_queued`.
    #[test]
    fn queued_rescue_is_grace_exempt_and_exactly_once() {
        let t = ForwardingTable::new();
        t.set_grace(Duration::ZERO);
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        std::thread::sleep(Duration::from_millis(2));
        // Client-facing verdict: expired.
        assert_eq!(t.lookup(0x40), ForwardVerdict::Stale);
        // The sweep must NOT reclaim the unconsumed entry yet.
        t.invalidate_reused(&[0x9999]);
        assert_eq!(t.len(), 1, "unconsumed entry swept before retention");
        // The parked op's rescue still forwards, exactly once.
        assert_eq!(t.take_queued(0x40), Some(GlobalAddr::new(1, 0x80)));
        assert_eq!(t.take_queued(0x40), None, "second rescue must miss");
        // Now consumed + expired: the next sweep reclaims it.
        t.invalidate_reused(&[0x9999]);
        assert!(t.is_empty());
    }

    #[test]
    fn take_queued_never_steals_a_submit_consumed_forward() {
        let t = ForwardingTable::new();
        let new = GlobalAddr::new(1, 0x80);
        assert!(t.try_insert(0x40, new));
        // A stale free already consumed the forward at submit...
        assert_eq!(t.lookup(0x40), ForwardVerdict::Forward(new));
        // ...so a queued op's rescue probe must miss (double free).
        assert_eq!(t.take_queued(0x40), None);
    }

    #[test]
    fn rollback_remove_clears_entry() {
        let t = ForwardingTable::new();
        assert!(t.try_insert(0x40, GlobalAddr::new(1, 0x80)));
        t.remove(0x40);
        assert_eq!(t.lookup(0x40), ForwardVerdict::Miss);
        assert!(!t.is_active());
    }

    #[test]
    fn fake_clock_advances_deterministically() {
        let c = FakeClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(30));
        assert_eq!(c.now(), Duration::from_millis(30));
        // sleep() advances instead of blocking.
        c.sleep(Duration::from_millis(20));
        assert_eq!(c.now(), Duration::from_millis(50));
    }

    #[test]
    fn system_clock_is_monotonic() {
        let c = SystemClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn health_policy_defaults_are_sane() {
        let p = HealthPolicy::default();
        assert!(p.auto_heal);
        assert!(p.error_rate > 0.0 && p.error_rate <= 1.0);
        assert!(p.min_ops > 0);
        assert!(p.stall_window > Duration::ZERO);
        assert!(p.probation > Duration::ZERO);
        assert!(p.pace.blocks_per_tick > 0);
    }

    #[test]
    fn drain_quiesce_timeout_default() {
        // Default (env unset in the test runner) is 5 s.
        if std::env::var("OURO_DRAIN_QUIESCE_MS").is_err() {
            assert_eq!(drain_quiesce_timeout(), Duration::from_secs(5));
        }
    }
}
