//! Cross-group federation: N whole `AllocService` groups behind one
//! thin placement router, with group-tagged addresses, whole-group
//! spillover, durable restart, and automatic failback.
//!
//! The service tier (`service.rs`) scales to one *group* of devices;
//! this tier is the next topology level up — the Intel-SHMEM-shaped
//! symmetric address space that outlives any single member, where the
//! "member" is now an entire allocation service:
//!
//! ```text
//!                     FederationRouter
//!            ┌───────────────┼────────────────┐
//!        group 0          group 1          group 2       (≤ MAX_GROUPS)
//!     AllocService     AllocService     AllocService
//!     ┌──┬──┬──┐       ┌──┬──┐          ┌──┬──┬──┬──┐
//!     d0 d1 d2 …       d0 d1            d0 d1 d2 d3     (≤ MAX_DEVICES)
//!
//!     addr = | group (2 bits) | device (4 bits) | local (26 bits) |
//! ```
//!
//! * **Placement**: each [`FederationClient`] has a primary group
//!   (round-robin at creation). An alloc lands there unless the group
//!   is under *pressure* — retired past quorum
//!   ([`GroupPressure::Exhausted`]) or, under
//!   `RoutePolicy::CapacityAware`, every healthy member already
//!   shedding ([`GroupPressure::Saturated`]) — in which case the
//!   placement **spills** to the next group and the group is latched
//!   spilled. When *every* group is latched, placement water-fills
//!   across all of them rather than refusing service (mirroring the
//!   member-level router).
//! * **Frees route by tag**: [`GlobalAddr::group`] names the owning
//!   group; the federation strips the tag and hands the group-local
//!   address to that service, whatever group the client's primary is.
//!   Each group keeps its own group-local address space (and its own
//!   `OURO_SAN` shadow heap), so cross-group frees stay double-entry
//!   bookkept end to end.
//! * **Failback**: [`FederationRouter::poll_health`] re-probes spilled
//!   groups and un-latches one once it recovers — quorum healthy again
//!   *and* (under CapacityAware) some member's occupancy back under
//!   `readmit_below`, the same hysteresis band the members shed by, so
//!   the latch cannot flap at the shed threshold. Run it from a test
//!   (deterministically, on a [`FakeClock`](super::rebalance::FakeClock))
//!   or via [`FederationRouter::spawn_watchdog`] in production.
//!
//! # Restart runbook (restart-with-live-traffic)
//!
//! A group restart — config change, crash recovery drill, process
//! upgrade — goes through [`FederationRouter::restart_group`]:
//!
//! 1. The group slot's write lock is taken. Client ops on that group
//!    block at the lock (they do not error) — other groups keep
//!    serving.
//! 2. The old service is torn down via `AllocService::prepare_handoff`:
//!    workers drain and join **first**, then the forwarding table
//!    (entry ages, consumed flags), grace, and drain cursors are
//!    snapshotted — so no in-flight dispatch can consume an entry after
//!    the capture. The shadow heap (if armed) is detached and handed
//!    over: blocks that outlive the restart are the payload, not leaks.
//! 3. The rebuild closure constructs the successor — typically
//!    `AllocService::start_group_restored`, which restores the snapshot
//!    so every stale name the old process promised to forward is still
//!    honored, with its grace countdown resumed (not reset).
//! 4. The slot epoch is bumped; clients' cached per-group handles
//!    refresh lazily on their next op. Live blocks, forwarded-
//!    exactly-once, and the sanitizer's address histories all span the
//!    restart — zero lost blocks.
//!
//! For a cross-process restart, persist the snapshot between steps 2
//! and 3 with `ServiceSnapshot::save` / `load` (format spec in
//! `coordinator/snapshot.rs`); a truncated or version-skewed file is
//! rejected wholesale with `AllocError::SnapshotCorrupt` — never a
//! silently empty table.
//!
//! If the rebuild closure fails, the slot is left empty and latched
//! spilled: placement avoids it, frees into it fail with `ServiceDown`,
//! and a later `restart_group` (with a working rebuild) can fill it.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::check::lockgraph::{classes, OrderedMutex, OrderedRwLock};
use crate::ouroboros::addr::MAX_GROUPS;
use crate::ouroboros::{AllocError, GlobalAddr};

use super::rebalance::{Clock, SystemClock};
use super::router::{DeviceState, RoutePolicy};
use super::service::{AllocService, Handoff, ServiceClient};

/// Placement health of one federated group, as scored by the
/// federation's pressure probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupPressure {
    /// Quorum healthy and (under CapacityAware) not all shedding.
    Ok,
    /// Fewer members accepting placements than the federation quorum
    /// (retired/draining past the floor), or the slot is empty after a
    /// failed rebuild.
    Exhausted,
    /// Every placeable member's heap is at/above the shed threshold —
    /// the group would only water-fill, so new load spills instead.
    Saturated,
}

/// What happened, on the federation clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FederationEventKind {
    /// The group was latched away from placement.
    Spilled,
    /// A health probe proved the group recovered; placements fail back.
    Recovered,
    /// The group's service was torn down and rebuilt from a handoff.
    Restarted,
}

/// One federation state transition, timestamped on the injectable
/// clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FederationEvent {
    pub group: usize,
    pub kind: FederationEventKind,
    pub at: Duration,
}

/// Federation-level counters (the per-group services keep their own
/// [`super::service::ServiceStats`] underneath).
#[derive(Debug, Default)]
pub struct FederationStats {
    /// Allocations served through the federation.
    pub allocs: AtomicU64,
    /// Frees served through the federation.
    pub frees: AtomicU64,
    /// Allocations a client's primary group could not take, served by
    /// another group.
    pub spilled_allocs: AtomicU64,
    /// Frees whose owning group differed from the submitting client's
    /// primary.
    pub cross_group_frees: AtomicU64,
    /// Groups latched away from placement (transitions, not probes).
    pub spill_events: AtomicU64,
    /// Spilled groups proven recovered and un-latched.
    pub failbacks: AtomicU64,
    /// Group services torn down and rebuilt from a handoff.
    pub restarts: AtomicU64,
}

/// Plain-value copy of [`FederationStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FederationSnapshot {
    pub allocs: u64,
    pub frees: u64,
    pub spilled_allocs: u64,
    pub cross_group_frees: u64,
    pub spill_events: u64,
    pub failbacks: u64,
    pub restarts: u64,
}

struct GroupSlot {
    /// The live service. `None` only between a failed rebuild and the
    /// next `restart_group`. Ops hold the read lock across the whole
    /// blocking call, so a restart's write lock is a traffic barrier:
    /// nothing is in flight on the group while it swaps.
    svc: OrderedRwLock<Option<AllocService>>,
    /// Latched when placement spills away from this group; cleared by
    /// a recovery probe.
    spilled: AtomicBool,
    /// Bumped on every restart; clients invalidate their cached
    /// per-group handles against it.
    epoch: AtomicU64,
}

struct FedInner {
    groups: Vec<GroupSlot>,
    /// Minimum placeable members for a group to accept federation
    /// placements.
    quorum: usize,
    clock: Arc<dyn Clock>,
    stats: FederationStats,
    events: OrderedMutex<Vec<FederationEvent>>,
    next_primary: AtomicUsize,
    watchdog: OrderedMutex<Option<(Arc<AtomicBool>, JoinHandle<()>)>>,
}

impl FedInner {
    fn record(&self, group: usize, kind: FederationEventKind) {
        let at = self.clock.now();
        self.events
            .lock()
            .unwrap()
            .push(FederationEvent { group, kind, at });
    }

    /// Latch `group` away from placement (idempotent; only the winning
    /// transition records an event).
    fn mark_spilled(&self, group: usize) {
        let slot = &self.groups[group];
        if slot
            .spilled
            // ordering: AcqRel latch CAS; one winner records the event
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            self.stats.spill_events.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            self.record(group, FederationEventKind::Spilled);
        }
    }

    /// Score a group against the placement threshold (`shed_above`) or,
    /// for a spilled group being probed for recovery, the stricter
    /// failback threshold (`readmit_below`) — the federation-level
    /// hysteresis band that keeps the latch from flapping.
    fn pressure(&self, group: usize, recovering: bool) -> GroupPressure {
        let guard = self.groups[group].svc.read().unwrap();
        let svc = match guard.as_ref() {
            Some(s) => s,
            None => return GroupPressure::Exhausted,
        };
        if svc.healthy_devices() < self.quorum {
            return GroupPressure::Exhausted;
        }
        if svc.route_policy() == RoutePolicy::CapacityAware {
            let h = svc.capacity_hysteresis();
            let bar = if recovering { h.readmit_below } else { h.shed_above };
            let any_below = (0..svc.device_count()).any(|d| {
                svc.device_state(d) == DeviceState::Healthy
                    && svc.allocator_of(d).heap().occupancy() < bar
            });
            if !any_below {
                return GroupPressure::Saturated;
            }
        }
        GroupPressure::Ok
    }

    /// One health/failback sweep over every group (the body of
    /// [`FederationRouter::poll_health`], callable from the watchdog
    /// thread which only holds the `Arc<FedInner>`).
    fn poll_health(&self) -> usize {
        let mut transitions = 0;
        for g in 0..self.groups.len() {
            let slot = &self.groups[g];
            // ordering: Acquire pairs with the latch CAS/stores
            if slot.spilled.load(Ordering::Acquire) {
                if self.pressure(g, true) == GroupPressure::Ok {
                    // ordering: Release un-latch; placement may resume
                    slot.spilled.store(false, Ordering::Release);
                    self.stats.failbacks.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                    self.record(g, FederationEventKind::Recovered);
                    transitions += 1;
                }
            } else if self.pressure(g, false) != GroupPressure::Ok {
                self.mark_spilled(g);
                transitions += 1;
            }
        }
        transitions
    }
}

/// The federation tier's owner handle: construct over N running
/// services, mint [`FederationClient`]s, drive health/failback and
/// restarts. See the module docs for the topology and the restart
/// runbook.
pub struct FederationRouter {
    inner: Arc<FedInner>,
}

impl FederationRouter {
    /// Federate `groups` (placement walks them in index order from each
    /// client's primary). `quorum` is the minimum placeable-member
    /// count for a group to accept placements — a group retired past it
    /// spills. Uses the wall clock for event timestamps and watchdog
    /// pacing; tests inject a fake one via
    /// [`FederationRouter::with_clock`].
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ouroboros_tpu::backend::Cuda;
    /// use ouroboros_tpu::coordinator::batcher::BatchPolicy;
    /// use ouroboros_tpu::coordinator::federation::FederationRouter;
    /// use ouroboros_tpu::coordinator::router::RoutePolicy;
    /// use ouroboros_tpu::coordinator::service::AllocService;
    /// use ouroboros_tpu::ouroboros::{HeapConfig, Variant};
    ///
    /// let group = || {
    ///     AllocService::start_named_group(
    ///         &[("t2000", Variant::Page); 2],
    ///         &HeapConfig::default(),
    ///         BatchPolicy::default(),
    ///         RoutePolicy::RoundRobin,
    ///         Arc::new(Cuda::new()),
    ///     )
    /// };
    /// // Two 2-member groups; a group below quorum 2 spills placements
    /// // to the next healthy group.
    /// let fed = FederationRouter::new(vec![group(), group()], 2);
    /// let client = fed.client();
    /// let addr = client.alloc(128)?;
    /// client.free(addr)?;
    /// fed.shutdown();
    /// # Ok::<(), ouroboros_tpu::ouroboros::AllocError>(())
    /// ```
    pub fn new(groups: Vec<AllocService>, quorum: usize) -> Self {
        Self::with_clock(groups, quorum, Arc::new(SystemClock::new()))
    }

    pub fn with_clock(
        groups: Vec<AllocService>,
        quorum: usize,
        clock: Arc<dyn Clock>,
    ) -> Self {
        assert!(!groups.is_empty(), "federation needs at least one group");
        assert!(
            groups.len() <= MAX_GROUPS as usize,
            "federation exceeds the {MAX_GROUPS}-group address space"
        );
        assert!(quorum >= 1, "quorum of zero would never spill");
        FederationRouter {
            inner: Arc::new(FedInner {
                groups: groups
                    .into_iter()
                    .map(|svc| GroupSlot {
                        svc: OrderedRwLock::new(&classes::FED_SLOT, Some(svc)),
                        spilled: AtomicBool::new(false),
                        epoch: AtomicU64::new(0),
                    })
                    .collect(),
                quorum,
                clock,
                stats: FederationStats::default(),
                events: OrderedMutex::new(&classes::FED_EVENTS, Vec::new()),
                next_primary: AtomicUsize::new(0),
                watchdog: OrderedMutex::new(&classes::FED_WATCHDOG, None),
            }),
        }
    }

    pub fn group_count(&self) -> usize {
        self.inner.groups.len()
    }

    /// Mint a client handle; its primary group is assigned round-robin.
    pub fn client(&self) -> FederationClient {
        let n = self.inner.groups.len();
        FederationClient {
            // ordering: round-robin; uniqueness only
            primary: self.inner.next_primary.fetch_add(1, Ordering::Relaxed) % n,
            fed: self.inner.clone(),
            cache: OrderedMutex::new(
                &classes::FED_CLIENT_CACHE,
                (0..n).map(|_| None).collect(),
            ),
            caching: AtomicBool::new(false),
        }
    }

    /// Run `f` against group `g`'s live service (read-locked for the
    /// duration — a concurrent restart waits). `None` if the slot is
    /// empty after a failed rebuild.
    pub fn with_group<R>(
        &self,
        g: usize,
        f: impl FnOnce(&AllocService) -> R,
    ) -> Option<R> {
        let guard = self.inner.groups[g].svc.read().unwrap();
        guard.as_ref().map(f)
    }

    /// Whether group `g` is currently latched away from placement.
    pub fn is_spilled(&self, g: usize) -> bool {
        // ordering: Acquire pairs with the latch CAS/stores
        self.inner.groups[g].spilled.load(Ordering::Acquire)
    }

    /// Score group `g` against the placement threshold.
    pub fn group_pressure(&self, g: usize) -> GroupPressure {
        self.inner.pressure(g, false)
    }

    /// One health/failback sweep: probe every group; latch the ones
    /// under pressure, un-latch the spilled ones that have recovered
    /// (quorum back and, under CapacityAware, occupancy under the
    /// readmit threshold). Returns the number of state transitions.
    /// Deterministic — drive it from a test, or let the watchdog call
    /// it on a period.
    pub fn poll_health(&self) -> usize {
        self.inner.poll_health()
    }

    /// Start a background watchdog calling [`FederationRouter::poll_health`]
    /// every `period` on the federation clock. Idempotent (a second
    /// call is a no-op while one runs); stop with
    /// [`FederationRouter::stop_watchdog`].
    pub fn spawn_watchdog(&self, period: Duration) {
        let mut slot = self.inner.watchdog.lock().unwrap();
        if slot.is_some() {
            return;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let inner = self.inner.clone();
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("ouro-fed-watchdog".into())
            .spawn(move || {
                // ordering: Acquire stop-flag poll; pairs with stop_watchdog
                while !flag.load(Ordering::Acquire) {
                    inner.clock.sleep(period);
                    inner.poll_health();
                }
            })
            .expect("spawning federation watchdog");
        *slot = Some((stop, handle));
    }

    /// Stop and join the watchdog thread, if one is running.
    pub fn stop_watchdog(&self) {
        if let Some((stop, handle)) = self.inner.watchdog.lock().unwrap().take()
        {
            // ordering: Release stop request; pairs with watchdog poll
            stop.store(true, Ordering::Release);
            let _ = handle.join();
        }
    }

    /// Tear down group `g`'s service and rebuild it from the durable
    /// handoff — the restart-with-live-traffic path (runbook in the
    /// module docs). Traffic to the group blocks at the slot lock for
    /// the duration; other groups keep serving. `rebuild` typically
    /// wraps [`AllocService::start_group_restored`]. On rebuild failure
    /// the slot is left empty and latched spilled, and the error
    /// surfaces.
    pub fn restart_group<F>(&self, g: usize, rebuild: F) -> Result<(), AllocError>
    where
        F: FnOnce(&Handoff) -> Result<AllocService, AllocError>,
    {
        let slot = &self.inner.groups[g];
        let mut w = slot.svc.write().unwrap();
        let old = w.take().ok_or(AllocError::ServiceDown)?;
        let handoff = old.prepare_handoff();
        match rebuild(&handoff) {
            Ok(fresh) => {
                *w = Some(fresh);
                // ordering: AcqRel epoch bump under the write lock;
                // clients re-read it under the read lock
                slot.epoch.fetch_add(1, Ordering::AcqRel);
                self.inner.stats.restarts.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                self.inner.record(g, FederationEventKind::Restarted);
                Ok(())
            }
            Err(e) => {
                self.inner.mark_spilled(g);
                Err(e)
            }
        }
    }

    /// Plain-value copy of the federation counters.
    pub fn stats(&self) -> FederationSnapshot {
        let s = &self.inner.stats;
        let r = Ordering::Relaxed; // ordering: Relaxed snapshot; independent stat counters
        FederationSnapshot {
            allocs: s.allocs.load(r),
            frees: s.frees.load(r),
            spilled_allocs: s.spilled_allocs.load(r),
            cross_group_frees: s.cross_group_frees.load(r),
            spill_events: s.spill_events.load(r),
            failbacks: s.failbacks.load(r),
            restarts: s.restarts.load(r),
        }
    }

    /// Everything that happened (spills, recoveries, restarts), in
    /// order, timestamped on the federation clock.
    pub fn events(&self) -> Vec<FederationEvent> {
        self.inner.events.lock().unwrap().clone()
    }

    /// Stop the watchdog and shut every group down; returns total ops
    /// served across the federation.
    pub fn shutdown(self) -> u64 {
        self.stop_watchdog();
        let mut ops = 0;
        for slot in &self.inner.groups {
            if let Some(svc) = slot.svc.write().unwrap().take() {
                ops += svc.shutdown();
            }
        }
        ops
    }
}

/// Cheap per-thread federation handle: blocking `alloc`/`free` with
/// group-tagged addresses, whole-group spillover on the alloc path and
/// tag-routed cross-group frees. Mint one per worker thread via
/// [`FederationRouter::client`].
pub struct FederationClient {
    fed: Arc<FedInner>,
    /// This handle's first-choice group for placements.
    primary: usize,
    /// Cached per-group service clients, invalidated by slot epoch
    /// after a restart.
    cache: OrderedMutex<Vec<Option<(u64, ServiceClient)>>>,
    /// Arm the lease cache on each per-group client as it is minted
    /// (see [`ServiceClient::set_caching`]).
    caching: AtomicBool,
}

impl FederationClient {
    /// This handle's first-choice placement group.
    pub fn primary(&self) -> usize {
        self.primary
    }

    /// Arm (or disarm) the mimalloc-style lease cache on every
    /// per-group service client this handle holds now or mints later —
    /// spillover placements get their own leases on the spill group,
    /// and tag-routed frees of cached blocks resolve inside the owning
    /// group like any other cached free. Call
    /// [`FederationClient::flush_caches`] (or drop the handle) before
    /// restarting a group: a lease is a live block, and a restart that
    /// strands one leaks its span (under `OURO_SAN=1`, the shutdown
    /// leak check names it).
    pub fn set_caching(&self, enabled: bool) {
        // ordering: Release; with_client's mint reads it with Acquire
        self.caching.store(enabled, Ordering::Release);
        let cache = self.cache.lock().unwrap();
        for entry in cache.iter().flatten() {
            entry.1.set_caching(enabled);
        }
    }

    /// Release every lease held by this handle's per-group clients —
    /// the pre-restart barrier for cached federated traffic.
    pub fn flush_caches(&self) {
        let cache = self.cache.lock().unwrap();
        for entry in cache.iter().flatten() {
            entry.1.flush_cache();
        }
    }

    /// Run `f` on a (cached) client of group `g`, holding the slot's
    /// read lock for the duration so a concurrent restart is a clean
    /// barrier rather than a mid-op teardown.
    fn with_client<R>(
        &self,
        g: usize,
        f: impl FnOnce(&ServiceClient) -> Result<R, AllocError>,
    ) -> Result<R, AllocError> {
        let guard = self.fed.groups[g].svc.read().unwrap();
        let svc = guard.as_ref().ok_or(AllocError::ServiceDown)?;
        // ordering: Acquire epoch read under the slot read lock; pairs
        // with the restart's bump under the write lock
        let epoch = self.fed.groups[g].epoch.load(Ordering::Acquire);
        let mut cache = self.cache.lock().unwrap();
        let stale = match &cache[g] {
            Some((e, _)) => *e != epoch,
            None => true,
        };
        if stale {
            let fresh = svc.client();
            // ordering: Acquire; pairs with set_caching's Release store
            if self.caching.load(Ordering::Acquire) {
                fresh.set_caching(true);
            }
            cache[g] = Some((epoch, fresh));
        }
        let (_, client) = cache[g].as_ref().unwrap();
        f(client)
    }

    /// Whether a placement failure should spill to the next group
    /// rather than surface: the group is out of capacity or members,
    /// not rejecting the request itself.
    fn spills(e: &AllocError) -> bool {
        matches!(e, AllocError::DeviceRetired | AllocError::OutOfMemory)
    }

    /// Blocking federated allocation: primary group first, spilling
    /// past groups under pressure (latching them), water-filling across
    /// all groups when everything is latched. The returned address is
    /// group-tagged; hand it back to [`FederationClient::free`] from
    /// any client.
    pub fn alloc(&self, size: u32) -> Result<GlobalAddr, AllocError> {
        let n = self.fed.groups.len();
        let mut last = AllocError::DeviceRetired;
        // First pass: respect the latches and the pressure probe.
        for i in 0..n {
            let g = (self.primary + i) % n;
            // ordering: Acquire pairs with the latch CAS/stores
            if self.fed.groups[g].spilled.load(Ordering::Acquire) {
                continue;
            }
            if self.fed.pressure(g, false) != GroupPressure::Ok {
                self.fed.mark_spilled(g);
                continue;
            }
            match self.with_client(g, |c| c.alloc(size)) {
                Ok(addr) => return Ok(self.account_alloc(g, addr)),
                Err(e) if Self::spills(&e) => {
                    self.fed.mark_spilled(g);
                    last = e;
                }
                Err(e) => return Err(e),
            }
        }
        // Every group latched: water-fill rather than refuse — the
        // latches stay set, so recovery still goes through the
        // failback probe.
        for i in 0..n {
            let g = (self.primary + i) % n;
            match self.with_client(g, |c| c.alloc(size)) {
                Ok(addr) => return Ok(self.account_alloc(g, addr)),
                Err(e) if Self::spills(&e) => last = e,
                Err(e) => return Err(e),
            }
        }
        Err(last)
    }

    fn account_alloc(&self, g: usize, addr: GlobalAddr) -> GlobalAddr {
        self.fed.stats.allocs.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        if g != self.primary {
            self.fed.stats.spilled_allocs.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        }
        addr.with_group(g as u32)
    }

    /// Blocking federated free: the address's group tag names the
    /// owning group; the tag is stripped and the group-local address
    /// handed to that service — from any client, whatever its primary.
    /// An unknown group tag is rejected with the federation-tagged
    /// `InvalidFree` (and so is a group-local rejection, re-tagged so
    /// the caller sees the address it actually submitted).
    pub fn free(&self, addr: GlobalAddr) -> Result<(), AllocError> {
        let g = addr.group() as usize;
        if g >= self.fed.groups.len() {
            return Err(AllocError::InvalidFree(addr.raw()));
        }
        let local = addr.strip_group();
        match self.with_client(g, |c| c.free(local)) {
            Ok(()) => {
                self.fed.stats.frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                if g != self.primary {
                    self.fed.stats.cross_group_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                }
                Ok(())
            }
            // Re-tag group-local rejections so the error names the
            // address the caller submitted, not the stripped one.
            Err(AllocError::InvalidFree(_)) => {
                Err(AllocError::InvalidFree(addr.raw()))
            }
            Err(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Cuda;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::rebalance::FakeClock;
    use crate::ouroboros::{HeapConfig, Variant};

    fn group(n: usize, route: RoutePolicy) -> AllocService {
        AllocService::start_named_group(
            &vec![("t2000", Variant::Page); n],
            &HeapConfig::test_small(),
            BatchPolicy::default(),
            route,
            Arc::new(Cuda::new()),
        )
    }

    fn two_group_fed() -> FederationRouter {
        FederationRouter::with_clock(
            vec![
                group(2, RoutePolicy::RoundRobin),
                group(2, RoutePolicy::RoundRobin),
            ],
            1,
            Arc::new(FakeClock::new()),
        )
    }

    #[test]
    fn single_group_federation_is_identity() {
        // Group 0 addresses are bit-identical to the bare service's.
        let fed = FederationRouter::new(vec![group(1, RoutePolicy::RoundRobin)], 1);
        let c = fed.client();
        let a = c.alloc(256).unwrap();
        assert_eq!(a.group(), 0);
        assert_eq!(a.raw(), a.strip_group().raw());
        c.free(a).unwrap();
        assert_eq!(fed.stats().spilled_allocs, 0);
        assert!(fed.shutdown() >= 2);
    }

    #[test]
    fn addresses_are_group_tagged_and_frees_route_home() {
        let fed = two_group_fed();
        let c0 = fed.client();
        let c1 = fed.client();
        assert_eq!((c0.primary(), c1.primary()), (0, 1));
        let a0 = c0.alloc(512).unwrap();
        let a1 = c1.alloc(512).unwrap();
        assert_eq!(a0.group(), 0);
        assert_eq!(a1.group(), 1);
        // Cross-client, cross-group frees: c0 frees group 1's block.
        c0.free(a1).unwrap();
        c1.free(a0).unwrap();
        let s = fed.stats();
        assert_eq!(s.frees, 2);
        assert_eq!(s.cross_group_frees, 2, "both frees crossed groups");
        fed.shutdown();
    }

    #[test]
    fn exhausted_primary_spills_and_fails_back() {
        let fed = two_group_fed();
        let c = fed.client();
        assert_eq!(c.primary(), 0);
        // Retire every member of group 0: healthy < quorum ⇒ spill.
        fed.with_group(0, |svc| {
            for d in 0..svc.device_count() {
                svc.retire_device(d);
            }
        })
        .unwrap();
        let a = c.alloc(512).unwrap();
        assert_eq!(a.group(), 1, "placement must spill to the standby group");
        assert!(fed.is_spilled(0));
        let s = fed.stats();
        assert_eq!(s.spilled_allocs, 1);
        assert_eq!(s.spill_events, 1);
        // Frees into the spilled-away-from group's space still route by
        // tag (the address owns its group forever).
        c.free(a).unwrap();
        // Repair group 0 and prove failback.
        fed.with_group(0, |svc| {
            for d in 0..svc.device_count() {
                svc.readmit_device(d).unwrap();
            }
        })
        .unwrap();
        assert!(fed.poll_health() >= 1, "recovery must be observed");
        assert!(!fed.is_spilled(0));
        let b = c.alloc(512).unwrap();
        assert_eq!(b.group(), 0, "placement must fail back to the primary");
        c.free(b).unwrap();
        let kinds: Vec<FederationEventKind> =
            fed.events().iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![FederationEventKind::Spilled, FederationEventKind::Recovered]
        );
        assert_eq!(fed.stats().failbacks, 1);
        fed.shutdown();
    }

    #[test]
    fn unknown_group_tag_is_rejected() {
        let fed = two_group_fed();
        let c = fed.client();
        let wild = GlobalAddr::new(0, 64).with_group(3);
        assert_eq!(
            c.free(wild),
            Err(AllocError::InvalidFree(wild.raw())),
            "tag past the federation size must reject, not alias"
        );
        fed.shutdown();
    }

    #[test]
    fn restart_group_preserves_forwarding_and_epoch() {
        let fed = two_group_fed();
        let c = fed.client();
        let a = c.alloc(900).unwrap();
        assert_eq!(a.group(), 0);
        // Migrate the block off its member so a forwarding entry (for
        // the group-local name) exists, then restart the group.
        let local = a.strip_group();
        let moved = fed
            .with_group(0, |svc| {
                svc.set_forwarding_grace(Duration::from_secs(120));
                svc.migrate(local).unwrap()
            })
            .unwrap();
        assert_ne!(moved, local);
        fed.restart_group(0, |handoff| {
            assert!(
                !handoff.snapshot.entries.is_empty(),
                "the forwarding entry must be in the handoff"
            );
            AllocService::start_group_restored(
                handoff.rebuild_members(),
                BatchPolicy::default(),
                RoutePolicy::RoundRobin,
                handoff,
            )
        })
        .unwrap();
        assert_eq!(fed.stats().restarts, 1);
        // The stale federated name still frees after the restart:
        // tag-routed to group 0, forwarded through the restored table
        // to the migrated copy — which is still live, because the
        // successor serves the predecessor's heaps. Zero lost blocks.
        c.free(a).unwrap();
        fed.shutdown();
    }
}
