//! Per-lane submission/completion ring for the async ticket pipeline.
//!
//! Virtio-flavoured (split avail/used design, see the virtio_queue
//! exemplar): a fixed **descriptor table** holds one in-flight op per
//! slot; submitters claim a descriptor from a **free list** (slot reuse),
//! write the request payload, and hand the descriptor id to the lane's
//! [`super::batcher::Batcher`] — the avail ring. The device worker drains
//! a batch of descriptor ids, dispatches the coalesced device pass, and
//! publishes every result back into the descriptor table with **one**
//! bulk completion call (a single state sweep + a single condvar
//! broadcast per batch — the used-ring analogue), instead of one
//! `mpsc::Sender::send` per op.
//!
//! A [`Ticket`] names a descriptor plus its generation; the generation
//! bumps on every reap, so stale tickets (double-poll, use-after-reap)
//! resolve to `None` instead of aliasing the slot's next occupant.
//!
//! The ring is bounded: claiming blocks when all descriptors are in
//! flight, which is the pipeline's natural backpressure — a client can
//! run at most `ring_slots` ops deep per lane.
//!
//! # Notification suppression (virtio EVENT_IDX discipline)
//!
//! Eagerly broadcasting `done_cv` on every completion batch — and
//! `free_cv` on every reap — is a wakeup storm under multi-client
//! churn: most notifications land on rings nobody is sleeping on. The
//! ring therefore adopts virtio's EVENT_IDX protocol (see the
//! virtio_queue exemplar's `used_event`/`avail_event`):
//!
//! * Workers publish a cumulative **used index** (`used_idx`, one bump
//!   per completion) with every `complete_bulk`.
//! * Clients publish a **watermark** (`used_event`): "interrupt me when
//!   the used index crosses N". A completion batch whose index range
//!   does not cross the watermark skips the condvar broadcast entirely.
//! * **Eager fallback**: whenever a waiter is actually blocking
//!   ([`TicketRing::wait`] or [`TicketRing::wait_quiet`]), it registers
//!   in a waiter count *before* re-checking its predicate (SeqCst, with
//!   a fence pairing against the completer's index publish), and
//!   `complete_bulk` delivers unconditionally while any waiter is
//!   registered — so a notification is never lost, only elided when
//!   provably unobservable. Multiple waiters may overwrite each other's
//!   watermark; this fallback is what makes the single watermark slot
//!   safe.
//! * The reap side mirrors it for the free list: `free_cv` is only
//!   notified while a claimer is actually parked on a full ring
//!   (tracked under the free-list mutex, so no fences are needed).
//!
//! The protocol's one ordering hazard — reading the watermark *before*
//! publishing the index lets a client publish-and-recheck in the gap
//! and park forever — is modelled as `NotifyModel` in
//! `crate::check::models`, where the buggy order yields a replayable
//! lost-wakeup counterexample.
//!
//! `TicketRing::new` builds a suppressing ring;
//! [`TicketRing::with_notify`] selects the eager baseline (every batch
//! broadcasts, every reap kicks) that the bench compares against.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Condvar, OnceLock};

use crate::check::lockgraph::{self, classes, OrderedMutex};
use crate::ouroboros::{AllocError, GlobalAddr};

use super::stats::Gauge;

/// Descriptor states. FREE -> SUBMITTED (claim) -> COMPLETE (worker)
/// -> FREE (reap).
const SLOT_FREE: u32 = 0;
const SLOT_SUBMITTED: u32 = 1;
const SLOT_COMPLETE: u32 = 2;

/// The result of an asynchronously submitted op. Alloc completions
/// carry the device-tagged [`GlobalAddr`] the service encoded on the
/// owning device's behalf.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Completion {
    Alloc(Result<GlobalAddr, AllocError>),
    Free(Result<(), AllocError>),
}

impl Completion {
    /// Unwrap an alloc completion. A mismatched kind means the ticket was
    /// forged or the pipeline corrupted; surfaced as `QueueCorrupt`.
    pub fn into_alloc(self) -> Result<GlobalAddr, AllocError> {
        match self {
            Completion::Alloc(r) => r,
            Completion::Free(_) => Err(AllocError::QueueCorrupt),
        }
    }

    /// Unwrap a free completion (see [`Completion::into_alloc`]).
    pub fn into_free(self) -> Result<(), AllocError> {
        match self {
            Completion::Free(r) => r,
            Completion::Alloc(_) => Err(AllocError::QueueCorrupt),
        }
    }
}

/// Handle to one in-flight op: service tag + device + flat lane index +
/// descriptor slot + generation.
///
/// The `svc` tag names the [`super::service::AllocService`] instance
/// that minted the ticket, so a ticket presented to a *different*
/// service resolves to a deterministic [`AllocError::ForeignTicket`]
/// (never a hang or an aliased payload). Within one service, tickets
/// are plain names for ring descriptors: any handle of that service may
/// reap them (see the service docs for the cross-handle semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    /// Minting service's instance tag (0 only transiently, between the
    /// ring claim and the service stamping it at submit).
    pub(crate) svc: u32,
    /// Group device the op was placed on.
    pub(crate) device: u32,
    /// Flat lane index (device-major: `device * lanes_per_device + l`).
    pub(crate) lane: u32,
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

impl Ticket {
    /// The service lane (flat, device-major) this ticket's op was
    /// routed to.
    pub fn lane(&self) -> usize {
        self.lane as usize
    }

    /// The group device this ticket's op was placed on.
    pub fn device(&self) -> usize {
        self.device as usize
    }
}

/// Request payload parked in a descriptor between claim and dispatch.
///
/// Frees carry their forwarding verdict, decided **exactly once at
/// submit**: a free whose address the submit path already rewrote
/// through the migration forwarding table is parked as
/// [`Payload::ForwardedFree`] — its one permitted forward is spent, and
/// the dispatcher must treat the address as final rather than re-probe
/// the table (the old submit/dispatch double-probe was a TOCTOU: the
/// grace window could expire between the two, turning an accepted op
/// into a spurious `InvalidFree`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Payload {
    Alloc { size: u32 },
    /// A free accepted at submit with no forwarding rewrite.
    Free { addr: u32 },
    /// A free whose address was rewritten through the forwarding table
    /// at submit; `addr` is the migrated copy's address.
    ForwardedFree { addr: u32 },
}

const KIND_ALLOC: u32 = 0;
const KIND_FREE: u32 = 1;
const KIND_FWD_FREE: u32 = 2;

/// "No interrupt requested": a watermark so far ahead of the used index
/// that [`need_event`] stays false for the next 2^32 completions. The
/// initial state, and what [`TicketRing::reopen`] resets to — a parked
/// watermark from a previous lane epoch must not leak wakeup decisions
/// into the next one.
const EVENT_IDLE: u32 = u32::MAX;

/// Virtio's `vring_need_event`: with the used index moving `old` →
/// `new` (wrapping), does it cross the client-published watermark
/// `event`? Written exactly as the spec's macro so the wrap-around
/// behaviour is the audited one: `(new - event - 1) < (new - old)`.
fn need_event(event: u32, new: u32, old: u32) -> bool {
    new.wrapping_sub(event).wrapping_sub(1) < new.wrapping_sub(old)
}

/// Nanoseconds since a process-wide monotonic epoch — the time base the
/// per-op ring-path latency histogram is measured in (and, when
/// `OURO_LIN=1` arms the history recorder, the clock every op
/// invocation/response interval is stamped against). One `Instant` is
/// pinned on first use; every stamp is an offset from it, so timestamps
/// fit an `AtomicU64` and never go backwards.
pub(crate) fn mono_ns() -> u64 {
    static EPOCH: OnceLock<std::time::Instant> = OnceLock::new();
    EPOCH.get_or_init(std::time::Instant::now).elapsed().as_nanos() as u64
}

struct Desc {
    state: AtomicU32,
    gen: AtomicU32,
    /// Payload, split into plain atomics (KIND_*, arg). Publication is
    /// ordered by the free-list mutex on claim and the avail (batcher)
    /// mutex on dispatch, so Relaxed suffices.
    kind: AtomicU32,
    arg: AtomicU32,
    /// Completion value; only ever touched by the completing worker and
    /// the reaping client, serialized by the `state` protocol.
    value: OrderedMutex<Option<Completion>>,
    /// `mono_ns` at claim time — the dispatch path subtracts this when
    /// it publishes the completion, giving the claim → publish latency
    /// the `StatsSnapshot::ring_latency` histogram reports. It doubles
    /// as the op's *invocation* timestamp for the `OURO_LIN` history
    /// recorder: the claim strictly precedes the heap effect.
    claimed_ns: AtomicU64,
    /// The submitting client handle's id, stamped by the service's
    /// submit path right after the claim (0 between claim and stamp,
    /// and for internal ops). Only consumed by the history recorder.
    client: AtomicU64,
}

impl Desc {
    fn new() -> Self {
        Desc {
            state: AtomicU32::new(SLOT_FREE),
            gen: AtomicU32::new(0),
            kind: AtomicU32::new(KIND_ALLOC),
            arg: AtomicU32::new(0),
            value: OrderedMutex::new(&classes::RING_VALUE, None),
            claimed_ns: AtomicU64::new(0),
            client: AtomicU64::new(0),
        }
    }
}

pub(crate) struct TicketRing {
    desc: Vec<Desc>,
    /// Free descriptor ids (the virtio free chain, as a stack).
    free: OrderedMutex<Vec<u32>>,
    /// Submitters park here when every descriptor is in flight.
    free_cv: Condvar,
    /// Completion barrier: `complete_bulk` broadcasts under this lock so
    /// a waiter cannot miss the wakeup between its state check and sleep.
    done_mx: OrderedMutex<()>,
    done_cv: Condvar,
    /// Set once the lane's workers are gone; wakes all parked threads.
    closed: AtomicBool,
    /// Threads parked in [`TicketRing::wait_quiet`]. Checked on the
    /// reap path before taking the completion lock, so rings nobody is
    /// watching pay one relaxed-ish load per reap, not a lock.
    quiet_waiters: AtomicU32,
    /// Descriptors sitting `COMPLETE` but not yet reaped. The health
    /// watchdog's stall detector subtracts this from `occupancy`: a
    /// completed op waiting on a slow client reaper is *served* work,
    /// not a wedged device, and must never read as a stall.
    completed: AtomicU32,
    /// In-flight descriptor count (ring occupancy) + high-water mark.
    pub occupancy: Gauge,
    /// Eager baseline: every `complete_bulk` broadcasts and every reap
    /// kicks `free_cv`, pre-suppression behaviour (bench comparison
    /// leg; see the module docs).
    eager: bool,
    /// Cumulative completions published (the virtio used index,
    /// wrapping). Bumped once per completion inside `complete_bulk`,
    /// *before* the watermark is consulted — that order is the
    /// lost-wakeup-free half of the protocol (`NotifyModel`).
    used_idx: AtomicU32,
    /// Client-published watermark: "interrupt me when `used_idx`
    /// crosses this" ([`need_event`]). One slot per ring; concurrent
    /// publishers overwrite each other, which is safe because every
    /// *blocking* waiter also registers in `blocked_waiters` and forces
    /// eager delivery while parked.
    used_event: AtomicU32,
    /// Threads parked in [`TicketRing::wait`]. Non-zero forces eager
    /// delivery in `complete_bulk` — the fallback that makes watermark
    /// overwrites and stale watermarks harmless.
    blocked_waiters: AtomicU32,
    /// Claimers parked on a full ring in [`TicketRing::claim`]. Only
    /// ever read and written under the `free` mutex, so the reap path
    /// can skip `free_cv` kicks nobody would hear without any fence.
    free_waiters: AtomicU32,
    /// Completion-side notifications actually broadcast / elided —
    /// summed into `StatsSnapshot::wakeup_{delivered,suppressed}`.
    delivered: AtomicU64,
    suppressed: AtomicU64,
}

impl TicketRing {
    /// A ring with the EVENT_IDX suppression discipline armed (the
    /// production default).
    pub fn new(slots: usize) -> Self {
        Self::with_notify(slots, false)
    }

    /// `eager = true` builds the pre-suppression baseline ring: every
    /// completion batch broadcasts `done_cv` and every reap kicks
    /// `free_cv`, whether or not anyone is listening.
    pub fn with_notify(slots: usize, eager: bool) -> Self {
        let slots = slots.max(1);
        TicketRing {
            desc: (0..slots).map(|_| Desc::new()).collect(),
            free: OrderedMutex::new(
                &classes::RING_FREE,
                (0..slots as u32).rev().collect(),
            ),
            free_cv: Condvar::new(),
            done_mx: OrderedMutex::new(&classes::RING_DONE, ()),
            done_cv: Condvar::new(),
            closed: AtomicBool::new(false),
            quiet_waiters: AtomicU32::new(0),
            completed: AtomicU32::new(0),
            occupancy: Gauge::new(),
            eager,
            used_idx: AtomicU32::new(0),
            used_event: AtomicU32::new(EVENT_IDLE),
            blocked_waiters: AtomicU32::new(0),
            free_waiters: AtomicU32::new(0),
            delivered: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
        }
    }

    /// (delivered, suppressed) completion-side notification decisions
    /// so far — the service sums these across lanes into
    /// `StatsSnapshot`; the free-list kick decisions are counted in the
    /// same pair (both are "wakeups a client would otherwise absorb").
    pub fn wakeups(&self) -> (u64, u64) {
        // ordering: stat read
        (self.delivered.load(Ordering::Relaxed), self.suppressed.load(Ordering::Relaxed))
    }

    /// The current used index (cumulative completions, wrapping) — the
    /// base a client computes its watermark against.
    pub fn used_index(&self) -> u32 {
        // ordering: SeqCst; watermark math must not see a stale index
        self.used_idx.load(Ordering::SeqCst)
    }

    /// Publish the suppression watermark: "interrupt me when the used
    /// index crosses `idx`" ([`need_event`] semantics — `idx` equal to
    /// the current index means "on the very next completion"). Blocking
    /// waiters must still register (`wait` does); a bare watermark is a
    /// polling client's channel and may be overwritten by any peer.
    pub fn set_used_event(&self, idx: u32) {
        // ordering: SeqCst publish; paired with the completer's SeqCst
        // index bump + watermark read (NotifyModel fixed protocol)
        self.used_event.store(idx, Ordering::SeqCst);
    }

    /// Ops claimed and not yet **completed** (still queued or mid-
    /// dispatch) — `occupancy` minus descriptors already parked
    /// `COMPLETE` awaiting their reap. This is the watchdog's stall
    /// signal: served-but-unreaped tickets are the client's pace, not
    /// the device's.
    pub fn unserved(&self) -> u64 {
        self.occupancy
            .current()
            // ordering: unserved gauge; watchdog heuristic
            .saturating_sub(u64::from(self.completed.load(Ordering::Relaxed)))
    }

    pub fn slots(&self) -> usize {
        self.desc.len()
    }

    fn is_closed(&self) -> bool {
        // ordering: Acquire; pairs with close()/reopen() Release
        self.closed.load(Ordering::Acquire)
    }

    /// Claim a descriptor and publish `payload` into it. Blocks while the
    /// ring is full (pipeline backpressure); returns `None` once the ring
    /// has closed.
    pub fn claim(&self, lane: u32, payload: Payload) -> Option<Ticket> {
        let mut free = self.free.lock().unwrap();
        let slot = loop {
            if self.is_closed() {
                return None;
            }
            if let Some(slot) = free.pop() {
                break slot;
            }
            // Register as parked *under the free mutex*: the reap path
            // pushes the slot and reads this count under the same
            // mutex, so it either sees the parker (and kicks) or the
            // parker's re-loop sees the pushed slot — never both blind.
            // ordering: Relaxed; the free mutex orders the handshake
            self.free_waiters.fetch_add(1, Ordering::Relaxed);
            free = lockgraph::wait(&self.free_cv, free);
            // ordering: Relaxed; still under the free mutex
            self.free_waiters.fetch_sub(1, Ordering::Relaxed);
        };
        drop(free);
        let d = &self.desc[slot as usize];
        let gen = d.gen.load(Ordering::Relaxed); // ordering: Relaxed; free-list pop owns the slot
        let (kind, arg) = match payload {
            Payload::Alloc { size } => (KIND_ALLOC, size),
            Payload::Free { addr } => (KIND_FREE, addr),
            Payload::ForwardedFree { addr } => (KIND_FWD_FREE, addr),
        };
        // ordering: payload field; SUBMITTED Release publishes
        d.kind.store(kind, Ordering::Relaxed);
        d.arg.store(arg, Ordering::Relaxed);
        d.claimed_ns.store(mono_ns(), Ordering::Relaxed); // ordering: stat stamp; published by SUBMITTED Release
        // ordering: Relaxed; reset the attribution tag so an internal
        // op never inherits the slot's previous client
        d.client.store(0, Ordering::Relaxed);
        d.state.store(SLOT_SUBMITTED, Ordering::Release);
        self.occupancy.inc();
        // svc/device are stamped by the service's submit path; the ring
        // itself only ever keys on (slot, gen).
        Some(Ticket { svc: 0, device: 0, lane, slot, gen })
    }

    /// Undo a claim whose avail-ring hand-off was refused (lane shut
    /// down between claim and submit).
    pub fn abort(&self, t: Ticket) {
        let d = &self.desc[t.slot as usize];
        // ordering: debug check on an owned slot
        debug_assert_eq!(d.gen.load(Ordering::Relaxed), t.gen);
        d.gen.fetch_add(1, Ordering::Relaxed);
        d.state.store(SLOT_FREE, Ordering::Release);
        self.occupancy.dec();
        self.recycle_slot(t.slot);
        self.wake_quiet_waiters();
    }

    /// Return `slot` to the free list, kicking `free_cv` only if a
    /// claimer is actually parked on a full ring (or in eager mode).
    /// The waiter count is read under the same mutex the slot is pushed
    /// under, so a parker is either seen here or sees the slot itself.
    fn recycle_slot(&self, slot: u32) {
        let mut free = self.free.lock().unwrap();
        free.push(slot);
        // ordering: Relaxed; the free mutex orders the handshake
        let kick = self.eager || self.free_waiters.load(Ordering::Relaxed) != 0;
        drop(free);
        if kick {
            self.delivered.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            self.free_cv.notify_one();
        } else {
            self.suppressed.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        }
    }

    /// Wake [`TicketRing::wait_quiet`] parkers if this reap drained the
    /// ring. The fence pairs with the one in `wait_quiet`: either the
    /// reaper sees the registered waiter, or the waiter sees the
    /// occupancy already at zero — never both blind.
    fn wake_quiet_waiters(&self) {
        // ordering: SeqCst fence; lost-notification fix, see wait_quiet
        std::sync::atomic::fence(Ordering::SeqCst);
        if self.quiet_waiters.load(Ordering::SeqCst) != 0
            && self.occupancy.current() == 0
        {
            let _barrier = self.done_mx.lock().unwrap();
            self.done_cv.notify_all();
        }
    }

    /// Block until the ring has **no in-flight descriptors** (every
    /// claimed op completed *and reaped*) or `deadline` passes; returns
    /// whether the ring went quiet. This is the event-driven quiesce
    /// the failover/self-heal controllers use between draining a member
    /// and retiring it — it replaces the old 200 µs busy-poll over
    /// `occupancy.current()`, waking on the reap that empties the ring
    /// instead of burning a core while waiting (and sleeping in bounded
    /// slices as a belt-and-braces progress guarantee).
    pub fn wait_quiet(&self, deadline: std::time::Instant) -> bool {
        if self.occupancy.current() == 0 {
            return true;
        }
        // ordering: SeqCst register before re-scan
        self.quiet_waiters.fetch_add(1, Ordering::SeqCst);
        std::sync::atomic::fence(Ordering::SeqCst);
        let mut g = self.done_mx.lock().unwrap();
        let quiet = loop {
            if self.occupancy.current() == 0 {
                break true;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                break false;
            }
            // Cap each sleep slice: a theoretically missed wakeup costs
            // at most one slice, never the whole deadline.
            let slice =
                (deadline - now).min(std::time::Duration::from_millis(5));
            let (g2, _) = lockgraph::wait_timeout(&self.done_cv, g, slice);
            g = g2;
        };
        drop(g);
        self.quiet_waiters.fetch_sub(1, Ordering::SeqCst); // ordering: SeqCst unregister; symmetric
        quiet
    }

    /// Nanoseconds elapsed since `slot` was claimed — the dispatch path
    /// calls this right before publishing the slot's completion, so the
    /// value is the per-op claim → publish ring-path latency.
    pub fn claimed_elapsed_ns(&self, slot: u32) -> u64 {
        // ordering: stat stamp; slot owned by the dispatching worker
        mono_ns().saturating_sub(self.desc[slot as usize].claimed_ns.load(Ordering::Relaxed))
    }

    /// Stamp the submitting client handle's id into a claimed slot —
    /// the service's submit path calls this between the claim and the
    /// avail-ring hand-off, so the dispatching worker (which reads it
    /// only after the batcher mutex hand-off) can attribute the op in
    /// the `OURO_LIN` history.
    pub fn set_client(&self, slot: u32, client: u64) {
        // ordering: Relaxed; the avail (batcher) mutex orders the
        // hand-off, same as the kind/arg payload fields
        self.desc[slot as usize].client.store(client, Ordering::Relaxed);
    }

    /// `(claim timestamp, client id)` for a slot the calling worker
    /// owns — the invocation half of the op's `OURO_LIN` interval.
    pub fn claim_info(&self, slot: u32) -> (u64, u64) {
        let d = &self.desc[slot as usize];
        // ordering: Relaxed; slot owned by the dispatching worker
        (d.claimed_ns.load(Ordering::Relaxed), d.client.load(Ordering::Relaxed))
    }

    /// Read a submitted descriptor's payload (worker side).
    pub fn payload(&self, slot: u32) -> Payload {
        let d = &self.desc[slot as usize];
        // ordering: Acquire; pairs with submit Release
        debug_assert_eq!(d.state.load(Ordering::Acquire), SLOT_SUBMITTED);
        match d.kind.load(Ordering::Relaxed) {
            KIND_ALLOC => Payload::Alloc { size: d.arg.load(Ordering::Relaxed) },
            KIND_FWD_FREE => {
                // ordering: Relaxed payload; see kind load above
                Payload::ForwardedFree { addr: d.arg.load(Ordering::Relaxed) }
            }
            _ => Payload::Free { addr: d.arg.load(Ordering::Relaxed) },
        }
    }

    /// Publish one dispatched batch's completions in bulk: per-slot value
    /// stores, then **at most** a single broadcast. This is the used-ring
    /// write: the used index is published first, then the EVENT_IDX
    /// discipline decides whether anyone could care about a broadcast —
    /// a registered blocking waiter, a quiesce waiter, a closing ring,
    /// or the client watermark crossed by this batch's index range. All
    /// other batches elide the condvar entirely (counted as suppressed).
    pub fn complete_bulk(&self, results: Vec<(u32, Completion)>) {
        if results.is_empty() {
            return;
        }
        let served = results.len() as u32;
        for (slot, val) in results {
            let d = &self.desc[slot as usize];
            *d.value.lock().unwrap() = Some(val);
            // ordering: Release; completion payload before COMPLETE
            d.state.store(SLOT_COMPLETE, Ordering::Release);
        }
        self.completed.fetch_add(served, Ordering::Relaxed); // ordering: stat counter
        // Index publish BEFORE the watermark/waiter read — inverting
        // these two is the lost-wakeup bug `NotifyModel::buggy()`
        // replays: a waiter could publish its watermark and re-check in
        // the gap, then park against a suppression decision made on the
        // stale watermark.
        // ordering: SeqCst index publish; precedes the watermark read
        let old = self.used_idx.fetch_add(served, Ordering::SeqCst);
        let new = old.wrapping_add(served);
        // ordering: SeqCst fence; pairs with the waiter-side fence in
        // wait() — either we see its registration/watermark, or its
        // post-fence re-check sees our COMPLETE stores
        std::sync::atomic::fence(Ordering::SeqCst);
        let deliver = self.eager
            // ordering: SeqCst waiter-count read after the index publish
            || self.blocked_waiters.load(Ordering::SeqCst) != 0
            // ordering: SeqCst; wait_quiet parkers share done_cv
            || self.quiet_waiters.load(Ordering::SeqCst) != 0
            || self.is_closed()
            // ordering: SeqCst watermark read after the index publish
            || need_event(self.used_event.load(Ordering::SeqCst), new, old);
        if deliver {
            self.delivered.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            let _barrier = self.done_mx.lock().unwrap();
            self.done_cv.notify_all();
        } else {
            self.suppressed.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        }
    }

    /// Non-blocking reap: `Some(value)` exactly once per completed
    /// ticket; `None` while pending and forever after (stale generation).
    pub fn try_take(&self, t: Ticket) -> Option<Completion> {
        let d = &self.desc[t.slot as usize];
        // ordering: Acquire; stale-ticket check before slot use
        if d.gen.load(Ordering::Acquire) != t.gen {
            return None;
        }
        if d.state
            .compare_exchange(
                SLOT_COMPLETE,
                SLOT_FREE,
                Ordering::AcqRel, // ordering: AcqRel take-CAS; win orders payload reads
                Ordering::Acquire,
            )
            .is_err()
        {
            return None;
        }
        let val = d.value.lock().unwrap().take();
        d.gen.fetch_add(1, Ordering::Release); // ordering: Release; stale tickets die before reuse
        self.completed.fetch_sub(1, Ordering::Relaxed); // ordering: stat counter
        self.occupancy.dec();
        self.recycle_slot(t.slot);
        self.wake_quiet_waiters();
        Some(val.expect("completed descriptor without a value"))
    }

    /// Blocking reap. Every accepted ticket is completed by the lane
    /// worker's drain (even across shutdown), so this only errors with
    /// `ServiceDown` if the ring closed with the op still unserved (a
    /// worker died) or the ticket is stale (already reaped).
    pub fn wait(&self, t: Ticket) -> Result<Completion, AllocError> {
        if let Some(v) = self.try_take(t) {
            return Ok(v);
        }
        // The eager-notify fallback: register BEFORE the locked re-check
        // so `complete_bulk` either sees the registration (and
        // broadcasts) or this thread's re-check sees the COMPLETE state
        // — the same two-sided fence protocol `wait_quiet` uses.
        // ordering: SeqCst register before re-check
        self.blocked_waiters.fetch_add(1, Ordering::SeqCst);
        // Also publish the watermark ("interrupt me at the very next
        // completion") — redundant while registered, but it keeps the
        // client-published EVENT_IDX channel exercised and documented
        // end to end; overwrites by peers are covered by the fallback.
        self.set_used_event(self.used_index());
        // ordering: SeqCst fence; pairs with the one in complete_bulk
        std::sync::atomic::fence(Ordering::SeqCst);
        // The reap itself (`try_take`) runs *outside* `done_mx`: it
        // recycles the slot and may wake quiesce waiters, both of which
        // take ring locks of their own — reaping under the completion
        // barrier was a latent same-thread `done_mx` relock (deadlock)
        // whenever the reap that emptied the ring raced a parked
        // `wait_quiet`. Under the mutex we only *re-check* the
        // descriptor's atomics; that preserves the no-lost-wakeup
        // protocol (completers broadcast under `done_mx` after setting
        // COMPLETE, so a COMPLETE we miss here is broadcast after we
        // park) without ever nesting a reap inside the barrier.
        let d = &self.desc[t.slot as usize];
        let res = loop {
            if let Some(v) = self.try_take(t) {
                break Ok(v);
            }
            // A generation mismatch means the ticket was already
            // reaped (its slot may even host a new op) — erroring
            // beats parking on a completion that will never re-fire
            // for this ticket.
            // ordering: Acquire; stale-ticket check before slot use
            if d.gen.load(Ordering::Acquire) != t.gen || self.is_closed() {
                break Err(AllocError::ServiceDown);
            }
            let g = self.done_mx.lock().unwrap();
            // ordering: Acquire pair; re-check under the barrier before
            // parking (completion publish precedes the broadcast)
            let pending = d.gen.load(Ordering::Acquire) == t.gen
                && d.state.load(Ordering::Acquire) != SLOT_COMPLETE
                && !self.is_closed();
            if pending {
                drop(lockgraph::wait(&self.done_cv, g));
            }
        };
        // ordering: SeqCst unregister; symmetric with the register
        self.blocked_waiters.fetch_sub(1, Ordering::SeqCst);
        res
    }

    /// Fail a whole batch of submitted descriptors with one deterministic
    /// error, preserving each op's completion *kind* (an alloc waiter
    /// gets `Completion::Alloc(Err(e))`, a free waiter
    /// `Completion::Free(Err(e))`). This is the drain-failure path: a
    /// retiring device's lane uses it to fail its in-flight tickets with
    /// [`AllocError::DeviceRetired`], and the dispatch unwind guard uses
    /// it to fail a crashed batch with [`AllocError::ServiceDown`] —
    /// either way waiters get an error, never a hang.
    pub fn fail_slots(&self, slots: &[u32], err: AllocError) {
        let failed = slots
            .iter()
            .map(|&slot| {
                let c = match self.payload(slot) {
                    Payload::Alloc { .. } => Completion::Alloc(Err(err)),
                    Payload::Free { .. } | Payload::ForwardedFree { .. } => {
                        Completion::Free(Err(err))
                    }
                };
                (slot, c)
            })
            .collect();
        self.complete_bulk(failed);
    }

    /// Mark the ring closed (lane workers gone) and wake every parked
    /// submitter and waiter.
    pub fn close(&self) {
        // ordering: Release; pairs with is_closed Acquire
        self.closed.store(true, Ordering::Release);
        drop(self.free.lock().unwrap());
        self.free_cv.notify_all();
        let _barrier = self.done_mx.lock().unwrap();
        self.done_cv.notify_all();
    }

    /// Reopen a closed ring for a readmitted member's fresh lane
    /// workers. Descriptors still parked `COMPLETE` — failed tickets
    /// nobody reaped before the retire — keep their slots out of the
    /// free list until their holders reap them, so reopening never
    /// invalidates or aliases an outstanding ticket; those slots simply
    /// rejoin the free list on their eventual (stale-safe) reap.
    ///
    /// The suppression watermark resets to idle: a watermark published
    /// against the previous lane epoch must not make the fresh workers'
    /// first batches look interesting (or, worse, a wrapped index make
    /// them look boring) — new-epoch clients re-publish when they park.
    pub fn reopen(&self) {
        self.set_used_event(EVENT_IDLE);
        // ordering: Release; pairs with is_closed Acquire
        self.closed.store(false, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn claim_complete_take_roundtrip() {
        let r = TicketRing::new(4);
        let t = r.claim(0, Payload::Alloc { size: 64 }).unwrap();
        assert_eq!(r.payload(t.slot), Payload::Alloc { size: 64 });
        assert_eq!(r.try_take(t), None, "pending ticket must not reap");
        r.complete_bulk(vec![(t.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(0x40))))]);
        assert_eq!(r.try_take(t), Some(Completion::Alloc(Ok(GlobalAddr::from_raw(0x40)))));
        assert_eq!(r.occupancy.current(), 0);
    }

    #[test]
    fn stale_ticket_never_reaps_twice() {
        let r = TicketRing::new(2);
        let t = r.claim(0, Payload::Free { addr: 16 }).unwrap();
        r.complete_bulk(vec![(t.slot, Completion::Free(Ok(())))]);
        assert!(r.try_take(t).is_some());
        // Same slot is reused by a new op; the old ticket stays dead.
        let t2 = r.claim(0, Payload::Alloc { size: 32 }).unwrap();
        r.complete_bulk(vec![(t2.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(7))))]);
        assert_eq!(r.try_take(t), None, "stale generation must not alias");
        assert!(r.try_take(t2).is_some());
    }

    #[test]
    fn abort_recycles_slot() {
        let r = TicketRing::new(1);
        let t = r.claim(0, Payload::Alloc { size: 8 }).unwrap();
        r.abort(t);
        assert_eq!(r.try_take(t), None);
        // The single slot is claimable again.
        let t2 = r.claim(0, Payload::Alloc { size: 8 }).unwrap();
        assert_eq!(t2.slot, t.slot);
        assert_ne!(t2.gen, t.gen, "aborted slot must bump generation");
    }

    #[test]
    fn full_ring_blocks_until_reap() {
        let r = Arc::new(TicketRing::new(2));
        let a = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        let _b = r.claim(0, Payload::Alloc { size: 2 }).unwrap();
        let r2 = r.clone();
        let claimer = std::thread::spawn(move || {
            // Blocks until a slot frees up.
            r2.claim(0, Payload::Alloc { size: 3 }).unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.complete_bulk(vec![(a.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(0))))]);
        assert!(r.try_take(a).is_some());
        let c = claimer.join().unwrap();
        assert_eq!(r.payload(c.slot), Payload::Alloc { size: 3 });
    }

    #[test]
    fn close_wakes_parked_waiter_with_service_down() {
        let r = Arc::new(TicketRing::new(1));
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        let r2 = r.clone();
        let waiter = std::thread::spawn(move || r2.wait(t));
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.close();
        assert_eq!(waiter.join().unwrap(), Err(AllocError::ServiceDown));
        assert!(r.claim(0, Payload::Alloc { size: 1 }).is_none());
    }

    #[test]
    fn wait_on_stale_ticket_errors_instead_of_hanging() {
        let r = TicketRing::new(2);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        r.complete_bulk(vec![(t.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(5))))]);
        assert!(r.try_take(t).is_some());
        // The reaped ticket's generation is gone: wait must not park.
        assert_eq!(r.wait(t), Err(AllocError::ServiceDown));
    }

    #[test]
    fn bulk_completion_wakes_blocking_waiter() {
        let r = Arc::new(TicketRing::new(8));
        let t = r.claim(0, Payload::Alloc { size: 4 }).unwrap();
        let r2 = r.clone();
        let waiter = std::thread::spawn(move || r2.wait(t));
        std::thread::sleep(std::time::Duration::from_millis(10));
        r.complete_bulk(vec![(t.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(99))))]);
        assert_eq!(waiter.join().unwrap(), Ok(Completion::Alloc(Ok(GlobalAddr::from_raw(99)))));
    }

    #[test]
    fn fail_slots_preserves_completion_kind() {
        let r = TicketRing::new(4);
        let ta = r.claim(0, Payload::Alloc { size: 64 }).unwrap();
        let tf = r.claim(0, Payload::Free { addr: 32 }).unwrap();
        r.fail_slots(&[ta.slot, tf.slot], AllocError::DeviceRetired);
        assert_eq!(
            r.try_take(ta),
            Some(Completion::Alloc(Err(AllocError::DeviceRetired)))
        );
        assert_eq!(
            r.try_take(tf),
            Some(Completion::Free(Err(AllocError::DeviceRetired)))
        );
        assert_eq!(r.occupancy.current(), 0);
    }

    #[test]
    fn forwarded_free_payload_roundtrips_and_fails_as_free() {
        let r = TicketRing::new(4);
        let t = r.claim(0, Payload::ForwardedFree { addr: 0x80 }).unwrap();
        assert_eq!(r.payload(t.slot), Payload::ForwardedFree { addr: 0x80 });
        r.fail_slots(&[t.slot], AllocError::DeviceRetired);
        assert_eq!(
            r.try_take(t),
            Some(Completion::Free(Err(AllocError::DeviceRetired))),
            "a forwarded free must fail with a Free completion kind"
        );
    }

    #[test]
    fn unserved_excludes_completed_but_unreaped() {
        let r = TicketRing::new(4);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        let t2 = r.claim(0, Payload::Free { addr: 16 }).unwrap();
        assert_eq!(r.unserved(), 2, "both claimed, neither served");
        r.complete_bulk(vec![(
            t.slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(0x40))),
        )]);
        // One op served but unreaped: occupancy stays 2, unserved 1 —
        // the watchdog must see the client's reap debt, not a stall.
        assert_eq!(r.occupancy.current(), 2);
        assert_eq!(r.unserved(), 1);
        assert!(r.try_take(t).is_some());
        assert_eq!(r.unserved(), 1, "reap clears occupancy and completed");
        r.fail_slots(&[t2.slot], AllocError::DeviceRetired);
        assert_eq!(r.unserved(), 0);
        assert!(r.try_take(t2).is_some());
        assert_eq!(r.occupancy.current(), 0);
    }

    #[test]
    fn wait_quiet_immediate_on_empty_ring() {
        let r = TicketRing::new(4);
        let deadline =
            std::time::Instant::now() + std::time::Duration::from_secs(5);
        assert!(r.wait_quiet(deadline));
    }

    #[test]
    fn wait_quiet_wakes_on_the_reap_that_empties_the_ring() {
        let r = Arc::new(TicketRing::new(4));
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        let r2 = r.clone();
        let waiter = std::thread::spawn(move || {
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_secs(10);
            (r2.wait_quiet(deadline), std::time::Instant::now())
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.complete_bulk(vec![(t.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(0))))]);
        assert!(r.try_take(t).is_some());
        let (quiet, _) = waiter.join().unwrap();
        assert!(quiet, "waiter must see the ring go quiet, not time out");
    }

    #[test]
    fn wait_quiet_times_out_on_a_wedged_ring() {
        let r = TicketRing::new(2);
        let _t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_millis(20);
        assert!(!r.wait_quiet(deadline), "nothing reaps: must report false");
    }

    #[test]
    fn reopen_revives_claims_and_recycles_reaped_slots() {
        let r = TicketRing::new(2);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        r.fail_slots(&[t.slot], AllocError::DeviceRetired);
        r.close();
        assert!(r.claim(0, Payload::Alloc { size: 2 }).is_none());
        r.reopen();
        // The unreaped COMPLETE slot stays out of the free list...
        let t2 = r.claim(0, Payload::Alloc { size: 3 }).unwrap();
        assert_ne!(t2.slot, t.slot, "reopen must not alias parked tickets");
        // ...until its holder reaps it, stale-safely, after which it is
        // claimable again.
        assert!(r.try_take(t).is_some());
        let t3 = r.claim(0, Payload::Alloc { size: 4 }).unwrap();
        assert_eq!(t3.slot, t.slot);
        r.abort(t2);
        r.abort(t3);
    }

    #[test]
    fn claim_timestamp_measures_elapsed() {
        let r = TicketRing::new(2);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let ns = r.claimed_elapsed_ns(t.slot);
        assert!(ns >= 4_000_000, "claim -> now must span the sleep: {ns}");
        assert!(ns < 60_000_000_000, "sane upper bound: {ns}");
        r.abort(t);
    }

    /// EVENT_IDX boundary: the batch whose index range crosses the
    /// published watermark must broadcast; batches short of it must
    /// not. `need_event` is exercised through the real ring, not a
    /// re-derivation.
    #[test]
    fn watermark_boundary_controls_delivery() {
        let r = TicketRing::new(8);
        let ts: Vec<Ticket> = (0..3)
            .map(|i| r.claim(0, Payload::Alloc { size: i + 1 }).unwrap())
            .collect();
        // "Interrupt me once the index crosses current + 2" — i.e. at
        // the third completion from now.
        r.set_used_event(r.used_index().wrapping_add(2));
        let (d0, _) = r.wakeups();
        r.complete_bulk(vec![(
            ts[0].slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(1))),
        )]);
        r.complete_bulk(vec![(
            ts[1].slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(2))),
        )]);
        let (d1, s1) = r.wakeups();
        assert_eq!(d1, d0, "batches short of the watermark must suppress");
        assert!(s1 >= 2, "both early batches count as suppressed");
        r.complete_bulk(vec![(
            ts[2].slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(3))),
        )]);
        let (d2, _) = r.wakeups();
        assert_eq!(
            d2,
            d0 + 1,
            "the batch crossing the watermark must broadcast"
        );
        // Suppressed completions are still plainly reapable by polling.
        for t in ts {
            assert!(r.try_take(t).is_some());
        }
    }

    /// A parked blocking waiter forces eager delivery no matter where
    /// the watermark sits — the no-lost-notification fallback.
    #[test]
    fn parked_waiter_overrides_stale_watermark() {
        let r = Arc::new(TicketRing::new(4));
        let t = r.claim(0, Payload::Alloc { size: 4 }).unwrap();
        // A peer parked the watermark far in the future: on its own
        // this would suppress every near-term broadcast.
        r.set_used_event(r.used_index().wrapping_add(1000));
        let r2 = r.clone();
        let waiter = std::thread::spawn(move || r2.wait(t));
        std::thread::sleep(std::time::Duration::from_millis(20));
        r.complete_bulk(vec![(
            t.slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(7))),
        )]);
        assert_eq!(
            waiter.join().unwrap(),
            Ok(Completion::Alloc(Ok(GlobalAddr::from_raw(7)))),
            "a blocking waiter must never lose its notification"
        );
    }

    /// The eager baseline ring delivers every batch broadcast and every
    /// reap kick, suppressing nothing — the bench's comparison leg.
    #[test]
    fn eager_ring_never_suppresses() {
        let r = TicketRing::with_notify(4, true);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        r.complete_bulk(vec![(
            t.slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(0))),
        )]);
        assert!(r.try_take(t).is_some());
        let (delivered, suppressed) = r.wakeups();
        assert_eq!(suppressed, 0);
        // One done_cv broadcast + one free_cv kick.
        assert_eq!(delivered, 2);
    }

    /// With no waiter parked and no watermark published, completions
    /// and reaps are silent — the storm the discipline removes.
    #[test]
    fn idle_ring_suppresses_the_storm() {
        let r = TicketRing::new(4);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        r.complete_bulk(vec![(
            t.slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(0))),
        )]);
        assert!(r.try_take(t).is_some());
        let (delivered, suppressed) = r.wakeups();
        assert_eq!(delivered, 0, "nobody listening: no broadcast, no kick");
        assert_eq!(suppressed, 2);
    }

    #[test]
    fn reopen_resets_the_watermark_to_idle() {
        let r = TicketRing::new(2);
        let t = r.claim(0, Payload::Alloc { size: 1 }).unwrap();
        // "Interrupt me at the next completion", then the lane dies.
        r.set_used_event(r.used_index());
        r.fail_slots(&[t.slot], AllocError::DeviceRetired);
        r.close();
        r.reopen();
        assert!(r.try_take(t).is_some());
        let (d0, _) = r.wakeups();
        let t2 = r.claim(0, Payload::Alloc { size: 2 }).unwrap();
        r.complete_bulk(vec![(
            t2.slot,
            Completion::Alloc(Ok(GlobalAddr::from_raw(0))),
        )]);
        let (d1, _) = r.wakeups();
        assert_eq!(
            d1, d0,
            "the pre-reopen watermark must not survive into the new epoch"
        );
        assert!(r.try_take(t2).is_some());
    }

    #[test]
    fn occupancy_gauge_tracks_inflight() {
        let r = TicketRing::new(8);
        let ts: Vec<Ticket> = (0..5)
            .map(|i| r.claim(0, Payload::Alloc { size: i + 1 }).unwrap())
            .collect();
        assert_eq!(r.occupancy.current(), 5);
        r.complete_bulk(
            ts.iter().map(|t| (t.slot, Completion::Alloc(Ok(GlobalAddr::from_raw(0))))).collect(),
        );
        for t in ts {
            r.try_take(t).unwrap();
        }
        assert_eq!(r.occupancy.current(), 0);
        assert_eq!(r.occupancy.high_water(), 5);
    }
}
