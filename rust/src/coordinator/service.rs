//! The allocation service: per-size-class request lanes owning the
//! simulated device, serving malloc/free requests from any number of
//! client threads through warp-shaped [`Batcher`] lanes.
//!
//! This is the deployment shape of the library (vLLM-router-style): the
//! rust coordinator owns the device and the event loops; clients hold
//! cheap cloneable handles. Requests are binned by size class **at
//! submit time** (the host-side mirror of the kernel-side
//! `size_to_queue`) into independent lanes, so:
//!
//! * lanes never contend on a shared queue lock or condvar — the
//!   structural fix the Intel SHMEM / SYCL-portability literature
//!   prescribes (contention-free lanes *before* the device);
//! * every lane batch is a same-class group, dispatched through the
//!   coalesced bulk paths (`malloc_bulk` / `free_bulk`) — one admission
//!   RMW pair per warp-width group instead of one per op;
//! * each lane has its own device worker(s), so classes make progress
//!   independently (a storm of 16 B allocations cannot head-of-line
//!   block an 8 KiB lane).
//!
//! `BatchPolicy { lanes: 1, .. }` recovers the pre-sharding single-lane
//! shape, kept as the `benches/service_throughput` baseline.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::ouroboros::params::{queue_for_size, NUM_QUEUES};
use crate::ouroboros::{AllocError, DeviceAllocator, Heap};
use crate::simt::{Device, Grid};

use super::batcher::{BatchPolicy, Batcher, Op};

#[derive(Debug)]
pub struct ServiceStats {
    pub batches: AtomicU64,
    pub ops: AtomicU64,
    pub allocs: AtomicU64,
    pub frees: AtomicU64,
    /// Sum of batch sizes (mean batch = / batches).
    pub batched_ops: AtomicU64,
    pub device_us_total: AtomicU64,
    /// Batches dispatched per lane — the sharding observability hook.
    lane_batches: Vec<AtomicU64>,
    /// Ops routed through each lane.
    lane_ops: Vec<AtomicU64>,
}

impl ServiceStats {
    fn new(lanes: usize) -> Self {
        ServiceStats {
            batches: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            batched_ops: AtomicU64::new(0),
            device_us_total: AtomicU64::new(0),
            lane_batches: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_ops: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_ops.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Per-lane dispatched-batch counts.
    pub fn lane_batches(&self) -> Vec<u64> {
        self.lane_batches.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Per-lane op counts.
    pub fn lane_ops(&self) -> Vec<u64> {
        self.lane_ops.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

struct Inner {
    lanes: Vec<Batcher>,
    policy: BatchPolicy,
    stats: ServiceStats,
    device: Device,
    alloc: Arc<dyn DeviceAllocator>,
}

impl Inner {
    /// Lane serving size class `q` (identity when lanes == NUM_QUEUES).
    fn lane_for_q(&self, q: usize) -> usize {
        let n = self.lanes.len();
        (q * n / NUM_QUEUES).min(n - 1)
    }

    /// Size class of a free: recovered from the address's chunk header.
    /// Addresses outside the heap resolve to class 0, where the device
    /// path rejects them as `InvalidFree`.
    fn class_for_addr(&self, addr: u32) -> usize {
        let (chunk, _) = Heap::locate(addr);
        if chunk < self.alloc.heap().num_chunks() {
            self.alloc.heap().header(chunk).queue().min(NUM_QUEUES - 1)
        } else {
            0
        }
    }

    fn lane_for_addr(&self, addr: u32) -> usize {
        self.lane_for_q(self.class_for_addr(addr))
    }
}

/// Cloneable client handle; blocking calls.
#[derive(Clone)]
pub struct ServiceClient {
    inner: Arc<Inner>,
}

impl ServiceClient {
    pub fn alloc(&self, size: u32) -> Result<u32, AllocError> {
        // Submit-time binning (host mirror of the size_to_queue kernel);
        // invalid sizes never occupy a lane slot.
        let q = match queue_for_size(size) {
            Some(q) => q,
            None if size == 0 => return Err(AllocError::ZeroSize),
            None => return Err(AllocError::TooLarge(size)),
        };
        let (tx, rx) = channel();
        let lane = self.inner.lane_for_q(q);
        if !self.inner.lanes[lane].submit(Op::Alloc { size, reply: tx }) {
            return Err(AllocError::ServiceDown);
        }
        rx.recv().unwrap_or(Err(AllocError::ServiceDown))
    }

    pub fn free(&self, addr: u32) -> Result<(), AllocError> {
        let (tx, rx) = channel();
        let lane = self.inner.lane_for_addr(addr);
        if !self.inner.lanes[lane].submit(Op::Free { addr, reply: tx }) {
            return Err(AllocError::ServiceDown);
        }
        rx.recv().unwrap_or(Err(AllocError::ServiceDown))
    }
}

pub struct AllocService {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl AllocService {
    pub fn start(
        device: Device,
        alloc: Arc<dyn DeviceAllocator>,
        policy: BatchPolicy,
    ) -> Self {
        let n_lanes = policy.lanes.clamp(1, NUM_QUEUES);
        let workers_per_lane = policy.workers_per_lane.max(1);
        let inner = Arc::new(Inner {
            lanes: (0..n_lanes).map(|_| Batcher::new()).collect(),
            stats: ServiceStats::new(n_lanes),
            policy,
            device,
            alloc,
        });
        let mut workers = Vec::with_capacity(n_lanes * workers_per_lane);
        for lane in 0..n_lanes {
            for w in 0..workers_per_lane {
                let inner2 = inner.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("ouro-alloc-l{lane}w{w}"))
                        .spawn(move || Self::run_lane(inner2, lane))
                        .expect("spawning service worker"),
                );
            }
        }
        AllocService { inner, workers }
    }

    pub fn client(&self) -> ServiceClient {
        ServiceClient { inner: self.inner.clone() }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    pub fn allocator(&self) -> &Arc<dyn DeviceAllocator> {
        &self.inner.alloc
    }

    fn run_lane(inner: Arc<Inner>, lane: usize) {
        while let Some(batch) = inner.lanes[lane].next_batch(&inner.policy) {
            Self::dispatch(&inner, lane, batch);
        }
    }

    /// Dispatch one lane batch: group by size class (a lane holds exactly
    /// one class when fully sharded, several in the single-lane baseline)
    /// and issue one coalesced device pass per (kind, class) group.
    fn dispatch(inner: &Inner, lane: usize, batch: Vec<Op>) {
        let stats = &inner.stats;
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.lane_batches[lane].fetch_add(1, Ordering::Relaxed);
        stats.ops.fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.lane_ops[lane].fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.batched_ops.fetch_add(batch.len() as u64, Ordering::Relaxed);

        type AllocReply = Sender<Result<u32, AllocError>>;
        type FreeReply = Sender<Result<(), AllocError>>;
        let mut alloc_groups: BTreeMap<usize, Vec<AllocReply>> = BTreeMap::new();
        let mut free_groups: BTreeMap<usize, (Vec<u32>, Vec<FreeReply>)> =
            BTreeMap::new();
        for op in batch {
            match op {
                Op::Alloc { size, reply } => match queue_for_size(size) {
                    Some(q) => alloc_groups.entry(q).or_default().push(reply),
                    // Clients validate at submit; guard anyway.
                    None => {
                        let _ = reply.send(Err(if size == 0 {
                            AllocError::ZeroSize
                        } else {
                            AllocError::TooLarge(size)
                        }));
                    }
                },
                Op::Free { addr, reply } => {
                    let g = free_groups.entry(inner.class_for_addr(addr)).or_default();
                    g.0.push(addr);
                    g.1.push(reply);
                }
            }
        }

        for (q, replies) in alloc_groups {
            Self::dispatch_allocs(inner, q, replies);
        }
        for (q, (addrs, replies)) in free_groups {
            Self::dispatch_frees(inner, q, addrs, replies);
        }
    }

    fn dispatch_allocs(
        inner: &Inner,
        q: usize,
        replies: Vec<Sender<Result<u32, AllocError>>>,
    ) {
        let n = replies.len();
        let stats = &inner.stats;
        stats.allocs.fetch_add(n as u64, Ordering::Relaxed);
        // The bulk path bypasses `DeviceAllocator::malloc`, so account
        // the requests here (matching the warp-path bookkeeping).
        inner.alloc.counters().mallocs.fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &inner.alloc;
        // (warp base, group width, addresses, terminal error) per warp.
        let results: std::sync::Mutex<Vec<(usize, usize, Vec<u32>, Option<AllocError>)>> =
            std::sync::Mutex::new(Vec::new());
        let st = inner.device.launch(
            &format!("service.malloc.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                // Leader-coalesced class group: one collective point,
                // then one bulk queue op for the whole warp.
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let mut out = Vec::with_capacity(width);
                let err =
                    alloc.malloc_bulk(&w.ctx, q, width as u32, &mut out).err();
                results.lock().unwrap().push((base, width, out, err));
            },
        );
        stats.device_us_total.fetch_add(st.device_us as u64, Ordering::Relaxed);

        let mut flat: Vec<Result<u32, AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, width, out, err) in results.into_inner().unwrap() {
            for i in 0..width {
                flat[base + i] = match out.get(i) {
                    Some(&a) => Ok(a),
                    None => Err(err.unwrap_or(AllocError::QueueCorrupt)),
                };
            }
        }
        for (reply, r) in replies.into_iter().zip(flat) {
            let _ = reply.send(r);
        }
    }

    fn dispatch_frees(
        inner: &Inner,
        q: usize,
        addrs: Vec<u32>,
        replies: Vec<Sender<Result<(), AllocError>>>,
    ) {
        let n = addrs.len();
        let stats = &inner.stats;
        stats.frees.fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &inner.alloc;
        let addrs_ref = &addrs;
        let results: std::sync::Mutex<Vec<(usize, Vec<Result<(), AllocError>>)>> =
            std::sync::Mutex::new(Vec::new());
        let st = inner.device.launch(
            &format!("service.free.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let rs = alloc.free_bulk(&w.ctx, &addrs_ref[base..base + width]);
                results.lock().unwrap().push((base, rs));
            },
        );
        stats.device_us_total.fetch_add(st.device_us as u64, Ordering::Relaxed);

        let mut flat: Vec<Result<(), AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, rs) in results.into_inner().unwrap() {
            for (i, r) in rs.into_iter().enumerate() {
                flat[base + i] = r;
            }
        }
        for (reply, r) in replies.into_iter().zip(flat) {
            let _ = reply.send(r);
        }
    }

    fn stop_and_join(&mut self) {
        for lane in &self.inner.lanes {
            lane.stop();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) -> u64 {
        self.stop_and_join();
        self.inner.stats.ops.load(Ordering::Relaxed)
    }
}

impl Drop for AllocService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Cuda;
    use crate::ouroboros::{build_allocator, HeapConfig, Variant};
    use crate::simt::DeviceProfile;

    fn service() -> AllocService {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::test_small());
        AllocService::start(device, alloc, BatchPolicy::default())
    }

    #[test]
    fn alloc_free_roundtrip_through_service() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(1000).unwrap();
        let b = c.alloc(1000).unwrap();
        assert_ne!(a, b);
        c.free(a).unwrap();
        c.free(b).unwrap();
        assert!(svc.stats().ops.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn concurrent_clients_get_unique_addresses() {
        let svc = service();
        let addrs = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = svc.client();
                let addrs = &addrs;
                s.spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..20 {
                        mine.push(c.alloc(64).unwrap());
                    }
                    addrs.lock().unwrap().extend(mine);
                });
            }
        });
        let mut got = addrs.into_inner().unwrap();
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "service handed out duplicate addresses");
        // Batching actually happened (mean batch > 1 with 8 clients).
        assert!(svc.stats().mean_batch() > 1.0);
    }

    #[test]
    fn oversize_rejected_through_service() {
        let svc = service();
        let c = svc.client();
        assert_eq!(c.alloc(9000), Err(AllocError::TooLarge(9000)));
        assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = service();
        let c = svc.client();
        c.alloc(128).unwrap();
        let ops = svc.shutdown();
        assert!(ops >= 1);
    }

    #[test]
    fn dead_service_reports_service_down_not_corruption() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(256).unwrap();
        c.free(a).unwrap();
        svc.shutdown();
        assert_eq!(c.alloc(256), Err(AllocError::ServiceDown));
        assert_eq!(c.free(a), Err(AllocError::ServiceDown));
    }

    #[test]
    fn lanes_shard_by_size_class() {
        let svc = service();
        let c = svc.client();
        // Three distinct classes: q0 (16 B), q6 (1000 B), q9 (8 KiB).
        let mut addrs = Vec::new();
        for &size in &[16u32, 1000, 8192] {
            for _ in 0..4 {
                addrs.push(c.alloc(size).unwrap());
            }
        }
        for a in addrs {
            c.free(a).unwrap();
        }
        let lanes = svc.stats().lane_batches();
        assert_eq!(lanes.len(), NUM_QUEUES);
        for q in [0usize, 6, 9] {
            assert!(lanes[q] > 0, "lane {q} saw no batches: {lanes:?}");
        }
        // Classes that never saw a request stay silent lanes.
        assert_eq!(lanes[3], 0, "unexpected traffic on idle lane: {lanes:?}");
        // Per-lane counts are a partition of the aggregate.
        assert_eq!(
            lanes.iter().sum::<u64>(),
            svc.stats().batches.load(Ordering::Relaxed)
        );
        assert_eq!(
            svc.stats().lane_ops().iter().sum::<u64>(),
            svc.stats().ops.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn single_lane_policy_still_works() {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Chunk, &HeapConfig::test_small());
        let svc =
            AllocService::start(device, alloc, BatchPolicy::single_lane());
        let c = svc.client();
        let addrs: Vec<u32> = (0u32..16)
            .map(|i| c.alloc(16u32 << (i % 5)).unwrap())
            .collect();
        for a in addrs {
            c.free(a).unwrap();
        }
        assert_eq!(svc.stats().lane_batches().len(), 1);
        assert!(svc.stats().lane_batches()[0] > 0);
    }
}
