//! The allocation service: per-size-class request lanes owning the
//! simulated device, serving malloc/free requests from any number of
//! client threads through warp-shaped [`Batcher`] lanes.
//!
//! This is the deployment shape of the library (vLLM-router-style): the
//! rust coordinator owns the device and the event loops; clients hold
//! cheap cloneable handles. Requests are binned by size class **at
//! submit time** (the host-side mirror of the kernel-side
//! `size_to_queue`) into independent lanes, so:
//!
//! * lanes never contend on a shared queue lock or condvar — the
//!   structural fix the Intel SHMEM / SYCL-portability literature
//!   prescribes (contention-free lanes *before* the device);
//! * every lane batch is a same-class group, dispatched through the
//!   coalesced bulk paths (`malloc_bulk` / `free_bulk`) — one admission
//!   RMW pair per warp-width group instead of one per op;
//! * each lane has its own device worker(s), so classes make progress
//!   independently (a storm of 16 B allocations cannot head-of-line
//!   block an 8 KiB lane).
//!
//! # The async ticket pipeline
//!
//! The hot path is **submit/poll**, not call/return. Each lane pairs its
//! [`Batcher`] (the avail ring: descriptor ids awaiting dispatch) with a
//! [`TicketRing`] (descriptor table + completion states + free list —
//! see `ring.rs` for the virtio lineage). A client submits at depth:
//!
//! ```text
//! let t1 = client.submit_alloc(96)?;        // claims a ring descriptor
//! let t2 = client.submit_alloc(1000)?;      // second op in flight
//! // ... do other work; the lane gathers a whole batch ...
//! let a1 = client.wait(t1)?.into_alloc()?;  // blocking reap
//! if let Some(c) = client.poll(t2) { ... }  // non-blocking reap
//! client.wait_all();                        // drain this handle
//! ```
//!
//! Because submission never blocks on the device round-trip, a *single*
//! client thread can keep a lane's batch full — the paper's coalesced
//! same-class groups stay wide without needing dozens of blocking
//! threads. Completions are published **once per dispatched batch**
//! (one state sweep + one condvar broadcast), not one channel send per
//! op. The classic blocking [`ServiceClient::alloc`] /
//! [`ServiceClient::free`] survive as `submit + wait` wrappers.
//!
//! Invalid requests never occupy a ring slot: oversize/zero allocs and
//! frees whose address lies outside the heap are rejected at submit
//! (`AllocError::InvalidFree`, counted in `ServiceStats::invalid_frees`)
//! instead of burning a lane batch slot on a guaranteed failure.
//!
//! `BatchPolicy { lanes: 1, .. }` recovers the pre-sharding single-lane
//! shape, kept as the `benches/service_throughput` baseline.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::ouroboros::params::{queue_for_size, NUM_QUEUES};
use crate::ouroboros::{AllocError, DeviceAllocator, Heap};
use crate::simt::{Device, Grid};

use super::batcher::{BatchPolicy, Batcher};
use super::ring::{Completion, Payload, Ticket, TicketRing};

#[derive(Debug)]
pub struct ServiceStats {
    pub batches: AtomicU64,
    pub ops: AtomicU64,
    pub allocs: AtomicU64,
    pub frees: AtomicU64,
    /// Sum of batch sizes (mean batch = / batches).
    pub batched_ops: AtomicU64,
    pub device_us_total: AtomicU64,
    /// Frees rejected at submit because the address lies outside the
    /// heap — they never reach a lane.
    pub invalid_frees: AtomicU64,
    /// Accepted submissions (async and blocking-wrapper alike).
    pub submits: AtomicU64,
    /// Sum over submissions of the lane ring occupancy observed at
    /// submit time (mean pipeline depth = / submits).
    pub depth_sum: AtomicU64,
    /// Batches dispatched per lane — the sharding observability hook.
    lane_batches: Vec<AtomicU64>,
    /// Ops routed through each lane.
    lane_ops: Vec<AtomicU64>,
}

impl ServiceStats {
    fn new(lanes: usize) -> Self {
        ServiceStats {
            batches: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            batched_ops: AtomicU64::new(0),
            device_us_total: AtomicU64::new(0),
            invalid_frees: AtomicU64::new(0),
            submits: AtomicU64::new(0),
            depth_sum: AtomicU64::new(0),
            lane_batches: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_ops: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_ops.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Mean ring occupancy observed at submit time — the effective
    /// pipeline depth clients actually ran at.
    pub fn mean_depth(&self) -> f64 {
        let s = self.submits.load(Ordering::Relaxed);
        if s == 0 {
            0.0
        } else {
            self.depth_sum.load(Ordering::Relaxed) as f64 / s as f64
        }
    }

    /// Per-lane dispatched-batch counts.
    pub fn lane_batches(&self) -> Vec<u64> {
        self.lane_batches.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Per-lane op counts.
    pub fn lane_ops(&self) -> Vec<u64> {
        self.lane_ops.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// One request lane: the avail ring (batcher) + descriptor/completion
/// ring.
struct Lane {
    batcher: Batcher,
    ring: TicketRing,
    /// Workers still serving this lane; the last one to exit — normally
    /// or by panic unwind — closes the ring so blocked clients get
    /// `ServiceDown` instead of waiting on completions that will never
    /// come (the mpsc design got this for free from dropped `Sender`s).
    workers_alive: AtomicUsize,
}

struct Inner {
    lanes: Vec<Lane>,
    policy: BatchPolicy,
    stats: ServiceStats,
    device: Device,
    alloc: Arc<dyn DeviceAllocator>,
}

impl Inner {
    /// Lane serving size class `q` (identity when lanes == NUM_QUEUES).
    fn lane_for_q(&self, q: usize) -> usize {
        let n = self.lanes.len();
        (q * n / NUM_QUEUES).min(n - 1)
    }

    /// Size class of a free, recovered from the address's chunk header;
    /// `None` for an address outside the heap (rejected at submit with
    /// `InvalidFree` — the single bounds check both the rejection and
    /// lane routing share).
    fn class_for_addr(&self, addr: u32) -> Option<usize> {
        let (chunk, _) = Heap::locate(addr);
        (chunk < self.alloc.heap().num_chunks())
            .then(|| self.alloc.heap().header(chunk).queue().min(NUM_QUEUES - 1))
    }

    /// Common submit tail: claim a descriptor on `lane`, hand it to the
    /// avail ring, account pipeline-depth stats.
    fn submit_to_lane(
        &self,
        lane: usize,
        payload: Payload,
    ) -> Result<Ticket, AllocError> {
        let l = &self.lanes[lane];
        let t = l
            .ring
            .claim(lane as u32, payload)
            .ok_or(AllocError::ServiceDown)?;
        if !l.batcher.submit(t.slot) {
            l.ring.abort(t);
            return Err(AllocError::ServiceDown);
        }
        self.stats.submits.fetch_add(1, Ordering::Relaxed);
        self.stats
            .depth_sum
            .fetch_add(l.ring.occupancy.current(), Ordering::Relaxed);
        Ok(t)
    }
}

/// Cloneable client handle. `submit_alloc`/`submit_free` + `poll`/`wait`
/// form the async pipeline; `alloc`/`free` are the blocking wrappers.
/// Each clone tracks its own outstanding tickets for `wait_all`.
pub struct ServiceClient {
    inner: Arc<Inner>,
    outstanding: Mutex<Vec<Ticket>>,
}

impl Clone for ServiceClient {
    fn clone(&self) -> Self {
        // Tickets are per-handle: a clone starts with nothing in flight.
        ServiceClient {
            inner: self.inner.clone(),
            outstanding: Mutex::new(Vec::new()),
        }
    }
}

impl ServiceClient {
    // ---- async pipeline -------------------------------------------------

    /// Submit an allocation without waiting; the op joins the lane's next
    /// batch. Blocks only if the lane ring is at capacity
    /// (`BatchPolicy::ring_slots` in flight).
    pub fn submit_alloc(&self, size: u32) -> Result<Ticket, AllocError> {
        let t = self.submit_alloc_raw(size)?;
        self.outstanding.lock().unwrap().push(t);
        Ok(t)
    }

    /// Validation + lane routing + ring claim, without the outstanding
    /// bookkeeping (the blocking wrappers reap immediately and skip it).
    fn submit_alloc_raw(&self, size: u32) -> Result<Ticket, AllocError> {
        // Submit-time binning (host mirror of the size_to_queue kernel);
        // invalid sizes never occupy a ring slot.
        let q = match queue_for_size(size) {
            Some(q) => q,
            None if size == 0 => return Err(AllocError::ZeroSize),
            None => return Err(AllocError::TooLarge(size)),
        };
        let lane = self.inner.lane_for_q(q);
        self.inner.submit_to_lane(lane, Payload::Alloc { size })
    }

    fn submit_free_raw(&self, addr: u32) -> Result<Ticket, AllocError> {
        let q = match self.inner.class_for_addr(addr) {
            Some(q) => q,
            None => {
                self.inner
                    .stats
                    .invalid_frees
                    .fetch_add(1, Ordering::Relaxed);
                return Err(AllocError::InvalidFree(addr));
            }
        };
        let lane = self.inner.lane_for_q(q);
        self.inner.submit_to_lane(lane, Payload::Free { addr })
    }

    /// Submit a free without waiting. Addresses outside the heap are
    /// rejected here with `InvalidFree` (and counted in
    /// `ServiceStats::invalid_frees`) instead of being routed through a
    /// lane to fail on the device.
    pub fn submit_free(&self, addr: u32) -> Result<Ticket, AllocError> {
        let t = self.submit_free_raw(addr)?;
        self.outstanding.lock().unwrap().push(t);
        Ok(t)
    }

    /// Non-blocking reap: `Some(completion)` exactly once per ticket,
    /// `None` while the op is still in flight (and forever for a ticket
    /// already reaped).
    pub fn poll(&self, t: Ticket) -> Option<Completion> {
        let v = self.inner.lanes[t.lane()].ring.try_take(t)?;
        self.forget(t);
        Some(v)
    }

    /// Blocking reap. Errs with `ServiceDown` only if the service died
    /// with the op unserved, or the ticket is stale.
    pub fn wait(&self, t: Ticket) -> Result<Completion, AllocError> {
        let r = self.inner.lanes[t.lane()].ring.wait(t);
        self.forget(t);
        r
    }

    /// Drain every outstanding ticket submitted through this handle, in
    /// submission order. Returns `(ticket, completion)` pairs.
    pub fn wait_all(&self) -> Vec<(Ticket, Result<Completion, AllocError>)> {
        let tickets: Vec<Ticket> = {
            let mut o = self.outstanding.lock().unwrap();
            o.drain(..).collect()
        };
        tickets
            .into_iter()
            .map(|t| (t, self.inner.lanes[t.lane()].ring.wait(t)))
            .collect()
    }

    /// Outstanding tickets on this handle (submitted, not yet reaped).
    pub fn in_flight(&self) -> usize {
        self.outstanding.lock().unwrap().len()
    }

    /// Deepest safely-pipelinable window: the lane ring capacity
    /// (`BatchPolicy::ring_slots`). A single thread submitting more than
    /// this to one lane without reaping blocks in the ring claim with
    /// nobody left to reap — callers driving a pipeline loop should
    /// clamp their depth to this.
    pub fn max_depth(&self) -> usize {
        self.inner
            .lanes
            .iter()
            .map(|l| l.ring.slots())
            .min()
            .unwrap_or(1)
    }

    fn forget(&self, t: Ticket) {
        let mut o = self.outstanding.lock().unwrap();
        if let Some(i) = o.iter().position(|x| *x == t) {
            // Order-preserving removal: `wait_all` promises submission
            // order even after interleaved poll/wait reaps.
            o.remove(i);
        }
    }

    // ---- blocking wrappers ----------------------------------------------
    // submit + wait without touching `outstanding`: the ticket never
    // outlives the call, so tracking it would only add two mutex
    // round-trips and a reap-time scan per op.

    pub fn alloc(&self, size: u32) -> Result<u32, AllocError> {
        let t = self.submit_alloc_raw(size)?;
        self.inner.lanes[t.lane()].ring.wait(t)?.into_alloc()
    }

    pub fn free(&self, addr: u32) -> Result<(), AllocError> {
        let t = self.submit_free_raw(addr)?;
        self.inner.lanes[t.lane()].ring.wait(t)?.into_free()
    }
}

pub struct AllocService {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl AllocService {
    pub fn start(
        device: Device,
        alloc: Arc<dyn DeviceAllocator>,
        policy: BatchPolicy,
    ) -> Self {
        let n_lanes = policy.lanes.clamp(1, NUM_QUEUES);
        let workers_per_lane = policy.workers_per_lane.max(1);
        let ring_slots = policy.ring_slots.max(policy.max_batch).max(1);
        let inner = Arc::new(Inner {
            lanes: (0..n_lanes)
                .map(|_| Lane {
                    batcher: Batcher::new(),
                    ring: TicketRing::new(ring_slots),
                    workers_alive: AtomicUsize::new(workers_per_lane),
                })
                .collect(),
            stats: ServiceStats::new(n_lanes),
            policy,
            device,
            alloc,
        });
        let mut workers = Vec::with_capacity(n_lanes * workers_per_lane);
        for lane in 0..n_lanes {
            for w in 0..workers_per_lane {
                let inner2 = inner.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("ouro-alloc-l{lane}w{w}"))
                        .spawn(move || Self::run_lane(inner2, lane))
                        .expect("spawning service worker"),
                );
            }
        }
        AllocService { inner, workers }
    }

    pub fn client(&self) -> ServiceClient {
        ServiceClient {
            inner: self.inner.clone(),
            outstanding: Mutex::new(Vec::new()),
        }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    /// Per-lane ring-occupancy high-water marks — how deep the pipeline
    /// actually ran on each lane.
    pub fn ring_high_water(&self) -> Vec<u64> {
        self.inner
            .lanes
            .iter()
            .map(|l| l.ring.occupancy.high_water())
            .collect()
    }

    pub fn allocator(&self) -> &Arc<dyn DeviceAllocator> {
        &self.inner.alloc
    }

    fn run_lane(inner: Arc<Inner>, lane: usize) {
        // Close the ring when the lane's last worker exits, whether it
        // drained cleanly or is unwinding from a dispatch panic — a dead
        // lane must fail its waiters, not strand them.
        struct CloseOnExit<'a>(&'a Lane);
        impl Drop for CloseOnExit<'_> {
            fn drop(&mut self) {
                if self.0.workers_alive.fetch_sub(1, Ordering::AcqRel) == 1 {
                    self.0.ring.close();
                }
            }
        }
        let l = &inner.lanes[lane];
        let _guard = CloseOnExit(l);
        while let Some(batch) = l.batcher.next_batch(&inner.policy) {
            Self::dispatch(&inner, lane, &batch);
            l.batcher.recycle(batch);
        }
    }

    /// Dispatch one lane batch of descriptor ids: group by size class (a
    /// lane holds exactly one class when fully sharded, several in the
    /// single-lane baseline), issue one coalesced device pass per
    /// (kind, class) group, then publish the whole batch's completions
    /// in one bulk write.
    fn dispatch(inner: &Inner, lane: usize, batch: &[u32]) {
        let stats = &inner.stats;
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats.lane_batches[lane].fetch_add(1, Ordering::Relaxed);
        stats.ops.fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.lane_ops[lane].fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.batched_ops.fetch_add(batch.len() as u64, Ordering::Relaxed);

        let ring = &inner.lanes[lane].ring;
        // If dispatch unwinds (a device-path panic), fail the whole
        // batch with `ServiceDown` instead of stranding its waiters on
        // completions that will never be published — the delivery
        // guarantee the mpsc design got from dropped `Sender`s. Nothing
        // in `batch` is completed until the final `complete_bulk`, so
        // while armed the guard can safely attribute every slot.
        struct FailBatchOnUnwind<'a> {
            ring: &'a TicketRing,
            batch: &'a [u32],
            armed: bool,
        }
        impl Drop for FailBatchOnUnwind<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                let failed = self
                    .batch
                    .iter()
                    .map(|&slot| {
                        let c = match self.ring.payload(slot) {
                            Payload::Alloc { .. } => {
                                Completion::Alloc(Err(AllocError::ServiceDown))
                            }
                            Payload::Free { .. } => {
                                Completion::Free(Err(AllocError::ServiceDown))
                            }
                        };
                        (slot, c)
                    })
                    .collect();
                self.ring.complete_bulk(failed);
            }
        }
        let mut guard = FailBatchOnUnwind { ring, batch, armed: true };

        // One completion sweep for the whole batch.
        let mut done: Vec<(u32, Completion)> = Vec::with_capacity(batch.len());
        let mut alloc_groups: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        let mut free_groups: BTreeMap<usize, (Vec<u32>, Vec<u32>)> =
            BTreeMap::new();
        for &slot in batch {
            match ring.payload(slot) {
                // Submit validates both invariants below; dispatch stays
                // total anyway — a regression should fail the one op,
                // not panic the lane worker and down the whole lane.
                Payload::Alloc { size } => match queue_for_size(size) {
                    Some(q) => alloc_groups.entry(q).or_default().push(slot),
                    None => done.push((
                        slot,
                        Completion::Alloc(Err(if size == 0 {
                            AllocError::ZeroSize
                        } else {
                            AllocError::TooLarge(size)
                        })),
                    )),
                },
                Payload::Free { addr } => {
                    // Class 0's device path still answers InvalidFree
                    // for any out-of-heap address that slips through.
                    let q = inner.class_for_addr(addr).unwrap_or(0);
                    let g = free_groups.entry(q).or_default();
                    g.0.push(addr);
                    g.1.push(slot);
                }
            }
        }
        for (q, slots) in alloc_groups {
            Self::dispatch_allocs(inner, q, &slots, &mut done);
        }
        for (q, (addrs, slots)) in free_groups {
            Self::dispatch_frees(inner, q, addrs, &slots, &mut done);
        }
        // Disarm before publishing: once any slot goes COMPLETE it can
        // be reaped and re-claimed, and the guard must never touch a
        // descriptor that might already host a new op.
        guard.armed = false;
        ring.complete_bulk(done);
    }

    fn dispatch_allocs(
        inner: &Inner,
        q: usize,
        slots: &[u32],
        done: &mut Vec<(u32, Completion)>,
    ) {
        let n = slots.len();
        let stats = &inner.stats;
        stats.allocs.fetch_add(n as u64, Ordering::Relaxed);
        // The bulk path bypasses `DeviceAllocator::malloc`, so account
        // the requests here (matching the warp-path bookkeeping).
        inner.alloc.counters().mallocs.fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &inner.alloc;
        // (warp base, group width, addresses, terminal error) per warp.
        let results: Mutex<Vec<(usize, usize, Vec<u32>, Option<AllocError>)>> =
            Mutex::new(Vec::new());
        let st = inner.device.launch(
            &format!("service.malloc.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                // Leader-coalesced class group: one collective point,
                // then one bulk queue op for the whole warp.
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let mut out = Vec::with_capacity(width);
                let err =
                    alloc.malloc_bulk(&w.ctx, q, width as u32, &mut out).err();
                results.lock().unwrap().push((base, width, out, err));
            },
        );
        stats.device_us_total.fetch_add(st.device_us as u64, Ordering::Relaxed);

        let mut flat: Vec<Result<u32, AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, width, out, err) in results.into_inner().unwrap() {
            for i in 0..width {
                flat[base + i] = match out.get(i) {
                    Some(&a) => Ok(a),
                    None => Err(err.unwrap_or(AllocError::QueueCorrupt)),
                };
            }
        }
        done.extend(
            slots
                .iter()
                .zip(flat)
                .map(|(&slot, r)| (slot, Completion::Alloc(r))),
        );
    }

    fn dispatch_frees(
        inner: &Inner,
        q: usize,
        addrs: Vec<u32>,
        slots: &[u32],
        done: &mut Vec<(u32, Completion)>,
    ) {
        let n = addrs.len();
        let stats = &inner.stats;
        stats.frees.fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &inner.alloc;
        let addrs_ref = &addrs;
        let results: Mutex<Vec<(usize, Vec<Result<(), AllocError>>)>> =
            Mutex::new(Vec::new());
        let st = inner.device.launch(
            &format!("service.free.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let rs = alloc.free_bulk(&w.ctx, &addrs_ref[base..base + width]);
                results.lock().unwrap().push((base, rs));
            },
        );
        stats.device_us_total.fetch_add(st.device_us as u64, Ordering::Relaxed);

        let mut flat: Vec<Result<(), AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, rs) in results.into_inner().unwrap() {
            for (i, r) in rs.into_iter().enumerate() {
                flat[base + i] = r;
            }
        }
        done.extend(
            slots
                .iter()
                .zip(flat)
                .map(|(&slot, r)| (slot, Completion::Free(r))),
        );
    }

    fn stop_and_join(&mut self) {
        for lane in &self.inner.lanes {
            lane.batcher.stop();
        }
        // Ring closing is owned by the workers' CloseOnExit guards: by
        // the time these joins return, every lane's last worker has
        // drained its accepted ops and closed its ring (the guard also
        // covers panic unwinds, which never reach this point).
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) -> u64 {
        self.stop_and_join();
        self.inner.stats.ops.load(Ordering::Relaxed)
    }
}

impl Drop for AllocService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Cuda;
    use crate::ouroboros::{build_allocator, HeapConfig, Variant};
    use crate::simt::DeviceProfile;

    fn service() -> AllocService {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::test_small());
        AllocService::start(device, alloc, BatchPolicy::default())
    }

    #[test]
    fn alloc_free_roundtrip_through_service() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(1000).unwrap();
        let b = c.alloc(1000).unwrap();
        assert_ne!(a, b);
        c.free(a).unwrap();
        c.free(b).unwrap();
        assert!(svc.stats().ops.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn async_submit_wait_matches_blocking() {
        let svc = service();
        let c = svc.client();
        let t = c.submit_alloc(512).unwrap();
        let a = c.wait(t).unwrap().into_alloc().unwrap();
        let tf = c.submit_free(a).unwrap();
        c.wait(tf).unwrap().into_free().unwrap();
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn pipelined_submits_batch_and_wait_all_drains() {
        let svc = service();
        let c = svc.client();
        // 32 same-class ops in flight from ONE client thread: the whole
        // point of the pipeline — the lane can gather a wide batch
        // without 32 blocking threads.
        let tickets: Vec<Ticket> =
            (0..32).map(|_| c.submit_alloc(1000).unwrap()).collect();
        assert_eq!(c.in_flight(), 32);
        let done = c.wait_all();
        assert_eq!(done.len(), 32);
        assert_eq!(c.in_flight(), 0);
        let mut addrs: Vec<u32> = done
            .into_iter()
            .map(|(_, r)| r.unwrap().into_alloc().unwrap())
            .collect();
        let n = addrs.len();
        addrs.sort_unstable();
        addrs.dedup();
        assert_eq!(addrs.len(), n, "pipeline handed out duplicate addresses");
        for a in addrs {
            c.free(a).unwrap();
        }
        // Ticket identities round-trip (first ticket was for lane q6).
        assert_eq!(tickets[0].lane(), 6);
        // The pipeline actually ran deep.
        assert!(svc.ring_high_water()[6] > 1);
        assert!(svc.stats().mean_depth() > 1.0);
    }

    #[test]
    fn poll_reaps_exactly_once() {
        let svc = service();
        let c = svc.client();
        let t = c.submit_alloc(64).unwrap();
        // Spin-poll until complete.
        let completion = loop {
            if let Some(v) = c.poll(t) {
                break v;
            }
            std::thread::yield_now();
        };
        let a = completion.into_alloc().unwrap();
        assert_eq!(c.poll(t), None, "second poll of a reaped ticket");
        c.free(a).unwrap();
    }

    #[test]
    fn concurrent_clients_get_unique_addresses() {
        let svc = service();
        let addrs = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = svc.client();
                let addrs = &addrs;
                s.spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..20 {
                        mine.push(c.alloc(64).unwrap());
                    }
                    addrs.lock().unwrap().extend(mine);
                });
            }
        });
        let mut got = addrs.into_inner().unwrap();
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "service handed out duplicate addresses");
        // Batching actually happened (mean batch > 1 with 8 clients).
        assert!(svc.stats().mean_batch() > 1.0);
    }

    #[test]
    fn oversize_rejected_through_service() {
        let svc = service();
        let c = svc.client();
        assert_eq!(c.alloc(9000), Err(AllocError::TooLarge(9000)));
        assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn out_of_heap_free_rejected_at_submit() {
        let svc = service();
        let c = svc.client();
        let before = svc.stats().batches.load(Ordering::Relaxed);
        assert_eq!(
            c.submit_free(0xDEAD_0000).unwrap_err(),
            AllocError::InvalidFree(0xDEAD_0000)
        );
        assert_eq!(c.free(0xDEAD_0000), Err(AllocError::InvalidFree(0xDEAD_0000)));
        assert_eq!(svc.stats().invalid_frees.load(Ordering::Relaxed), 2);
        // The wild frees never occupied a lane batch.
        assert_eq!(svc.stats().batches.load(Ordering::Relaxed), before);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = service();
        let c = svc.client();
        c.alloc(128).unwrap();
        let ops = svc.shutdown();
        assert!(ops >= 1);
    }

    #[test]
    fn dead_service_reports_service_down_not_corruption() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(256).unwrap();
        c.free(a).unwrap();
        svc.shutdown();
        assert_eq!(c.alloc(256), Err(AllocError::ServiceDown));
        assert_eq!(c.free(a), Err(AllocError::ServiceDown));
        assert!(c.submit_alloc(256).is_err());
    }

    #[test]
    fn submitted_work_completes_across_shutdown() {
        let svc = service();
        let c = svc.client();
        let tickets: Vec<Ticket> =
            (0..8).map(|_| c.submit_alloc(100).unwrap()).collect();
        // Shutdown drains accepted ops before the workers exit, so every
        // ticket still resolves to a real completion.
        svc.shutdown();
        for t in tickets {
            c.wait(t).unwrap().into_alloc().unwrap();
        }
    }

    #[test]
    fn lanes_shard_by_size_class() {
        let svc = service();
        let c = svc.client();
        // Three distinct classes: q0 (16 B), q6 (1000 B), q9 (8 KiB).
        let mut addrs = Vec::new();
        for &size in &[16u32, 1000, 8192] {
            for _ in 0..4 {
                addrs.push(c.alloc(size).unwrap());
            }
        }
        for a in addrs {
            c.free(a).unwrap();
        }
        let lanes = svc.stats().lane_batches();
        assert_eq!(lanes.len(), NUM_QUEUES);
        for q in [0usize, 6, 9] {
            assert!(lanes[q] > 0, "lane {q} saw no batches: {lanes:?}");
        }
        // Classes that never saw a request stay silent lanes.
        assert_eq!(lanes[3], 0, "unexpected traffic on idle lane: {lanes:?}");
        // Per-lane counts are a partition of the aggregate.
        assert_eq!(
            lanes.iter().sum::<u64>(),
            svc.stats().batches.load(Ordering::Relaxed)
        );
        assert_eq!(
            svc.stats().lane_ops().iter().sum::<u64>(),
            svc.stats().ops.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn single_lane_policy_still_works() {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Chunk, &HeapConfig::test_small());
        let svc =
            AllocService::start(device, alloc, BatchPolicy::single_lane());
        let c = svc.client();
        let addrs: Vec<u32> = (0u32..16)
            .map(|i| c.alloc(16u32 << (i % 5)).unwrap())
            .collect();
        for a in addrs {
            c.free(a).unwrap();
        }
        assert_eq!(svc.stats().lane_batches().len(), 1);
        assert!(svc.stats().lane_batches()[0] > 0);
    }
}
