//! The allocation service: a leader thread owning the simulated device,
//! serving malloc/free requests from any number of client threads through
//! the warp-shaped [`Batcher`].
//!
//! This is the deployment shape of the library (vLLM-router-style): the
//! rust coordinator owns the device and the event loop; clients hold
//! cheap cloneable handles. The service path is also where the batch
//! planner artifact (`plan_alloc`) can pre-bin request sizes via PJRT —
//! see `examples/planner_service.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::ouroboros::{
    allocator::{warp_free, warp_malloc},
    AllocError, DeviceAllocator,
};
use crate::simt::{Device, Grid};

use super::batcher::{BatchPolicy, Batcher, Op};

#[derive(Debug, Default)]
pub struct ServiceStats {
    pub batches: AtomicU64,
    pub ops: AtomicU64,
    pub allocs: AtomicU64,
    pub frees: AtomicU64,
    /// Sum of batch sizes (mean batch = / batches).
    pub batched_ops: AtomicU64,
    pub device_us_total: AtomicU64,
}

impl ServiceStats {
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_ops.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

struct Inner {
    batcher: Batcher,
    policy: BatchPolicy,
    stats: ServiceStats,
    device: Device,
    alloc: Arc<dyn DeviceAllocator>,
}

/// Cloneable client handle; blocking calls.
#[derive(Clone)]
pub struct ServiceClient {
    inner: Arc<Inner>,
}

impl ServiceClient {
    pub fn alloc(&self, size: u32) -> Result<u32, AllocError> {
        let (tx, rx) = channel();
        self.inner.batcher.submit(Op::Alloc { size, reply: tx });
        rx.recv().unwrap_or(Err(AllocError::QueueCorrupt))
    }

    pub fn free(&self, addr: u32) -> Result<(), AllocError> {
        let (tx, rx) = channel();
        self.inner.batcher.submit(Op::Free { addr, reply: tx });
        rx.recv().unwrap_or(Err(AllocError::QueueCorrupt))
    }
}

pub struct AllocService {
    inner: Arc<Inner>,
    worker: Option<JoinHandle<()>>,
}

impl AllocService {
    pub fn start(
        device: Device,
        alloc: Arc<dyn DeviceAllocator>,
        policy: BatchPolicy,
    ) -> Self {
        let inner = Arc::new(Inner {
            batcher: Batcher::new(),
            policy,
            stats: ServiceStats::default(),
            device,
            alloc,
        });
        let inner2 = inner.clone();
        let worker = std::thread::Builder::new()
            .name("ouro-alloc-service".into())
            .spawn(move || Self::run(inner2))
            .expect("spawning service worker");
        AllocService { inner, worker: Some(worker) }
    }

    pub fn client(&self) -> ServiceClient {
        ServiceClient { inner: self.inner.clone() }
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    pub fn allocator(&self) -> &Arc<dyn DeviceAllocator> {
        &self.inner.alloc
    }

    fn run(inner: Arc<Inner>) {
        while let Some(batch) = inner.batcher.next_batch(&inner.policy) {
            let stats = &inner.stats;
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats.ops.fetch_add(batch.len() as u64, Ordering::Relaxed);
            stats
                .batched_ops
                .fetch_add(batch.len() as u64, Ordering::Relaxed);

            let mut alloc_sizes = Vec::new();
            let mut alloc_replies = Vec::new();
            let mut free_addrs = Vec::new();
            let mut free_replies = Vec::new();
            for op in batch {
                match op {
                    Op::Alloc { size, reply } => {
                        alloc_sizes.push(size);
                        alloc_replies.push(reply);
                    }
                    Op::Free { addr, reply } => {
                        free_addrs.push(addr);
                        free_replies.push(reply);
                    }
                }
            }

            if !alloc_sizes.is_empty() {
                stats
                    .allocs
                    .fetch_add(alloc_sizes.len() as u64, Ordering::Relaxed);
                let alloc = inner.alloc.clone();
                let sizes = alloc_sizes.clone();
                let results = std::sync::Mutex::new(Vec::new());
                let st = inner.device.launch(
                    "service.malloc",
                    Grid::new(alloc_sizes.len() as u32),
                    |w| {
                        let lanes: Vec<u32> = w.active_lanes().collect();
                        let base = w.thread_id(0) as usize;
                        let mine = &sizes[base..base + lanes.len()];
                        let rs = warp_malloc(alloc.as_ref(), w, mine);
                        results.lock().unwrap().push((base, rs));
                    },
                );
                stats
                    .device_us_total
                    .fetch_add(st.device_us as u64, Ordering::Relaxed);
                let mut flat: Vec<Option<Result<u32, AllocError>>> =
                    vec![None; alloc_replies.len()];
                for (base, rs) in results.into_inner().unwrap() {
                    for (i, r) in rs.into_iter().enumerate() {
                        flat[base + i] = Some(r);
                    }
                }
                for (reply, r) in alloc_replies.into_iter().zip(flat) {
                    let _ = reply.send(r.unwrap_or(Err(AllocError::QueueCorrupt)));
                }
            }

            if !free_addrs.is_empty() {
                stats
                    .frees
                    .fetch_add(free_addrs.len() as u64, Ordering::Relaxed);
                let alloc = inner.alloc.clone();
                let addrs = free_addrs.clone();
                let results = std::sync::Mutex::new(Vec::new());
                let st = inner.device.launch(
                    "service.free",
                    Grid::new(free_addrs.len() as u32),
                    |w| {
                        let lanes: Vec<u32> = w.active_lanes().collect();
                        let base = w.thread_id(0) as usize;
                        let mine: Vec<Option<u32>> = lanes
                            .iter()
                            .enumerate()
                            .map(|(i, _)| Some(addrs[base + i]))
                            .collect();
                        let rs = warp_free(alloc.as_ref(), w, &mine);
                        results.lock().unwrap().push((base, rs));
                    },
                );
                stats
                    .device_us_total
                    .fetch_add(st.device_us as u64, Ordering::Relaxed);
                let mut flat: Vec<Option<Result<(), AllocError>>> =
                    vec![None; free_replies.len()];
                for (base, rs) in results.into_inner().unwrap() {
                    for (i, r) in rs.into_iter().enumerate() {
                        flat[base + i] = Some(r);
                    }
                }
                for (reply, r) in free_replies.into_iter().zip(flat) {
                    let _ = reply.send(r.unwrap_or(Err(AllocError::QueueCorrupt)));
                }
            }
        }
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) -> u64 {
        self.inner.batcher.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.inner.stats.ops.load(Ordering::Relaxed)
    }
}

impl Drop for AllocService {
    fn drop(&mut self) {
        self.inner.batcher.stop();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Cuda;
    use crate::ouroboros::{build_allocator, HeapConfig, Variant};
    use crate::simt::DeviceProfile;

    fn service() -> AllocService {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::test_small());
        AllocService::start(device, alloc, BatchPolicy::default())
    }

    #[test]
    fn alloc_free_roundtrip_through_service() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(1000).unwrap();
        let b = c.alloc(1000).unwrap();
        assert_ne!(a, b);
        c.free(a).unwrap();
        c.free(b).unwrap();
        assert!(svc.stats().ops.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn concurrent_clients_get_unique_addresses() {
        let svc = service();
        let addrs = std::sync::Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = svc.client();
                let addrs = &addrs;
                s.spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..20 {
                        mine.push(c.alloc(64).unwrap());
                    }
                    addrs.lock().unwrap().extend(mine);
                });
            }
        });
        let mut got = addrs.into_inner().unwrap();
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "service handed out duplicate addresses");
        // Batching actually happened (mean batch > 1 with 8 clients).
        assert!(svc.stats().mean_batch() > 1.0);
    }

    #[test]
    fn oversize_rejected_through_service() {
        let svc = service();
        let c = svc.client();
        assert_eq!(c.alloc(9000), Err(AllocError::TooLarge(9000)));
        assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = service();
        let c = svc.client();
        c.alloc(128).unwrap();
        let ops = svc.shutdown();
        assert!(ops >= 1);
    }
}
