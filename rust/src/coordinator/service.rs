//! The allocation service: a **device-group topology** — N simulated
//! devices (possibly heterogeneous, e.g. a `t2000` next to an
//! `iris_xe`), each owning its own heap and a full set of per-size-class
//! ticket lanes, behind a submit-time placement router.
//!
//! This is the deployment shape of the library (vLLM-router-style): the
//! rust coordinator owns the devices and the event loops; clients hold
//! cheap cloneable handles. Two routing decisions happen **at submit
//! time**:
//!
//! 1. **Placement** (allocs only): the router picks the device under
//!    the configured [`RoutePolicy`] — round-robin, least-loaded by
//!    live ring occupancy, client affinity, or capacity-aware by heap
//!    occupancy with shed/readmit hysteresis.
//! 2. **Binning**: within the chosen device, the request is binned by
//!    size class (the host-side mirror of the kernel-side
//!    `size_to_queue`) into that device's per-class lane.
//!
//! Completed allocations come back as device-tagged
//! [`GlobalAddr`]s (device id in the high bits). **Frees are never
//! routed by policy**: the address's tag names the owning device, and
//! the free travels to that device's lane no matter which client handle
//! submitted it — cross-client, cross-device frees are first-class.
//! The lanes keep the properties the single-device service had:
//!
//! * lanes never contend on a shared queue lock or condvar;
//! * every lane batch is a same-class group on one device, dispatched
//!   through the coalesced bulk paths (`malloc_bulk` / `free_bulk`);
//! * each lane has its own device worker(s), so classes — and now whole
//!   devices — make progress independently.
//!
//! # The async ticket pipeline
//!
//! The hot path is **submit/poll**, not call/return. Each lane pairs its
//! [`Batcher`] (the avail ring) with a ticket ring (descriptor table
//! + completion states + free list — see `ring.rs`). A client submits
//! at depth:
//!
//! ```text
//! let t1 = client.submit_alloc(96)?;        // router places, lane claims
//! let t2 = client.submit_alloc(1000)?;      // second op in flight
//! let a1 = client.wait(t1)?.into_alloc()?;  // a device-tagged GlobalAddr
//! if let Some(c) = client.poll(t2) { ... }  // non-blocking reap
//! client.wait_all();                        // drain this handle
//! ```
//!
//! Completions are published **once per dispatched batch**; the classic
//! blocking [`ServiceClient::alloc`] / [`ServiceClient::free`] survive
//! as `submit + wait` wrappers.
//!
//! # Ticket ownership semantics
//!
//! A [`Ticket`] is a name for a ring descriptor, not a capability bound
//! to the submitting handle:
//!
//! * **Any handle of the same service** may `poll`/`wait` a ticket —
//!   cross-handle reaping is supported (useful for hand-off patterns).
//!   The descriptor generation guard makes the hand-off race-free: the
//!   completion is delivered **exactly once**, to whichever handle
//!   reaps first.
//! * A ticket **already reaped** (by any handle) is *stale* everywhere:
//!   `poll` returns `None` forever, `wait` returns
//!   [`AllocError::ServiceDown`] — never a hang, never another op's
//!   payload. Note `wait_all` only tracks tickets submitted through its
//!   own handle, so a ticket reaped through a different handle shows up
//!   there as this stale error.
//! * A ticket minted by a **different service instance** is rejected
//!   deterministically: `poll` returns `None`, `wait` returns
//!   [`AllocError::ForeignTicket`] (every service carries a process-
//!   unique tag, stamped into each ticket at submit).
//!
//! Invalid requests never occupy a ring slot: oversize/zero allocs and
//! frees whose device tag or chunk index is out of range are rejected
//! at submit (`AllocError::InvalidFree`, counted in
//! `ServiceStats::invalid_frees`).
//!
//! `AllocService::start` keeps the one-device signature (a group of
//! one, bit-for-bit the pre-group address space);
//! `AllocService::start_group` is the topology constructor.
//!
//! # Failover, self-healing and rebalancing
//!
//! The group survives losing a member — and heals: see `rebalance.rs`
//! for the `healthy → draining → retired → readmitting` state machine,
//! [`AllocService::drain_device`] / [`AllocService::drain_device_paced`]
//! (live-set migration onto healthy members — stop-the-world or a few
//! blocks per tick from a persistent cursor — with stale frees
//! forwarded through a grace-windowed table),
//! [`AllocService::retire_device`] (in-flight tickets failed with the
//! deterministic [`AllocError::DeviceRetired`]; queued frees whose
//! blocks already migrated are delivered to the copies),
//! [`AllocService::readmit_device`] (repaired members rejoin with fresh
//! lanes over an asserted-empty heap), the
//! [`HealthMonitor`](super::rebalance::HealthMonitor) watchdog that
//! drives all of the above automatically, and
//! [`AllocService::migrate`] (single-allocation rebalancing).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{
    AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::backend::Backend;
use crate::check::history::{HistoryRecorder, OpKind, OpRecord};
use crate::check::lockgraph::{classes, OrderedMutex};
use crate::ouroboros::addr::{DEVICE_SPAN, MAX_DEVICES};
use crate::ouroboros::params::{queue_for_size, NUM_QUEUES};
use crate::ouroboros::{
    build_allocator, AllocError, DeviceAllocator, GlobalAddr, Heap,
    HeapConfig, Variant,
};
use crate::simt::{Device, DeviceProfile, Grid};

use super::batcher::{BatchPolicy, Batcher};
use super::lease::{
    cacheable_class, span_bytes, ClientCache, Lease, LeaseRegistry,
    SPAN_CLASS,
};
use super::rebalance::{
    Clock, DrainCursor, ForwardVerdict, ForwardingTable, SystemClock,
};
use super::ring::{Completion, Payload, Ticket, TicketRing};
use super::router::{DeviceState, RoutePolicy, Router};
use super::snapshot::{CursorSnapshot, ServiceSnapshot};
use super::stats::{DeviceSnapshot, LatencyHist, StatsSnapshot};

/// Process-unique service tags (ticket provenance; 0 is reserved for
/// "not yet stamped").
static NEXT_SVC_TAG: AtomicU32 = AtomicU32::new(1);

/// Process-unique client-handle ids, stamped onto ring descriptors so
/// the `OURO_LIN` history attributes every op to the handle that
/// submitted it (0 is reserved for service-internal ops).
static NEXT_CLIENT_ID: AtomicU64 = AtomicU64::new(1);

#[derive(Debug)]
pub struct ServiceStats {
    pub batches: AtomicU64,
    pub ops: AtomicU64,
    pub allocs: AtomicU64,
    pub frees: AtomicU64,
    /// Sum of batch sizes (mean batch = / batches).
    pub batched_ops: AtomicU64,
    /// Frees rejected at submit because the device tag or chunk index
    /// is out of range — they never reach a lane.
    pub invalid_frees: AtomicU64,
    /// Accepted submissions (async and blocking-wrapper alike).
    pub submits: AtomicU64,
    /// Sum over submissions of the lane ring occupancy observed at
    /// submit time (mean pipeline depth = / submits).
    pub depth_sum: AtomicU64,
    /// Allocations moved between members by live-set migration
    /// (`AllocService::migrate` / `drain_device`).
    pub migrations: AtomicU64,
    /// Stale frees of migrated addresses rewritten through the
    /// forwarding table (each address forwards at most once).
    pub forwarded_frees: AtomicU64,
    /// In-flight ops failed with `AllocError::DeviceRetired` when a
    /// retiring member's lanes were drained.
    pub retired_ops: AtomicU64,
    /// Members brought back through `AllocService::readmit_device`.
    pub readmits: AtomicU64,
    /// Blocking allocs transparently re-attempted by the client-side
    /// retry loop after a transient `DeviceRetired` (shed window,
    /// mid-retire race) — each backoff+resubmit counts once.
    pub alloc_retries: AtomicU64,
    /// Lease spans minted for client caches (one ring alloc each).
    pub lease_mints: AtomicU64,
    /// Lease spans returned to their device (one ring free each,
    /// except spans stranded by a hard retire).
    pub lease_returns: AtomicU64,
    /// Leases recalled by drain/retire before their owner released
    /// them.
    pub lease_recalls: AtomicU64,
    /// Allocations served from a client's local lease cache — zero
    /// ring traffic each.
    pub cached_allocs: AtomicU64,
    /// Frees absorbed by the lease registry (owner-local or delayed).
    pub cached_frees: AtomicU64,
    /// The cross-client subset of `cached_frees`: frees parked on a
    /// lease's delayed list for the owner's renewal drain.
    pub delayed_frees: AtomicU64,
    /// Per-op latency of the cached client path (serve/free, no ring).
    pub cached_hist: LatencyHist,
    /// Per-op latency of the ring path (descriptor claim → publish).
    pub ring_hist: LatencyHist,
    /// Batches dispatched per lane (flat, device-major) — the sharding
    /// observability hook.
    lane_batches: Vec<AtomicU64>,
    /// Ops routed through each lane (flat, device-major).
    lane_ops: Vec<AtomicU64>,
    /// Per-device rollups (group observability).
    device_names: Vec<&'static str>,
    /// Batches dispatched per device — also the watchdog's lane-progress
    /// heartbeat (ring occupancy without batch progress = stall).
    /// `pub(crate)`: the health monitor in `rebalance.rs` samples it.
    pub(crate) device_batches: Vec<AtomicU64>,
    device_ops: Vec<AtomicU64>,
    /// Alloc requests routed per device (successes and failures alike) —
    /// the denominator of the watchdog's error-rate signal.
    pub(crate) device_allocs: Vec<AtomicU64>,
    device_frees: Vec<AtomicU64>,
    /// Alloc requests that completed with an error, per device — the
    /// numerator of the watchdog's error-rate signal (a member whose
    /// error rate spikes is tripped even while it still makes dispatch
    /// progress).
    pub(crate) device_alloc_errors: Vec<AtomicU64>,
    /// Modeled busy time per device, nanoseconds (ns so sub-µs batches
    /// don't truncate to zero). `pub(crate)`: migration launches in
    /// `rebalance.rs` charge their device time here too.
    pub(crate) device_ns: Vec<AtomicU64>,
}

impl ServiceStats {
    fn new(lanes: usize, device_names: Vec<&'static str>) -> Self {
        let n_dev = device_names.len();
        let zeros = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect();
        ServiceStats {
            batches: AtomicU64::new(0),
            ops: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            frees: AtomicU64::new(0),
            batched_ops: AtomicU64::new(0),
            invalid_frees: AtomicU64::new(0),
            submits: AtomicU64::new(0),
            depth_sum: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            forwarded_frees: AtomicU64::new(0),
            retired_ops: AtomicU64::new(0),
            readmits: AtomicU64::new(0),
            alloc_retries: AtomicU64::new(0),
            lease_mints: AtomicU64::new(0),
            lease_returns: AtomicU64::new(0),
            lease_recalls: AtomicU64::new(0),
            cached_allocs: AtomicU64::new(0),
            cached_frees: AtomicU64::new(0),
            delayed_frees: AtomicU64::new(0),
            cached_hist: LatencyHist::new(),
            ring_hist: LatencyHist::new(),
            lane_batches: zeros(lanes),
            lane_ops: zeros(lanes),
            device_batches: zeros(n_dev),
            device_ops: zeros(n_dev),
            device_allocs: zeros(n_dev),
            device_frees: zeros(n_dev),
            device_alloc_errors: zeros(n_dev),
            device_ns: zeros(n_dev),
            device_names,
        }
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed); // ordering: stat read
        if b == 0 {
            0.0
        } else {
            self.batched_ops.load(Ordering::Relaxed) as f64 / b as f64 // ordering: stat read
        }
    }

    /// Mean ring occupancy observed at submit time — the effective
    /// pipeline depth clients actually ran at.
    pub fn mean_depth(&self) -> f64 {
        let s = self.submits.load(Ordering::Relaxed); // ordering: stat read
        if s == 0 {
            0.0
        } else {
            self.depth_sum.load(Ordering::Relaxed) as f64 / s as f64 // ordering: stat read
        }
    }

    /// Per-lane dispatched-batch counts (flat, device-major).
    pub fn lane_batches(&self) -> Vec<u64> {
        self.lane_batches.iter().map(|c| c.load(Ordering::Relaxed)).collect() // ordering: stat read
    }

    /// Per-lane op counts (flat, device-major).
    pub fn lane_ops(&self) -> Vec<u64> {
        self.lane_ops.iter().map(|c| c.load(Ordering::Relaxed)).collect() // ordering: stat read
    }

    /// Plain-value copy of every counter plus the derived ratios and
    /// the per-device rollups — see [`StatsSnapshot`] for the
    /// consistency caveat.
    pub fn snapshot(&self) -> StatsSnapshot {
        let r = Ordering::Relaxed; // ordering: Relaxed snapshot; independent stat counters
        StatsSnapshot {
            batches: self.batches.load(r),
            ops: self.ops.load(r),
            allocs: self.allocs.load(r),
            frees: self.frees.load(r),
            batched_ops: self.batched_ops.load(r),
            invalid_frees: self.invalid_frees.load(r),
            submits: self.submits.load(r),
            migrations: self.migrations.load(r),
            forwarded_frees: self.forwarded_frees.load(r),
            retired_ops: self.retired_ops.load(r),
            readmits: self.readmits.load(r),
            alloc_retries: self.alloc_retries.load(r),
            lease_mints: self.lease_mints.load(r),
            lease_returns: self.lease_returns.load(r),
            lease_recalls: self.lease_recalls.load(r),
            cached_allocs: self.cached_allocs.load(r),
            cached_frees: self.cached_frees.load(r),
            delayed_frees: self.delayed_frees.load(r),
            // The bare counter snapshot has no lane access; the
            // suppression tallies live on each lane's ring/batcher and
            // `AllocService::snapshot` sums them in.
            wakeup_delivered: 0,
            wakeup_suppressed: 0,
            doorbell_delivered: 0,
            doorbell_suppressed: 0,
            cached_latency: self.cached_hist.snapshot(),
            ring_latency: self.ring_hist.snapshot(),
            mean_batch: self.mean_batch(),
            mean_depth: self.mean_depth(),
            lane_batches: self.lane_batches(),
            lane_ops: self.lane_ops(),
            devices: self
                .device_names
                .iter()
                .enumerate()
                .map(|(d, &name)| DeviceSnapshot {
                    name,
                    batches: self.device_batches[d].load(r),
                    ops: self.device_ops[d].load(r),
                    allocs: self.device_allocs[d].load(r),
                    frees: self.device_frees[d].load(r),
                    alloc_errors: self.device_alloc_errors[d].load(r),
                    device_us: self.device_ns[d].load(r) as f64 / 1e3,
                    // The bare counter snapshot has no heap or router
                    // access; `AllocService::snapshot` fills these from
                    // the live group.
                    heap_occupancy: 0.0,
                    state: "healthy",
                })
                .collect(),
        }
    }
}

/// One request lane: the avail ring (batcher) + descriptor/completion
/// ring.
pub(crate) struct Lane {
    pub(crate) batcher: Batcher,
    pub(crate) ring: TicketRing,
    /// Workers still serving this lane; the last one to exit — normally
    /// or by panic unwind — closes the ring so blocked clients get
    /// `ServiceDown` instead of waiting on completions that will never
    /// come (the mpsc design got this for free from dropped `Sender`s).
    /// `pub(crate)`: `readmit_device` re-arms it before spawning a
    /// readmitted member's fresh workers.
    pub(crate) workers_alive: AtomicUsize,
    /// Set by `AllocService::retire_device` *before* the lane's batcher
    /// stops: the workers' final drain then fails every still-queued op
    /// with `DeviceRetired` instead of dispatching it, and submit-path
    /// refusals on this lane report `DeviceRetired` rather than
    /// `ServiceDown`.
    pub(crate) retired: AtomicBool,
}

/// One device-group member: the simulated device plus its allocator
/// (and through it, its heap).
pub(crate) struct Member {
    pub(crate) device: Device,
    pub(crate) alloc: Arc<dyn DeviceAllocator>,
}

pub(crate) struct Inner {
    pub(crate) members: Vec<Member>,
    /// All lanes, flat device-major: lane `d * lanes_per_device + l`
    /// serves device `d`.
    pub(crate) lanes: Vec<Lane>,
    pub(crate) lanes_per_device: usize,
    pub(crate) policy: BatchPolicy,
    /// Lane workers, tagged with the flat lane index they serve so
    /// `retire_device` can join exactly the retiring member's threads
    /// and `readmit_device` can hand a member fresh ones. Lives in
    /// `Inner` (not the owning `AllocService`) so the health watchdog's
    /// background thread can drive the retire path through its
    /// `Arc<Inner>` alone.
    pub(crate) workers: OrderedMutex<Vec<(usize, JoinHandle<()>)>>,
    pub(crate) router: Router,
    pub(crate) stats: ServiceStats,
    /// Old→new address map for migrated allocations (stale frees are
    /// forwarded through it exactly once, within a grace window).
    pub(crate) forwarding: ForwardingTable,
    /// Per-member count of allocations placed but not yet executed.
    /// `drain_device` quiesces on this before enumerating the live set:
    /// an alloc routed to a member while it was still healthy may land
    /// on its heap after the draining mark, and must be visible to the
    /// migration sweep. SeqCst everywhere (with the router's state
    /// atomics) so "saw Healthy at submit" implies "gauge increment
    /// visible to the drain's quiesce loop".
    pub(crate) alloc_inflight: Vec<AtomicU64>,
    /// Serialises the control plane: individual migrations and member
    /// retirement take this, so concurrent drains of the same live set
    /// cannot double-migrate a block, and `RetireReport` deltas over
    /// the shared `retired_ops` counter attribute to one retire at a
    /// time. Never held across a wait on client traffic.
    pub(crate) rebalance_lock: OrderedMutex<()>,
    /// Per-member paced-drain cursor: where the incremental live-set
    /// sweep resumes after an interrupted `drain_tick` sequence. Locked
    /// under `rebalance_lock` (lock order: plane, then cursor).
    pub(crate) drain_cursors: Vec<OrderedMutex<DrainCursor>>,
    /// Chaos hook: a member whose flag is set has its lane workers park
    /// *between* taking a batch and dispatching it, so claimed ops pile
    /// up with no dispatch progress — exactly the wedged-device shape
    /// the health watchdog's stall detector keys on. Test/bench only;
    /// cleared by retirement (a retired lane's final drain proceeds).
    pub(crate) stall_inject: Vec<AtomicBool>,
    /// Service-wide index of live client-cache leases (see
    /// `super::lease`): every free consults it (behind a one-load
    /// gate) so cached block names — which the heaps have never heard
    /// of — resolve no matter which handle frees them, and the
    /// drain/retire paths enumerate it to recall spans out of client
    /// caches.
    pub(crate) leases: LeaseRegistry,
    /// Process-unique instance tag stamped into every ticket.
    svc_tag: u32,
    /// Round-robin affinity assignment for new client handles.
    next_affinity: AtomicUsize,
    /// Shadow-heap sanitizer (`OURO_SAN=1`): mirrors every address
    /// lifecycle event out of the dispatch/migrate paths. `None` (the
    /// default) costs one branch per dispatched batch.
    pub(crate) san: Option<Arc<crate::check::sanitizer::ShadowHeap>>,
    /// Set by `AllocService::prepare_handoff`: the shadow heap is being
    /// handed to a successor service, so this instance's shutdown must
    /// *not* run the leak check — blocks that outlive a restart are the
    /// whole point of the handoff, not leaks.
    pub(crate) san_detached: AtomicBool,
    /// `OURO_LIN=1` op-history recorder (see `crate::check::history`):
    /// every successful alloc/free/migrate/lease transition is recorded
    /// with its real invocation/response interval for offline
    /// linearizability checking. `None` (the default) costs one branch
    /// per dispatched group.
    pub(crate) lin: Option<Arc<HistoryRecorder>>,
}

impl Inner {
    /// Flat index of the lane serving size class `q` on `device`
    /// (identity within a device when lanes_per_device == NUM_QUEUES).
    pub(crate) fn lane_index(&self, device: usize, q: usize) -> usize {
        let n = self.lanes_per_device;
        device * n + (q * n / NUM_QUEUES).min(n - 1)
    }

    /// Group device a flat lane index serves.
    pub(crate) fn device_of_lane(&self, lane: usize) -> usize {
        lane / self.lanes_per_device
    }

    /// Decode a free's owning device and size class from its global
    /// address: the device tag must name a group member and the chunk
    /// must be inside that member's heap (the single bounds check the
    /// `InvalidFree` fast-reject and lane routing share). The class is
    /// recovered from the chunk header on the owning device.
    fn class_for_addr(&self, addr: GlobalAddr) -> Option<(usize, usize)> {
        if !addr.device_in(self.members.len()) {
            return None;
        }
        let dev = addr.device() as usize;
        let heap = self.members[dev].alloc.heap();
        let (chunk, _) = Heap::locate(addr.local());
        (chunk < heap.num_chunks())
            .then(|| (dev, heap.header(chunk).queue().min(NUM_QUEUES - 1)))
    }

    /// Whether `t` was minted by this service (and its lane index is in
    /// range — always true for own tickets, guards forged ones).
    fn owns_ticket(&self, t: Ticket) -> bool {
        t.svc == self.svc_tag && (t.lane as usize) < self.lanes.len()
    }

    /// What a refused lane hand-off means for the caller: a retired
    /// lane (its member was drained and killed) reports the
    /// deterministic `DeviceRetired`; a lane that died with the whole
    /// service reports `ServiceDown`.
    fn lane_down_error(l: &Lane) -> AllocError {
        if l.retired.load(Ordering::Acquire) { // ordering: Acquire; pairs with retire Release
            AllocError::DeviceRetired
        } else {
            AllocError::ServiceDown
        }
    }

    /// Common submit tail: claim a descriptor on `lane`, stamp the
    /// ticket's provenance, hand it to the avail ring, account
    /// pipeline-depth stats.
    ///
    /// For allocs this is also where the drain race closes: the router
    /// picked `device` while it was healthy, but the ring claim may
    /// have blocked past a concurrent `drain_device` mark. The
    /// in-flight gauge is raised *before* re-checking the member state
    /// (both SeqCst), so either this submit observes the draining mark
    /// and backs out, or the drain's quiesce loop observes the gauge
    /// and waits for the op — an alloc can never slip onto a member
    /// after its live set was enumerated for migration.
    fn submit_to_lane(
        &self,
        device: usize,
        lane: usize,
        payload: Payload,
        client: u64,
    ) -> Result<Ticket, AllocError> {
        let l = &self.lanes[lane];
        let is_alloc = matches!(payload, Payload::Alloc { .. });
        let mut t = match l.ring.claim(lane as u32, payload) {
            Some(t) => t,
            None => return Err(Self::lane_down_error(l)),
        };
        // Attribution tag for the `OURO_LIN` history: stamped before
        // the avail-ring hand-off, so dispatch always reads the
        // submitting handle (batcher-mutex-ordered, like the payload).
        l.ring.set_client(t.slot, client);
        if is_alloc {
            // ordering: SeqCst raise BEFORE health re-check (quiesce)
            self.alloc_inflight[device].fetch_add(1, Ordering::SeqCst);
            if self.router.state(device) != DeviceState::Healthy {
                self.alloc_inflight[device].fetch_sub(1, Ordering::SeqCst);
                l.ring.abort(t);
                // The caller (`submit_alloc_raw`) re-routes on this.
                return Err(AllocError::DeviceRetired);
            }
        }
        t.svc = self.svc_tag;
        t.device = device as u32;
        if !l.batcher.submit(t.slot) {
            if is_alloc {
                // ordering: SeqCst undo of the gauge raise
                self.alloc_inflight[device].fetch_sub(1, Ordering::SeqCst);
            }
            l.ring.abort(t);
            return Err(Self::lane_down_error(l));
        }
        self.stats.submits.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        self.stats
            .depth_sum
            .fetch_add(l.ring.occupancy.current(), Ordering::Relaxed);
        Ok(t)
    }

    /// Smallest lane ring capacity — the safe pipeline-depth bound both
    /// [`ServiceClient::max_depth`] and [`AllocService::max_depth`]
    /// report.
    fn min_ring_slots(&self) -> usize {
        self.lanes.iter().map(|l| l.ring.slots()).min().unwrap_or(1)
    }

    /// Build a fresh handle with the next round-robin device affinity —
    /// the one place affinities are assigned (`AllocService::client` and
    /// `ServiceClient::clone` both come through here).
    fn new_client(inner: &Arc<Inner>) -> ServiceClient {
        ServiceClient {
            // ordering: round-robin; uniqueness only
            affinity: inner.next_affinity.fetch_add(1, Ordering::Relaxed)
                % inner.members.len(),
            // ordering: unique id mint; uniqueness only
            id: NEXT_CLIENT_ID.fetch_add(1, Ordering::Relaxed),
            inner: inner.clone(),
            outstanding: OrderedMutex::new(
                &classes::CLIENT_OUTSTANDING,
                Outstanding::default(),
            ),
            retry: RetryPolicy::default(),
            retry_clock: Arc::new(SystemClock::new()),
            cache: OrderedMutex::new(&classes::CLIENT_CACHE, None),
        }
    }
}

/// Client-side transient-failure retry: how many times — and on what
/// backoff schedule — a *blocking* [`ServiceClient::alloc`] re-attempts
/// a placement that failed with the transient [`AllocError::DeviceRetired`]
/// (every member shedding under `CapacityAware`, or a mid-retire race).
/// The schedule is bounded exponential: `base`, doubling per retry,
/// capped at `cap`; after `max_retries` re-attempts the error surfaces.
/// Sleeps go through the client's injectable [`Clock`], so tests retry
/// on a [`FakeClock`](super::rebalance::FakeClock) without wall-time.
/// The async `submit_*` paths never retry — a pipeline caller owns its
/// own pacing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first failure (0 disables retry).
    pub max_retries: u32,
    /// First backoff sleep.
    pub base: Duration,
    /// Backoff ceiling (the doubling clamps here).
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base: Duration::from_micros(200),
            cap: Duration::from_millis(5),
        }
    }
}

impl RetryPolicy {
    /// The pre-retry behavior: every transient failure surfaces.
    pub fn none() -> Self {
        RetryPolicy { max_retries: 0, ..Default::default() }
    }
}

/// Per-handle outstanding-ticket ledger: submission order preserved for
/// `wait_all`, reaps resolved through a **slot-indexed** map instead of
/// an O(n) scan + order-preserving `Vec::remove` — at pipeline depth n
/// the old scheme made every `poll`/`wait` reap O(n) under the ledger
/// mutex (quadratic across a full drain of a deep pipeline). Reaped
/// entries become `None` tombstones in the order vector; the vector is
/// compacted once tombstones outnumber live entries, keeping the whole
/// ledger amortised O(1) per op (asserted op-count-wise by the
/// depth-512 regression test below).
#[derive(Default)]
struct Outstanding {
    /// Tickets in submission order; `None` marks a reaped tombstone.
    order: Vec<Option<Ticket>>,
    /// `(lane, slot)` → index into `order` for the ticket from this
    /// handle currently occupying that ring descriptor (at most one:
    /// a descriptor holds one in-flight op).
    index: HashMap<u64, usize>,
    tombstones: usize,
    /// Ledger elements touched (pushes, forgets, compaction moves) —
    /// the op-count the reap-cost regression test bounds, so the test
    /// asserts work done rather than flaky wall time.
    work: u64,
}

impl Outstanding {
    fn key(t: &Ticket) -> u64 {
        (u64::from(t.lane) << 32) | u64::from(t.slot)
    }

    fn push(&mut self, t: Ticket) {
        self.work += 1;
        let i = self.order.len();
        self.order.push(Some(t));
        // A stale same-slot entry (its ticket was reaped through a
        // *different* handle, so this handle never forgot it) loses its
        // index here; it stays in `order` as a dead ticket, which is
        // exactly what `wait_all` reported for it before: a
        // deterministic stale error.
        self.index.insert(Self::key(&t), i);
    }

    fn forget(&mut self, t: Ticket) {
        self.work += 1;
        if let Some(&i) = self.index.get(&Self::key(&t)) {
            // Generation check: only the ticket actually recorded may
            // tombstone the entry (a forged or recycled ticket no-ops).
            if self.order[i] == Some(t) {
                self.order[i] = None;
                self.index.remove(&Self::key(&t));
                self.tombstones += 1;
                self.maybe_compact();
            }
        }
    }

    fn maybe_compact(&mut self) {
        if self.order.len() < 64 || self.tombstones * 2 <= self.order.len() {
            return;
        }
        let live: Vec<Ticket> = self.order.drain(..).flatten().collect();
        self.index.clear();
        for t in live {
            self.work += 1;
            let i = self.order.len();
            self.order.push(Some(t));
            self.index.insert(Self::key(&t), i);
        }
        self.tombstones = 0;
    }

    fn live(&self) -> usize {
        self.order.len() - self.tombstones
    }

    /// Take every live ticket in submission order, leaving the ledger
    /// empty.
    fn drain_in_order(&mut self) -> Vec<Ticket> {
        self.index.clear();
        self.tombstones = 0;
        self.order.drain(..).flatten().collect()
    }
}

/// Cloneable client handle. `submit_alloc`/`submit_free` + `poll`/`wait`
/// form the async pipeline; `alloc`/`free` are the blocking wrappers.
/// Each handle carries a device **affinity** (assigned round-robin at
/// creation; only consulted by [`RoutePolicy::ClientAffinity`]) and
/// tracks its own outstanding tickets for `wait_all` — see the module
/// docs for the cross-handle ticket semantics.
pub struct ServiceClient {
    inner: Arc<Inner>,
    affinity: usize,
    /// Process-unique handle id — the `OURO_LIN` history's attribution
    /// tag (0 means a service-internal op).
    id: u64,
    outstanding: OrderedMutex<Outstanding>,
    /// Transient-failure policy for the blocking `alloc` wrapper.
    retry: RetryPolicy,
    /// Backoff sleeps run on this clock (injectable for tests).
    retry_clock: Arc<dyn Clock>,
    /// Opt-in mimalloc-style lease cache (see `super::lease`): `None`
    /// until [`ServiceClient::set_caching`] arms it, so uncached
    /// handles pay one lock-free registry gate per free and nothing on
    /// alloc.
    cache: OrderedMutex<Option<ClientCache>>,
}

impl Clone for ServiceClient {
    fn clone(&self) -> Self {
        // Tickets are per-handle: a clone starts with nothing in flight
        // — and gets its own (fresh round-robin) device affinity. The
        // retry configuration and caching *setting* are inherited; the
        // cache contents are not (leases are owner-private).
        let mut c = Inner::new_client(&self.inner);
        c.retry = self.retry;
        c.retry_clock = self.retry_clock.clone();
        if self.caching_enabled() {
            c.set_caching(true);
        }
        c
    }
}

impl ServiceClient {
    // ---- async pipeline -------------------------------------------------

    /// Submit an allocation without waiting; the router places it on a
    /// device, the op joins that device's class lane. Blocks only if
    /// the lane ring is at capacity (`BatchPolicy::ring_slots` in
    /// flight).
    ///
    /// # Examples
    ///
    /// Pipeline a burst, then reap the tickets in order:
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ouroboros_tpu::backend::Cuda;
    /// use ouroboros_tpu::coordinator::batcher::BatchPolicy;
    /// use ouroboros_tpu::coordinator::service::AllocService;
    /// use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
    /// use ouroboros_tpu::simt::{Device, DeviceProfile};
    ///
    /// let svc = AllocService::start(
    ///     Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
    ///     build_allocator(Variant::Page, &HeapConfig::default()),
    ///     BatchPolicy::default(),
    /// );
    /// let client = svc.client();
    /// let tickets: Vec<_> = (0..8)
    ///     .map(|_| client.submit_alloc(64))
    ///     .collect::<Result<_, _>>()?;
    /// for t in tickets {
    ///     let addr = client.wait(t)?.into_alloc()?;
    ///     client.free(addr)?;
    /// }
    /// # Ok::<(), ouroboros_tpu::ouroboros::AllocError>(())
    /// ```
    pub fn submit_alloc(&self, size: u32) -> Result<Ticket, AllocError> {
        let t = self.submit_alloc_raw(size)?;
        self.outstanding.lock().unwrap().push(t);
        Ok(t)
    }

    /// Ledger-maintenance op count (see `Outstanding::work`) — the
    /// observable the reap-cost regression test bounds.
    #[cfg(test)]
    fn ledger_work(&self) -> u64 {
        self.outstanding.lock().unwrap().work
    }

    /// This handle's device affinity (the placement target under
    /// [`RoutePolicy::ClientAffinity`]).
    pub fn affinity(&self) -> usize {
        self.affinity
    }

    /// Validation + placement + lane routing + ring claim, without the
    /// outstanding bookkeeping (the blocking wrappers reap immediately
    /// and skip it). Placement retries past members that drain or
    /// retire between routing and the ring claim; only a group with no
    /// healthy member left reports `DeviceRetired` to the caller.
    fn submit_alloc_raw(&self, size: u32) -> Result<Ticket, AllocError> {
        // Submit-time binning (host mirror of the size_to_queue kernel);
        // invalid sizes never occupy a ring slot.
        let q = match queue_for_size(size) {
            Some(q) => q,
            None if size == 0 => return Err(AllocError::ZeroSize),
            None => return Err(AllocError::TooLarge(size)),
        };
        let inner = &*self.inner;
        for _attempt in 0..=inner.members.len() {
            let device = match inner.router.route_alloc(
                self.affinity,
                |d| inner.lanes[inner.lane_index(d, q)].ring.occupancy.current(),
                |d| inner.members[d].alloc.heap().occupancy(),
            ) {
                Some(d) => d,
                None => return Err(AllocError::DeviceRetired),
            };
            match inner.submit_to_lane(
                device,
                inner.lane_index(device, q),
                Payload::Alloc { size },
                self.id,
            ) {
                // Lost a race with a concurrent drain/retire of the
                // routed member: place again on what is left.
                Err(AllocError::DeviceRetired) => continue,
                other => return other,
            }
        }
        Err(AllocError::DeviceRetired)
    }

    fn submit_free_raw(&self, addr: GlobalAddr) -> Result<Ticket, AllocError> {
        let inner = &*self.inner;
        // Migrated addresses forward (exactly once, inside the grace
        // window) to their new home before any routing decision. The
        // consumption is provisional until the forwarded free actually
        // submits: a free that ends up rejected (e.g. the new home was
        // itself retired) must not burn the one permitted forward.
        let (addr, forwarded_from) = match inner.forwarding.lookup(addr.raw())
        {
            ForwardVerdict::Miss => (addr, None),
            ForwardVerdict::Forward(to) => (to, Some(addr.raw())),
            ForwardVerdict::Stale => {
                inner.stats.invalid_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                return Err(AllocError::InvalidFree(addr.raw()));
            }
        };
        let unconsume = |e: AllocError| {
            if let Some(raw) = forwarded_from {
                inner.forwarding.unconsume(raw);
            }
            e
        };
        // Frees ignore the route policy: the device tag names the owner.
        let (device, q) = match inner.class_for_addr(addr) {
            Some(x) => x,
            None => {
                inner.stats.invalid_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                return Err(unconsume(AllocError::InvalidFree(addr.raw())));
            }
        };
        // A retired member's heap is gone (and a readmitting member's
        // heap is empty — any address tagged for it predates the
        // retirement): deterministic rejection. Draining members still
        // serve frees — migration depends on it.
        if matches!(
            inner.router.state(device),
            DeviceState::Retired | DeviceState::Readmitting
        ) {
            return Err(unconsume(AllocError::DeviceRetired));
        }
        // The forwarding verdict is decided exactly once, here, and
        // carried on the descriptor: the dispatcher must not re-probe
        // the table for an already-rewritten free (the grace window
        // could have expired in between — the submit/dispatch TOCTOU).
        let payload = if forwarded_from.is_some() {
            Payload::ForwardedFree { addr: addr.raw() }
        } else {
            Payload::Free { addr: addr.raw() }
        };
        match inner.submit_to_lane(
            device,
            inner.lane_index(device, q),
            payload,
            self.id,
        ) {
            Ok(t) => {
                if forwarded_from.is_some() {
                    inner
                        .stats
                        .forwarded_frees
                        .fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                }
                Ok(t)
            }
            Err(e) => Err(unconsume(e)),
        }
    }

    /// Submit a free without waiting. It routes to the owning device's
    /// lane (decoded from the address tag) regardless of this handle's
    /// affinity or the service's route policy. Addresses whose device
    /// tag or chunk index is out of range are rejected here with
    /// `InvalidFree` (counted in `ServiceStats::invalid_frees`).
    ///
    /// A free of a cached block (any handle's lease) is absorbed by
    /// the lease bitmaps and handed back as an *already-complete*
    /// ticket — `poll`/`wait`/`wait_all` behave normally, but no
    /// dispatch happens. Cached rejections (double free of a cached
    /// block, a lease stranded by a hard retire) surface at submit,
    /// like other invalid frees.
    pub fn submit_free(&self, addr: GlobalAddr) -> Result<Ticket, AllocError> {
        if let Some((lane, r)) = self.try_cached_free(addr) {
            r?;
            let t = self.cached_free_ticket(lane, addr)?;
            self.outstanding.lock().unwrap().push(t);
            return Ok(t);
        }
        let t = self.submit_free_raw(addr)?;
        self.outstanding.lock().unwrap().push(t);
        Ok(t)
    }

    /// Non-blocking reap: `Some(completion)` exactly once per ticket,
    /// `None` while the op is still in flight — and forever for a
    /// ticket already reaped (by any handle) or minted by a different
    /// service.
    pub fn poll(&self, t: Ticket) -> Option<Completion> {
        if !self.inner.owns_ticket(t) {
            return None;
        }
        let v = self.inner.lanes[t.lane()].ring.try_take(t)?;
        self.forget(t);
        Some(v)
    }

    /// Blocking reap. Errs with `ServiceDown` if the service died with
    /// the op unserved or the ticket is stale (already reaped through
    /// any handle), and with `ForeignTicket` for a ticket minted by a
    /// different service instance — both deterministic, never a hang.
    ///
    /// While parked, the waiter publishes its ring's EVENT_IDX
    /// watermark and registers as blocked, so the completing worker
    /// broadcasts for it even when idle-ring broadcasts are being
    /// suppressed (see the `ring` module docs).
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ouroboros_tpu::backend::Cuda;
    /// use ouroboros_tpu::coordinator::batcher::BatchPolicy;
    /// use ouroboros_tpu::coordinator::service::AllocService;
    /// use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
    /// use ouroboros_tpu::simt::{Device, DeviceProfile};
    ///
    /// let svc = AllocService::start(
    ///     Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
    ///     build_allocator(Variant::Page, &HeapConfig::default()),
    ///     BatchPolicy::default(),
    /// );
    /// let client = svc.client();
    /// let ticket = client.submit_alloc(256)?;
    /// // ... overlap other work with the in-flight op ...
    /// let addr = client.wait(ticket)?.into_alloc()?;
    /// client.free(addr)?;
    /// # Ok::<(), ouroboros_tpu::ouroboros::AllocError>(())
    /// ```
    pub fn wait(&self, t: Ticket) -> Result<Completion, AllocError> {
        if !self.inner.owns_ticket(t) {
            return Err(AllocError::ForeignTicket);
        }
        let r = self.inner.lanes[t.lane()].ring.wait(t);
        self.forget(t);
        r
    }

    /// Drain every outstanding ticket submitted through this handle, in
    /// submission order. Returns `(ticket, completion)` pairs.
    pub fn wait_all(&self) -> Vec<(Ticket, Result<Completion, AllocError>)> {
        let tickets: Vec<Ticket> =
            self.outstanding.lock().unwrap().drain_in_order();
        tickets
            .into_iter()
            .map(|t| (t, self.inner.lanes[t.lane()].ring.wait(t)))
            .collect()
    }

    /// Outstanding tickets on this handle (submitted, not yet reaped).
    pub fn in_flight(&self) -> usize {
        self.outstanding.lock().unwrap().live()
    }

    /// Deepest safely-pipelinable window: the lane ring capacity
    /// (`BatchPolicy::ring_slots`). A single thread submitting more than
    /// this to one lane without reaping blocks in the ring claim with
    /// nobody left to reap — callers driving a pipeline loop should
    /// clamp their depth to this.
    pub fn max_depth(&self) -> usize {
        self.inner.min_ring_slots()
    }

    fn forget(&self, t: Ticket) {
        // O(1) slot-indexed tombstone; `wait_all`'s submission-order
        // promise survives because tombstones keep their position.
        self.outstanding.lock().unwrap().forget(t);
    }

    /// Replace this handle's transient-failure retry policy (the
    /// blocking [`ServiceClient::alloc`] backoff — see [`RetryPolicy`]).
    pub fn set_retry(&mut self, policy: RetryPolicy) {
        self.retry = policy;
    }

    /// This handle's transient-failure retry policy.
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry
    }

    /// Run backoff sleeps on `clock` instead of the wall clock — tests
    /// drive the retry schedule with a
    /// [`FakeClock`](super::rebalance::FakeClock).
    pub fn set_retry_clock(&mut self, clock: Arc<dyn Clock>) {
        self.retry_clock = clock;
    }

    // ---- client-side lease cache ----------------------------------------

    /// Arm (or disarm) the mimalloc-style lease cache on this handle.
    /// Off by default: with caching off every op crosses a ticket ring
    /// exactly as before. Armed, the blocking [`ServiceClient::alloc`]
    /// serves cacheable classes from leased spans with zero ring
    /// traffic and frees of cached blocks (through *any* handle) land
    /// in the lease bitmaps — see `super::lease` for the protocol.
    /// Disarming flushes every held lease first. Clones inherit the
    /// setting with their own empty cache.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ouroboros_tpu::backend::Cuda;
    /// use ouroboros_tpu::coordinator::batcher::BatchPolicy;
    /// use ouroboros_tpu::coordinator::service::AllocService;
    /// use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
    /// use ouroboros_tpu::simt::{Device, DeviceProfile};
    ///
    /// let svc = AllocService::start(
    ///     Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
    ///     build_allocator(Variant::Page, &HeapConfig::default()),
    ///     BatchPolicy::default(),
    /// );
    /// let client = svc.client();
    /// client.set_caching(true);
    /// let addr = client.alloc(64)?; // served from a leased span
    /// client.free(addr)?; // lands on the local free list
    /// client.flush_cache(); // hand every lease back before shutdown
    /// # Ok::<(), ouroboros_tpu::ouroboros::AllocError>(())
    /// ```
    pub fn set_caching(&self, enabled: bool) {
        if enabled {
            let mut g = self.cache.lock().unwrap();
            if g.is_none() {
                *g = Some(ClientCache::new());
            }
        } else {
            self.flush_cache();
            *self.cache.lock().unwrap() = None;
        }
    }

    /// Whether the lease cache is armed on this handle.
    pub fn caching_enabled(&self) -> bool {
        self.cache.lock().unwrap().is_some()
    }

    /// Spans currently leased by this handle, across all size classes.
    pub fn cached_spans(&self) -> usize {
        self.cache.lock().unwrap().as_ref().map_or(0, |c| c.total_spans())
    }

    /// Release every lease this handle holds: local free lists are
    /// dropped (the lease bitmaps already record every freed block)
    /// and each span whose blocks are all free is returned to its
    /// device with one bulk ring free. Spans with client blocks still
    /// live stay registered — whichever free completes one returns it.
    /// Runs on handle drop too; call it explicitly **before** the
    /// service shuts down or a federation group restarts (a lease is a
    /// live block, and under `OURO_SAN=1` a still-leased span panics
    /// the shutdown leak check).
    pub fn flush_cache(&self) {
        let drained = match self.cache.lock().unwrap().as_mut() {
            Some(c) => c.drain_all(),
            None => return,
        };
        self.drop_surrendered(drained);
    }

    /// Dispose of leases this handle no longer serves (flush, or spans
    /// surrendered mid-serve after a recall/epoch bump): drop their
    /// delayed hand-offs — the free bits already record those frees —
    /// and return any span that is already fully free.
    fn drop_surrendered(&self, surrendered: Vec<Arc<Lease>>) {
        for lease in surrendered {
            let _ = lease.drain_delayed();
            self.try_return_lease(&lease);
        }
    }

    /// Finalize a released lease once every block is free: exactly one
    /// caller (owner flush, last cross-client free, surrender) wins
    /// the latch and returns the span with one ring free at its
    /// current home.
    fn try_return_lease(&self, lease: &Arc<Lease>) {
        // OURO_LIN: stamped before the finalize CAS — the lease's
        // linearization point — so the recorded interval contains it.
        let lin_inv = super::ring::mono_ns();
        if !lease.try_finalize() {
            return;
        }
        let inner = &*self.inner;
        // Unregister BEFORE the ring free: the span's base address
        // aliases its block 0, and a still-registered lease would
        // bounce the span-return free back into the cached path.
        inner.leases.unregister(lease);
        inner.stats.lease_returns.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        // The home is stable once finalized (`relocate` refuses after
        // the latch), so one read serves the record, the shadow heap,
        // and the ring free alike. Dead leases record too: the span
        // leaves the lease partition even when its heap is gone.
        let span = lease.current_span();
        if let Some(lin) = &inner.lin {
            lin.record(OpRecord {
                inv_ns: lin_inv,
                res_ns: super::ring::mono_ns(),
                client: self.id,
                kind: OpKind::LeaseReturn,
                device: span.device(),
                class: SPAN_CLASS as u32,
                addr: span.raw(),
                lease_id: lease.id(),
            });
        }
        if lease.is_dead() {
            // Hard-retired: the backing heap is gone; the shadow heap
            // stranded the span with its member.
            return;
        }
        if let Some(san) = &inner.san {
            san.on_lease_return(span);
        }
        // A service already shut down just strands the span with the
        // heap — same as any other in-flight op at teardown.
        if let Ok(t) = self.submit_free_raw(span) {
            let _ = inner.lanes[t.lane()].ring.wait(t);
        }
    }

    /// The cached-alloc fast path: serve a block from a held lease, or
    /// mint a fresh span (the one ring op of this path, amortised over
    /// every block it carves) and serve from that. `None` falls
    /// through to the ring path: caching off, uncacheable class, span
    /// cap reached, or the mint itself was refused.
    fn try_cached_alloc(
        &self,
        size: u32,
    ) -> Option<Result<GlobalAddr, AllocError>> {
        let class = cacheable_class(size)?;
        let inner = &*self.inner;
        let start = Instant::now();
        // OURO_LIN: one invocation stamp covers both possible effects
        // of this call (span carve, block serve) — each linearizes
        // after this point and before its record's response stamp.
        let lin_inv = super::ring::mono_ns();
        let mut g = self.cache.lock().unwrap();
        let cache = g.as_mut()?;
        let epoch_of = |d: u32| inner.router.lease_epoch(d as usize);
        let mut out = cache.serve(class, epoch_of);
        if out.addr.is_none() && cache.can_mint(class) {
            // Minted while holding the cache lock, so a handle shared
            // across threads leases one span, not one per thread.
            if let Some(span) = self.mint_span() {
                let lease = Lease::new(span, class, epoch_of(span.device()));
                inner.leases.register(&lease);
                if let Some(san) = &inner.san {
                    san.on_lease_carve(span);
                }
                if let Some(lin) = &inner.lin {
                    // The span's heap-side Alloc was recorded by the
                    // ring dispatch; this is its lease-side identity.
                    lin.record(OpRecord {
                        inv_ns: lin_inv,
                        res_ns: super::ring::mono_ns(),
                        client: self.id,
                        kind: OpKind::LeaseCarve,
                        device: span.device(),
                        class: SPAN_CLASS as u32,
                        addr: span.raw(),
                        lease_id: lease.id(),
                    });
                }
                inner.stats.lease_mints.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                cache.install(lease);
                let more = cache.serve(class, epoch_of);
                out.surrendered.extend(more.surrendered);
                out.addr = more.addr;
            }
        }
        drop(g);
        self.drop_surrendered(out.surrendered);
        let addr = out.addr?;
        if let Some(san) = &inner.san {
            san.on_cached_alloc(addr);
        }
        if let Some(lin) = &inner.lin {
            // The serving lease is still registered — the block just
            // served from it is live, which blocks finalize; a miss
            // (hard retire mid-serve) drops the record, which is
            // always sound.
            if let Some((l, _)) = inner.leases.resolve(addr) {
                lin.record(OpRecord {
                    inv_ns: lin_inv,
                    res_ns: super::ring::mono_ns(),
                    client: self.id,
                    kind: OpKind::Alloc,
                    device: addr.device(),
                    class: class as u32,
                    addr: addr.raw(),
                    lease_id: l.id(),
                });
            }
        }
        inner.stats.cached_allocs.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        inner
            .stats
            .cached_hist
            .record_ns(start.elapsed().as_nanos() as u64);
        Some(Ok(addr))
    }

    /// Mint one span-sized allocation backing a new lease; `None` when
    /// the ring path refused it (the caller falls back to a plain
    /// alloc — a group that cannot lease can often still allocate
    /// small).
    fn mint_span(&self) -> Option<GlobalAddr> {
        let t = self.submit_alloc_raw(span_bytes()).ok()?;
        self.inner.lanes[t.lane()].ring.wait(t).ok()?.into_alloc().ok()
    }

    /// The cached-free fast path: a free whose address resolves to a
    /// live lease lands in the lease bitmaps — owner frees go back on
    /// the local list, cross-client frees onto the delayed list — with
    /// zero ring traffic. `None` when the address is not a cached
    /// block. The returned flat lane index serves `submit_free`'s
    /// already-complete ticket shim.
    fn try_cached_free(
        &self,
        addr: GlobalAddr,
    ) -> Option<(usize, Result<(), AllocError>)> {
        let inner = &*self.inner;
        if !inner.leases.is_active() {
            return None;
        }
        let (lease, i) = inner.leases.resolve(addr)?;
        let lane =
            inner.lane_index(lease.origin().device() as usize, lease.class());
        if lease.is_dead() {
            // Stranded by a hard retire: the same deterministic answer
            // as any other address on the dead member.
            return Some((lane, Err(AllocError::DeviceRetired)));
        }
        let start = Instant::now();
        // OURO_LIN: the free linearizes at the bitmap publish inside
        // `free_block`, strictly between these two stamps.
        let lin_inv = super::ring::mono_ns();
        let delayed = {
            let mut g = self.cache.lock().unwrap();
            let owner = g.as_mut().is_some_and(|c| c.holds(&lease));
            if let Err(e) = lease.free_block(i, !owner) {
                inner.stats.invalid_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                return Some((lane, Err(e)));
            }
            if owner {
                g.as_mut().unwrap().local_push(&lease, i);
            }
            !owner
        };
        if let Some(san) = &inner.san {
            san.on_cached_free(addr, delayed);
        }
        if let Some(lin) = &inner.lin {
            lin.record(OpRecord {
                inv_ns: lin_inv,
                res_ns: super::ring::mono_ns(),
                client: self.id,
                kind: OpKind::Free,
                device: addr.device(),
                class: lease.class() as u32,
                addr: addr.raw(),
                lease_id: lease.id(),
            });
        }
        let stats = &inner.stats;
        stats.cached_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        if delayed {
            stats.delayed_frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        }
        // A released lease whose last block just came home is returned
        // by whichever free completed it — owner or not.
        self.try_return_lease(&lease);
        stats.cached_hist.record_ns(start.elapsed().as_nanos() as u64);
        Some((lane, Ok(())))
    }

    /// Mint an already-complete ticket for a free absorbed by the
    /// lease cache: the descriptor is claimed on the block's home lane
    /// and completed in place, never entering the avail ring —
    /// `poll`/`wait`/`wait_all` see a normal completion with zero
    /// dispatch traffic.
    fn cached_free_ticket(
        &self,
        lane: usize,
        addr: GlobalAddr,
    ) -> Result<Ticket, AllocError> {
        let inner = &*self.inner;
        let l = &inner.lanes[lane];
        let mut t =
            match l.ring.claim(lane as u32, Payload::Free { addr: addr.raw() })
            {
                Some(t) => t,
                None => return Err(Inner::lane_down_error(l)),
            };
        t.svc = inner.svc_tag;
        t.device = inner.device_of_lane(lane) as u32;
        inner.stats.submits.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        l.ring.complete_bulk(vec![(t.slot, Completion::Free(Ok(())))]);
        Ok(t)
    }

    // ---- blocking wrappers ----------------------------------------------
    // submit + wait without touching `outstanding`: the ticket never
    // outlives the call, so tracking it would only add two mutex
    // round-trips and a reap-time scan per op.

    /// Blocking allocation with transparent transient-failure retry:
    /// a `DeviceRetired` result (whole group shedding, or the placed
    /// member retired mid-flight) is re-attempted up to
    /// `RetryPolicy::max_retries` times on the bounded-exponential
    /// backoff, each counted in `ServiceStats::alloc_retries`. Every
    /// other error — and exhaustion of the budget — surfaces unchanged.
    pub fn alloc(&self, size: u32) -> Result<GlobalAddr, AllocError> {
        if let Some(r) = self.try_cached_alloc(size) {
            return r;
        }
        let mut backoff = self.retry.base;
        let mut attempt = 0u32;
        loop {
            let r = self.alloc_once(size);
            match r {
                Err(AllocError::DeviceRetired)
                    if attempt < self.retry.max_retries =>
                {
                    attempt += 1;
                    self.inner
                        .stats
                        .alloc_retries
                        .fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                    self.retry_clock.sleep(backoff);
                    backoff = (backoff * 2).min(self.retry.cap);
                }
                _ => return r,
            }
        }
    }

    fn alloc_once(&self, size: u32) -> Result<GlobalAddr, AllocError> {
        let t = self.submit_alloc_raw(size)?;
        self.inner.lanes[t.lane()].ring.wait(t)?.into_alloc()
    }

    pub fn free(&self, addr: GlobalAddr) -> Result<(), AllocError> {
        if let Some((_, r)) = self.try_cached_free(addr) {
            return r;
        }
        let t = self.submit_free_raw(addr)?;
        self.inner.lanes[t.lane()].ring.wait(t)?.into_free()
    }
}

impl Drop for ServiceClient {
    /// A dropped handle surrenders its leases — a lease is a live
    /// block, and an implicit drop must not leak spans the way an
    /// explicit `flush_cache` would not.
    fn drop(&mut self) {
        self.flush_cache();
    }
}

pub struct AllocService {
    pub(crate) inner: Arc<Inner>,
}

impl AllocService {
    /// Single-device convenience: a group of one, placement trivial.
    /// Device 0's global addresses are numerically the local addresses,
    /// so this is bit-for-bit the pre-group service — with one new
    /// constraint inherited from the global address namespace: the heap
    /// must fit the per-device window
    /// ([`DEVICE_SPAN`](crate::ouroboros::addr::DEVICE_SPAN), 64 MiB —
    /// twice the default heap). Larger single heaps would alias the
    /// device-tag bits and are rejected at startup.
    pub fn start(
        device: Device,
        alloc: Arc<dyn DeviceAllocator>,
        policy: BatchPolicy,
    ) -> Self {
        Self::start_group(vec![(device, alloc)], policy, RoutePolicy::RoundRobin)
    }

    /// Start a service over a device group. Each member brings its own
    /// device and allocator (heterogeneous profiles and variants are
    /// fine); every member gets a full set of per-size-class lanes, and
    /// `route` decides allocation placement at submit time.
    ///
    /// # Examples
    ///
    /// ```
    /// use std::sync::Arc;
    /// use ouroboros_tpu::backend::Cuda;
    /// use ouroboros_tpu::coordinator::batcher::BatchPolicy;
    /// use ouroboros_tpu::coordinator::router::RoutePolicy;
    /// use ouroboros_tpu::coordinator::service::AllocService;
    /// use ouroboros_tpu::ouroboros::{build_allocator, HeapConfig, Variant};
    /// use ouroboros_tpu::simt::{Device, DeviceProfile};
    ///
    /// let member = || {
    ///     (
    ///         Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new())),
    ///         build_allocator(Variant::Page, &HeapConfig::default()),
    ///     )
    /// };
    /// let svc = AllocService::start_group(
    ///     vec![member(), member()],
    ///     BatchPolicy::default(),
    ///     RoutePolicy::RoundRobin,
    /// );
    /// let client = svc.client();
    /// let addr = client.alloc(256)?; // placed round-robin, tagged global
    /// client.free(addr)?; // routed home by the address tag
    /// # Ok::<(), ouroboros_tpu::ouroboros::AllocError>(())
    /// ```
    pub fn start_group(
        members: Vec<(Device, Arc<dyn DeviceAllocator>)>,
        policy: BatchPolicy,
        route: RoutePolicy,
    ) -> Self {
        Self::start_group_inner(
            members,
            policy,
            route,
            crate::check::sanitizer::ShadowHeap::from_env(),
            HistoryRecorder::from_env(),
        )
    }

    /// `start_group` with the checkers injected explicitly, ignoring
    /// `OURO_SAN`/`OURO_LIN`: tests arm a recorder (or shadow heap)
    /// for one service without mutating process environment.
    pub fn start_group_instrumented(
        members: Vec<(Device, Arc<dyn DeviceAllocator>)>,
        policy: BatchPolicy,
        route: RoutePolicy,
        san: Option<Arc<crate::check::sanitizer::ShadowHeap>>,
        lin: Option<Arc<HistoryRecorder>>,
    ) -> Self {
        Self::start_group_inner(members, policy, route, san, lin)
    }

    /// `start_group` body with the sanitizer and history recorder
    /// injected — the restart path (`start_group_restored`) threads the
    /// predecessor's shadow heap and recorder through here so address
    /// histories span the restart.
    fn start_group_inner(
        members: Vec<(Device, Arc<dyn DeviceAllocator>)>,
        policy: BatchPolicy,
        route: RoutePolicy,
        san: Option<Arc<crate::check::sanitizer::ShadowHeap>>,
        lin: Option<Arc<HistoryRecorder>>,
    ) -> Self {
        assert!(!members.is_empty(), "device group needs at least one member");
        assert!(
            members.len() <= MAX_DEVICES as usize,
            "device group exceeds the {MAX_DEVICES}-device address space"
        );
        for (_, alloc) in &members {
            assert!(
                alloc.heap().cfg.heap_bytes() <= DEVICE_SPAN as u64,
                "member heap exceeds the per-device address window"
            );
        }
        let n_dev = members.len();
        let n_lanes = policy.lanes.clamp(1, NUM_QUEUES);
        let workers_per_lane = policy.workers_per_lane.max(1);
        let ring_slots = policy.ring_slots.max(policy.max_batch).max(1);
        let total_lanes = n_dev * n_lanes;
        let names: Vec<&'static str> =
            members.iter().map(|(d, _)| d.profile.name).collect();
        let inner = Arc::new(Inner {
            router: Router::new(route, n_dev),
            forwarding: ForwardingTable::new(),
            alloc_inflight: (0..n_dev).map(|_| AtomicU64::new(0)).collect(),
            rebalance_lock: OrderedMutex::new(&classes::REBALANCE, ()),
            drain_cursors: (0..n_dev)
                .map(|_| {
                    OrderedMutex::new(
                        &classes::DRAIN_CURSOR,
                        DrainCursor::default(),
                    )
                })
                .collect(),
            stall_inject: (0..n_dev).map(|_| AtomicBool::new(false)).collect(),
            members: members
                .into_iter()
                .map(|(device, alloc)| Member { device, alloc })
                .collect(),
            lanes: (0..total_lanes)
                .map(|_| Lane {
                    batcher: Batcher::with_notify(policy.eager_notify),
                    ring: TicketRing::with_notify(
                        ring_slots,
                        policy.eager_notify,
                    ),
                    workers_alive: AtomicUsize::new(workers_per_lane),
                    retired: AtomicBool::new(false),
                })
                .collect(),
            lanes_per_device: n_lanes,
            workers: OrderedMutex::new(
                &classes::WORKERS,
                Vec::with_capacity(total_lanes * workers_per_lane),
            ),
            stats: ServiceStats::new(total_lanes, names),
            leases: LeaseRegistry::new(n_dev),
            // ordering: unique tag mint; uniqueness only
            svc_tag: NEXT_SVC_TAG.fetch_add(1, Ordering::Relaxed),
            next_affinity: AtomicUsize::new(0),
            policy,
            san,
            san_detached: AtomicBool::new(false),
            lin,
        });
        {
            let mut workers = inner.workers.lock().unwrap();
            for lane in 0..total_lanes {
                for w in 0..workers_per_lane {
                    let inner2 = inner.clone();
                    let (d, l) = (lane / n_lanes, lane % n_lanes);
                    workers.push((
                        lane,
                        std::thread::Builder::new()
                            .name(format!("ouro-alloc-d{d}l{l}w{w}"))
                            .spawn(move || Inner::run_lane(inner2, lane))
                            .expect("spawning service worker"),
                    ));
                }
            }
        }
        AllocService { inner }
    }

    /// Convenience group constructor from `(profile-name, variant)`
    /// pairs — the name-spelled topology hook
    /// ([`DeviceProfile::parse`] accepts `"t2000"`, `"iris-xe"`,
    /// `"test-tiny"`). Every member gets a fresh heap from `cfg` and
    /// shares `backend` (backends are stateless cost/semantic tables).
    /// Panics on an unknown profile name.
    pub fn start_named_group(
        spec: &[(&str, Variant)],
        cfg: &HeapConfig,
        policy: BatchPolicy,
        route: RoutePolicy,
        backend: Arc<dyn Backend>,
    ) -> Self {
        let members = spec
            .iter()
            .map(|&(name, variant)| {
                let profile = DeviceProfile::parse(name).unwrap_or_else(|| {
                    panic!("unknown device profile {name:?}")
                });
                (
                    Device::new(profile, backend.clone()),
                    build_allocator(variant, cfg),
                )
            })
            .collect();
        Self::start_group(members, policy, route)
    }

    pub fn client(&self) -> ServiceClient {
        Inner::new_client(&self.inner)
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.inner.stats
    }

    /// Leases currently registered across every client handle —
    /// spans carved out of the heaps and parked in client caches.
    pub fn live_leases(&self) -> usize {
        self.inner.leases.live_leases()
    }

    /// Plain-value counter snapshot with per-device rollups, including
    /// each member's live heap-occupancy gauge and failover state.
    pub fn snapshot(&self) -> StatsSnapshot {
        let mut s = self.inner.stats.snapshot();
        for (d, m) in self.inner.members.iter().enumerate() {
            s.devices[d].heap_occupancy = m.alloc.heap().occupancy();
            s.devices[d].state = self.inner.router.state(d).id();
        }
        for lane in self.inner.lanes.iter() {
            let (wd, ws) = lane.ring.wakeups();
            s.wakeup_delivered += wd;
            s.wakeup_suppressed += ws;
            let (dd, ds) = lane.batcher.doorbells();
            s.doorbell_delivered += dd;
            s.doorbell_suppressed += ds;
        }
        s
    }

    /// The placement policy this service routes allocations under.
    pub fn route_policy(&self) -> RoutePolicy {
        self.inner.router.policy()
    }

    /// The batching policy this service's lanes were built with — what a
    /// restart must pass to [`AllocService::start_group_restored`] to
    /// rebuild an identical successor.
    pub fn batch_policy(&self) -> BatchPolicy {
        self.inner.policy.clone()
    }

    /// Group size.
    pub fn device_count(&self) -> usize {
        self.inner.members.len()
    }

    /// Smallest lane ring capacity — the deepest pipeline one client can
    /// safely run (same bound [`ServiceClient::max_depth`] reports), and
    /// the aggregate in-flight budget shared-lane workloads must respect
    /// (see [`super::driver::run_group_trace`]).
    pub fn max_depth(&self) -> usize {
        self.inner.min_ring_slots()
    }

    /// Per-lane ring-occupancy high-water marks (flat, device-major) —
    /// how deep the pipeline actually ran on each lane.
    pub fn ring_high_water(&self) -> Vec<u64> {
        self.inner
            .lanes
            .iter()
            .map(|l| l.ring.occupancy.high_water())
            .collect()
    }

    /// Live per-lane ring occupancy (flat, device-major): ops claimed
    /// and not yet reaped. The failover driver polls a retiring
    /// member's slice of this to wait for its lanes to go quiet between
    /// `drain_device` and `retire_device`.
    pub fn ring_occupancy(&self) -> Vec<u64> {
        self.inner
            .lanes
            .iter()
            .map(|l| l.ring.occupancy.current())
            .collect()
    }

    /// This member's flat lane range (device-major lane vector).
    pub fn lanes_of(&self, device: usize) -> std::ops::Range<usize> {
        let n = self.inner.lanes_per_device;
        device * n..(device + 1) * n
    }

    /// Device 0's allocator — the single-device convenience accessor
    /// (use [`AllocService::allocator_of`] / [`AllocService::allocators`]
    /// for groups).
    pub fn allocator(&self) -> &Arc<dyn DeviceAllocator> {
        &self.inner.members[0].alloc
    }

    /// Allocator of group device `device`.
    pub fn allocator_of(&self, device: usize) -> &Arc<dyn DeviceAllocator> {
        &self.inner.members[device].alloc
    }

    /// Every member's allocator, in group order.
    pub fn allocators(&self) -> Vec<Arc<dyn DeviceAllocator>> {
        self.inner.members.iter().map(|m| m.alloc.clone()).collect()
    }

    /// Chaos/fault-injection hook: wedge (or un-wedge) a member's lane
    /// workers between batch pickup and dispatch, so claimed ops pile
    /// up with no dispatch progress — the stalled-device shape the
    /// health watchdog detects and self-heals from. Used by the chaos
    /// tests, the self-heal bench, and
    /// [`super::driver::run_selfheal_trace`]; a production build never
    /// sets it.
    pub fn inject_stall(&self, device: usize, stalled: bool) {
        // ordering: Release; pairs with worker Acquire poll
        self.inner.stall_inject[device].store(stalled, Ordering::Release);
    }
}

impl Inner {
    pub(crate) fn run_lane(inner: Arc<Inner>, lane: usize) {
        // Close the ring when the lane's last worker exits, whether it
        // drained cleanly or is unwinding from a dispatch panic — a dead
        // lane must fail its waiters, not strand them.
        struct CloseOnExit<'a>(&'a Lane);
        impl Drop for CloseOnExit<'_> {
            fn drop(&mut self) {
                // ordering: AcqRel; last worker sees peers exits
                if self.0.workers_alive.fetch_sub(1, Ordering::AcqRel) == 1 {
                    self.0.ring.close();
                }
            }
        }
        let dev = inner.device_of_lane(lane);
        let l = &inner.lanes[lane];
        let _guard = CloseOnExit(l);
        while let Some(batch) = l.batcher.next_batch(&inner.policy) {
            // Chaos hook: a stall-injected member wedges here with the
            // batch claimed but undispatched — ring occupancy high, no
            // batch progress — until the watchdog (or a test) retires
            // the member or lifts the stall.
            // ordering: Acquire chaos-flag poll
            while inner.stall_inject[dev].load(Ordering::Acquire)
                && !l.retired.load(Ordering::Acquire)
            {
                std::thread::sleep(Duration::from_micros(50));
            }
            inner.dispatch(lane, &batch);
            l.batcher.recycle(batch);
        }
    }

    /// Dispatch one lane batch of descriptor ids on the lane's device:
    /// group by size class (a lane holds exactly one class when fully
    /// sharded, several in coarser topologies), issue one coalesced
    /// device pass per (kind, class) group, then publish the whole
    /// batch's completions in one bulk write.
    fn dispatch(&self, lane: usize, batch: &[u32]) {
        let inner = self;
        let dev = inner.device_of_lane(lane);
        let l = &inner.lanes[lane];
        // A retired lane's final drain. Queued *frees* whose block the
        // drain already migrated off this member are delivered to the
        // migrated copy (the service accepted them before the retire,
        // and the forwarding table knows where the block went) — losing
        // them would leak the copy. Everything else fails with the
        // deterministic `DeviceRetired` instead of launching on a
        // member that is being torn down. Waiters get a completion of
        // the right kind either way, never a hang.
        if l.retired.load(Ordering::Acquire) { // ordering: Acquire; pairs with retire Release
            let allocs = batch
                .iter()
                .filter(|&&s| {
                    matches!(l.ring.payload(s), Payload::Alloc { .. })
                })
                .count() as u64;
            if allocs > 0 {
                // ordering: SeqCst gauge release; drain sees every bit
                inner.alloc_inflight[dev].fetch_sub(allocs, Ordering::SeqCst);
            }
            let mut rescued: Vec<(u32, Completion)> = Vec::new();
            let mut failed: Vec<u32> = Vec::new();
            for &slot in batch {
                let claim = l.ring.claim_info(slot);
                match l.ring.payload(slot) {
                    Payload::Free { addr } => {
                        match inner.late_forward_free(addr, false, claim) {
                            Some(r) => rescued.push((slot, Completion::Free(r))),
                            None => failed.push(slot),
                        }
                    }
                    // A forwarded free parked on a member that then
                    // retired: its target copy was just drained again,
                    // so chain through the fresh entry (counted at its
                    // original submit, not again here).
                    Payload::ForwardedFree { addr } => {
                        match inner.late_forward_free(addr, true, claim) {
                            Some(r) => rescued.push((slot, Completion::Free(r))),
                            None => failed.push(slot),
                        }
                    }
                    _ => failed.push(slot),
                }
            }
            inner
                .stats
                .retired_ops
                .fetch_add(failed.len() as u64, Ordering::Relaxed); // ordering: stat counter
            l.ring.fail_slots(&failed, AllocError::DeviceRetired);
            l.ring.complete_bulk(rescued);
            return;
        }
        let stats = &inner.stats;
        stats.batches.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        stats.lane_batches[lane].fetch_add(1, Ordering::Relaxed);
        stats.device_batches[dev].fetch_add(1, Ordering::Relaxed);
        stats.ops.fetch_add(batch.len() as u64, Ordering::Relaxed);
        // ordering: stat counter
        stats.lane_ops[lane].fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.device_ops[dev].fetch_add(batch.len() as u64, Ordering::Relaxed);
        stats.batched_ops.fetch_add(batch.len() as u64, Ordering::Relaxed);

        let ring = &inner.lanes[lane].ring;
        // If dispatch unwinds (a device-path panic), fail the whole
        // batch with `ServiceDown` instead of stranding its waiters on
        // completions that will never be published — the delivery
        // guarantee the mpsc design got from dropped `Sender`s. Nothing
        // in `batch` is completed until the final `complete_bulk`, so
        // while armed the guard can safely attribute every slot. The
        // guard also releases the batch's share of the in-flight-alloc
        // gauge, so a crashed lane can never wedge a later drain.
        struct FailBatchOnUnwind<'a> {
            ring: &'a TicketRing,
            batch: &'a [u32],
            inflight: &'a AtomicU64,
            n_allocs: u64,
            armed: bool,
        }
        impl Drop for FailBatchOnUnwind<'_> {
            fn drop(&mut self) {
                if !self.armed {
                    return;
                }
                if self.n_allocs > 0 {
                    // ordering: SeqCst gauge release on unwind path
                    self.inflight.fetch_sub(self.n_allocs, Ordering::SeqCst);
                }
                self.ring.fail_slots(self.batch, AllocError::ServiceDown);
            }
        }
        let n_allocs = batch
            .iter()
            .filter(|&&s| matches!(ring.payload(s), Payload::Alloc { .. }))
            .count() as u64;
        let mut guard = FailBatchOnUnwind {
            ring,
            batch,
            inflight: &inner.alloc_inflight[dev],
            n_allocs,
            armed: true,
        };

        // One completion sweep for the whole batch.
        let mut done: Vec<(u32, Completion)> = Vec::with_capacity(batch.len());
        let mut alloc_groups: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        // Per class: (device-local addresses, descriptor slots,
        // forwarded-at-submit flags).
        type FreeGroup = (Vec<u32>, Vec<u32>, Vec<bool>);
        let mut free_groups: BTreeMap<usize, FreeGroup> = BTreeMap::new();
        for &slot in batch {
            let payload = ring.payload(slot);
            match payload {
                // Submit validates both invariants below; dispatch stays
                // total anyway — a regression should fail the one op,
                // not panic the lane worker and down the whole lane.
                Payload::Alloc { size } => match queue_for_size(size) {
                    Some(q) => alloc_groups.entry(q).or_default().push(slot),
                    None => done.push((
                        slot,
                        Completion::Alloc(Err(if size == 0 {
                            AllocError::ZeroSize
                        } else {
                            AllocError::TooLarge(size)
                        })),
                    )),
                },
                Payload::Free { addr } | Payload::ForwardedFree { addr } => {
                    let pre =
                        matches!(payload, Payload::ForwardedFree { .. });
                    let ga = GlobalAddr::from_raw(addr);
                    // Submit routed this free here, so the tag names
                    // this lane's device; a slipped-through wild free
                    // falls back to class 0 and fails on-device.
                    let decoded = inner.class_for_addr(ga);
                    debug_assert!(
                        match decoded {
                            Some((d, _)) => d == dev,
                            None => true,
                        },
                        "free routed to the wrong device's lane"
                    );
                    let q = match decoded {
                        Some((_, q)) => q,
                        None => 0,
                    };
                    let g = free_groups.entry(q).or_default();
                    g.0.push(ga.local());
                    g.1.push(slot);
                    g.2.push(pre);
                }
            }
        }
        for (q, slots) in alloc_groups {
            inner.dispatch_allocs(dev, q, ring, &slots, &mut done);
        }
        for (q, (addrs, slots, pre)) in free_groups {
            inner.dispatch_frees(dev, q, ring, addrs, &slots, &pre, &mut done);
        }
        // The batch's allocs have hit the heap (their occupancy bits
        // are set by the launches above): release the drain-quiesce
        // gauge *before* the results are published — a migration sweep
        // that observes the gauge at zero must see every bit.
        if n_allocs > 0 {
            // ordering: SeqCst gauge release; drain sees every bit
            inner.alloc_inflight[dev].fetch_sub(n_allocs, Ordering::SeqCst);
        }
        // A freshly minted address re-owns its name: if migration left
        // a forwarding entry keyed by it (its page was recycled on this
        // device) or pointing at it (the migrated copy was freed and
        // its page recycled), that entry must die now — forwarding it
        // later would free someone else's allocation.
        if inner.forwarding.is_active() {
            let minted: Vec<u32> = done
                .iter()
                .filter_map(|(_, c)| match c {
                    Completion::Alloc(Ok(a)) => Some(a.raw()),
                    _ => None,
                })
                .collect();
            inner.forwarding.invalidate_reused(&minted);
        }
        // Claim→complete wall time per descriptor, the ring-path
        // counterpart of the cached-path histogram.
        for &(slot, _) in &done {
            inner.stats.ring_hist.record_ns(ring.claimed_elapsed_ns(slot));
        }
        // Disarm before publishing: once any slot goes COMPLETE it can
        // be reaped and re-claimed, and the guard must never touch a
        // descriptor that might already host a new op.
        guard.armed = false;
        ring.complete_bulk(done);
    }

    fn dispatch_allocs(
        &self,
        dev: usize,
        q: usize,
        ring: &TicketRing,
        slots: &[u32],
        done: &mut Vec<(u32, Completion)>,
    ) {
        let inner = self;
        let member = &inner.members[dev];
        let n = slots.len();
        let stats = &inner.stats;
        stats.allocs.fetch_add(n as u64, Ordering::Relaxed); // ordering: stat counter
        stats.device_allocs[dev].fetch_add(n as u64, Ordering::Relaxed);
        // The bulk path bypasses `DeviceAllocator::malloc`, so account
        // the requests here (matching the warp-path bookkeeping).
        // ordering: stat counter
        member.alloc.counters().mallocs.fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &member.alloc;
        // (warp base, group width, addresses, terminal error) per warp.
        type WarpAllocs = Vec<(usize, usize, Vec<u32>, Option<AllocError>)>;
        let results: OrderedMutex<WarpAllocs> =
            OrderedMutex::new(&classes::LAUNCH_RESULT, Vec::new());
        let st = member.device.launch(
            &format!("service.malloc.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                // Leader-coalesced class group: one collective point,
                // then one bulk queue op for the whole warp.
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let mut out = Vec::with_capacity(width);
                let err =
                    alloc.malloc_bulk(&w.ctx, q, width as u32, &mut out).err();
                results.lock().unwrap().push((base, width, out, err));
            },
        );
        stats.device_ns[dev]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed); // ordering: stat counter

        let mut flat: Vec<Result<GlobalAddr, AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, width, out, err) in results.into_inner().unwrap() {
            for i in 0..width {
                flat[base + i] = match out.get(i) {
                    // The device hands back a local address; tag it with
                    // the owning device on the way out.
                    Some(&a) => Ok(GlobalAddr::new(dev as u32, a)),
                    None => Err(err.unwrap_or(AllocError::QueueCorrupt)),
                };
            }
        }
        // Feed the watchdog's error-rate heartbeat: a member drowning
        // in failed allocs (heap sickness, persistent OOM) trips the
        // health policy even while its lanes still make progress.
        let errors = flat.iter().filter(|r| r.is_err()).count() as u64;
        if errors > 0 {
            // ordering: stat counter
            stats.device_alloc_errors[dev].fetch_add(errors, Ordering::Relaxed);
        }
        if let Some(san) = &inner.san {
            for a in flat.iter().flatten() {
                san.on_mint(*a);
            }
        }
        // OURO_LIN: the invocation was stamped at the ring claim; the
        // response is stamped here, after the heap bits are set and
        // before the batch's completions publish — the recorded
        // interval always contains the true linearization point.
        if let Some(lin) = &inner.lin {
            let res_ns = super::ring::mono_ns();
            for (&slot, r) in slots.iter().zip(flat.iter()) {
                if let Ok(a) = r {
                    let (inv_ns, client) = ring.claim_info(slot);
                    lin.record(OpRecord {
                        inv_ns,
                        res_ns,
                        client,
                        kind: OpKind::Alloc,
                        device: dev as u32,
                        class: q as u32,
                        addr: a.raw(),
                        lease_id: 0,
                    });
                }
            }
        }
        done.extend(
            slots
                .iter()
                .zip(flat)
                .map(|(&slot, r)| (slot, Completion::Alloc(r))),
        );
    }

    fn dispatch_frees(
        &self,
        dev: usize,
        q: usize,
        ring: &TicketRing,
        addrs: Vec<u32>,
        slots: &[u32],
        pre_forwarded: &[bool],
        done: &mut Vec<(u32, Completion)>,
    ) {
        let inner = self;
        let member = &inner.members[dev];
        let n = addrs.len();
        let stats = &inner.stats;
        stats.frees.fetch_add(n as u64, Ordering::Relaxed); // ordering: stat counter
        stats.device_frees[dev].fetch_add(n as u64, Ordering::Relaxed);

        let alloc = &member.alloc;
        let addrs_ref = &addrs;
        let results: OrderedMutex<Vec<(usize, Vec<Result<(), AllocError>>)>> =
            OrderedMutex::new(&classes::LAUNCH_RESULT, Vec::new());
        let st = member.device.launch(
            &format!("service.free.q{q}"),
            Grid::new(n as u32),
            |w| {
                let width = w.active_lanes().count();
                let base = w.thread_id(0) as usize;
                let _ = w.ctx.subgroup_sync(w.active_mask(), w.active_mask());
                let rs = alloc.free_bulk(&w.ctx, &addrs_ref[base..base + width]);
                results.lock().unwrap().push((base, rs));
            },
        );
        stats.device_ns[dev]
            .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed); // ordering: stat counter

        let mut flat: Vec<Result<(), AllocError>> =
            vec![Err(AllocError::QueueCorrupt); n];
        for (base, rs) in results.into_inner().unwrap() {
            for (i, r) in rs.into_iter().enumerate() {
                // The device speaks local addresses; re-tag its
                // InvalidFree reports with the owning device so the
                // error names the global address the client submitted.
                flat[base + i] = r.map_err(|e| match e {
                    AllocError::InvalidFree(local) => AllocError::InvalidFree(
                        GlobalAddr::new(dev as u32, local).raw(),
                    ),
                    other => other,
                });
            }
        }
        // Shadow the straight successes now, against this device; frees
        // rescued by late forwarding below are shadowed inside
        // `late_forward_free` against the member that actually released
        // the block.
        if let Some(san) = &inner.san {
            for (i, r) in flat.iter().enumerate() {
                if r.is_ok() {
                    san.on_free(GlobalAddr::new(dev as u32, addrs[i]), dev as u32);
                }
            }
        }
        // OURO_LIN: record the straight successes before the
        // late-forwarding rescue below mutates `flat` — a rescued free
        // released a *different* address on a *different* member, and
        // `late_forward_free` records it against that member itself.
        if let Some(lin) = &inner.lin {
            let res_ns = super::ring::mono_ns();
            for (i, r) in flat.iter().enumerate() {
                if r.is_ok() {
                    let (inv_ns, client) = ring.claim_info(slots[i]);
                    lin.record(OpRecord {
                        inv_ns,
                        res_ns,
                        client,
                        kind: OpKind::Free,
                        device: dev as u32,
                        class: q as u32,
                        addr: GlobalAddr::new(dev as u32, addrs[i]).raw(),
                        lease_id: 0,
                    });
                }
            }
        }
        // Late forwarding: a free that was already queued in this lane
        // when live-set migration claimed its block finds the page gone
        // and fails InvalidFree here — but the forwarding table knows
        // where the block went. Deliver it to the migrated copy now
        // (consuming the entry exactly once; grace-exempt, because the
        // service accepted this op *before* the block moved — the
        // client-facing grace window governs frees submitted after the
        // migration, not ops the drain raced), so a legitimate free
        // never turns into a spurious error just because it raced a
        // drain. Frees already rewritten at submit (`ForwardedFree`)
        // may chain the same way when their *target* member was drained
        // again while they were queued.
        if inner.forwarding.is_active() {
            for (i, r) in flat.iter_mut().enumerate() {
                if let Err(AllocError::InvalidFree(raw)) = *r {
                    if let Some(rescued) = inner.late_forward_free(
                        raw,
                        pre_forwarded[i],
                        ring.claim_info(slots[i]),
                    ) {
                        *r = rescued;
                    }
                }
            }
        }
        done.extend(
            slots
                .iter()
                .zip(flat)
                .map(|(&slot, r)| (slot, Completion::Free(r))),
        );
    }

    /// Execute a free against its forwarded address (dispatch-time
    /// forwarding — see `dispatch_frees` and the retired-lane drain in
    /// `dispatch`). `None` when the address has no unconsumed
    /// forwarding entry, leaving the original error in place.
    ///
    /// Deliberately **grace-exempt** (`ForwardingTable::take_queued`):
    /// an op reaching here was *accepted by the service before its
    /// block migrated* — it merely raced a drain while queued — so the
    /// client-facing staleness window must not apply; applying it was
    /// the submit/dispatch TOCTOU (an accepted free turning into a
    /// spurious `InvalidFree` because the grace expired while it sat in
    /// the lane). `chained` marks an op already counted as forwarded at
    /// submit, so a second hop is not double-counted.
    fn late_forward_free(
        &self,
        raw: u32,
        chained: bool,
        claim: (u64, u64),
    ) -> Option<Result<(), AllocError>> {
        let inner = self;
        let mut cur = inner.forwarding.take_queued(raw)?;
        // The op may have been queued across *several* drains: the copy
        // its entry points at can itself have migrated onward before
        // this dispatch ran. Follow the chain hop by hop rather than
        // failing an accepted free one drain short. Each hop consumes
        // its entry and each hop's source page is dead, so the chain
        // cannot revisit an address; the bound is belt and braces.
        let mut last = Err(AllocError::InvalidFree(raw));
        for _hop in 0..=inner.members.len() {
            if !cur.device_in(inner.members.len()) {
                return None;
            }
            let tgt = cur.device() as usize;
            let member = &inner.members[tgt];
            let alloc = member.alloc.clone();
            let dst = cur;
            let res: OrderedMutex<Option<Result<(), AllocError>>> =
                OrderedMutex::new(&classes::LAUNCH_RESULT, None);
            let st = member.device.launch(
                "service.free.forwarded",
                Grid::new(1),
                |w| {
                    *res.lock().unwrap() =
                        Some(alloc.free(&w.ctx, dst.local()));
                },
            );
            inner.stats.device_ns[tgt]
                // ordering: stat counter
                .fetch_add((st.device_us * 1e3) as u64, Ordering::Relaxed);
            let r = res
                .into_inner()
                .unwrap()
                .unwrap_or(Err(AllocError::QueueCorrupt));
            match r {
                Ok(()) => {
                    if let Some(san) = &inner.san {
                        san.on_free(dst, tgt as u32);
                    }
                    // OURO_LIN: the rescue released the migrated copy —
                    // record the free against the member and class that
                    // actually held it, paired with the `MigrateIn`
                    // that put it there.
                    if let Some(lin) = &inner.lin {
                        let (inv_ns, client) = claim;
                        let class = inner
                            .class_for_addr(dst)
                            .map_or(0, |(_, q)| q as u32);
                        lin.record(OpRecord {
                            inv_ns,
                            res_ns: super::ring::mono_ns(),
                            client,
                            kind: OpKind::Free,
                            device: tgt as u32,
                            class,
                            addr: dst.raw(),
                            lease_id: 0,
                        });
                    }
                    if !chained {
                        inner
                            .stats
                            .forwarded_frees
                            .fetch_add(1, Ordering::Relaxed); // ordering: stat counter
                    }
                    return Some(Ok(()));
                }
                Err(AllocError::InvalidFree(local)) => {
                    let tagged = GlobalAddr::new(tgt as u32, local).raw();
                    match inner.forwarding.take_queued(tagged) {
                        Some(next) => cur = next,
                        None => return Some(Err(
                            AllocError::InvalidFree(tagged),
                        )),
                    }
                    last = Err(AllocError::InvalidFree(tagged));
                }
                Err(e) => return Some(Err(e)),
            }
        }
        Some(last)
    }
}

impl AllocService {
    fn stop_and_join(&self) {
        for lane in &self.inner.lanes {
            lane.batcher.stop();
        }
        // Ring closing is owned by the workers' CloseOnExit guards: by
        // the time these joins return, every lane's last worker has
        // drained its accepted ops and closed its ring (the guard also
        // covers panic unwinds, which never reach this point). Workers
        // of already-retired members were joined by `retire_device` and
        // are no longer in the vector.
        let workers: Vec<(usize, JoinHandle<()>)> =
            self.inner.workers.lock().unwrap().drain(..).collect();
        for (_, w) in workers {
            let _ = w.join();
        }
        // Every lane has drained: anything still live in the shadow
        // heap was leaked by a client. The check self-latches, so the
        // shutdown() -> Drop double call reports at most once. A
        // handed-off sanitizer is exempt: its live set is the restart
        // payload, and the successor service runs the check instead.
        if let Some(san) = &self.inner.san {
            // ordering: Acquire pairs with prepare_handoff's Release
            if !self.inner.san_detached.load(Ordering::Acquire) {
                san.check_shutdown();
            }
        }
    }

    /// The `OURO_SAN` shadow heap this service reports into, if the
    /// sanitizer was enabled when the service started.
    pub fn sanitizer(&self) -> Option<Arc<crate::check::sanitizer::ShadowHeap>> {
        self.inner.san.clone()
    }

    /// The `OURO_LIN` op-history recorder this service reports into, if
    /// history recording was enabled when the service started. Harvest
    /// it after traffic and feed the result through
    /// [`crate::check::linearize::check`].
    pub fn history(&self) -> Option<Arc<HistoryRecorder>> {
        self.inner.lin.clone()
    }

    /// Drain and stop the workers.
    pub fn shutdown(self) -> u64 {
        self.stop_and_join();
        self.inner.stats.ops.load(Ordering::Relaxed) // ordering: stat read
    }

    // ---- restart durability ---------------------------------------------

    /// Capture the durable control-plane state: the forwarding table
    /// (entry ages, consumed flags), the forwarding grace, and every
    /// member's paced-drain cursor. Pair with
    /// [`AllocService::restore_state`] /
    /// [`AllocService::start_group_restored`]; persist across processes
    /// via [`ServiceSnapshot::encode`] / `save`.
    ///
    /// For a consistent capture, quiesce first (stop client traffic or
    /// use [`AllocService::prepare_handoff`], which snapshots *after*
    /// the workers join): an entry consumed between capture and
    /// shutdown would be restored un-spent.
    pub fn snapshot_state(&self) -> ServiceSnapshot {
        ServiceSnapshot {
            grace_nanos: self
                .inner
                .forwarding
                .grace()
                .as_nanos()
                .min(u64::MAX as u128) as u64,
            cursors: self
                .inner
                .drain_cursors
                .iter()
                .map(|c| {
                    let (chunk, page, exhausted) = c.lock().unwrap().parts();
                    CursorSnapshot { chunk, page, exhausted }
                })
                .collect(),
            entries: self.inner.forwarding.export(),
        }
    }

    /// Re-apply a durable snapshot to this (freshly started) service:
    /// forwarding grace, forwarding entries (ages re-anchored so each
    /// grace countdown resumes), and per-member drain cursors. Refuses
    /// with [`AllocError::SnapshotCorrupt`] when the snapshot's cursor
    /// count disagrees with this group's member count — a snapshot from
    /// a different topology must not be half-applied.
    pub fn restore_state(&self, snap: &ServiceSnapshot) -> Result<(), AllocError> {
        if snap.cursors.len() != self.inner.members.len() {
            return Err(AllocError::SnapshotCorrupt);
        }
        self.inner
            .forwarding
            .set_grace(Duration::from_nanos(snap.grace_nanos));
        self.inner.forwarding.restore(&snap.entries);
        for (slot, cs) in self.inner.drain_cursors.iter().zip(&snap.cursors) {
            *slot.lock().unwrap() =
                DrainCursor::from_parts(cs.chunk, cs.page, cs.exhausted);
        }
        Ok(())
    }

    /// Tear the service down for a restart, capturing everything the
    /// successor needs: workers are stopped and joined *first* (so no
    /// in-flight dispatch can consume a forwarding entry after the
    /// capture), then the durable state is snapshotted and the shadow
    /// heap (if armed) is detached — its live blocks are the restart
    /// payload, not leaks, so this instance's shutdown leak check is
    /// skipped and the successor inherits the full address histories.
    pub fn prepare_handoff(self) -> Handoff {
        // ordering: Release before stop_and_join's Acquire load
        self.inner.san_detached.store(true, Ordering::Release);
        self.stop_and_join();
        Handoff {
            snapshot: self.snapshot_state(),
            san: self.inner.san.clone(),
            lin: self.inner.lin.clone(),
            members: self
                .inner
                .members
                .iter()
                .map(|m| {
                    (
                        m.device.profile.clone(),
                        m.device.backend.clone(),
                        m.alloc.clone(),
                    )
                })
                .collect(),
        }
    }

    /// Start a service over `members` and restore a predecessor's
    /// durable state, so the new instance keeps honoring every stale
    /// name the old one promised to forward. The handoff's shadow heap
    /// (when the predecessor ran under `OURO_SAN=1`) carries over, so
    /// sanitizer address histories span the restart. Fails with
    /// [`AllocError::SnapshotCorrupt`] — starting nothing — when the
    /// snapshot's topology does not match `members`.
    pub fn start_group_restored(
        members: Vec<(Device, Arc<dyn DeviceAllocator>)>,
        policy: BatchPolicy,
        route: RoutePolicy,
        handoff: &Handoff,
    ) -> Result<Self, AllocError> {
        if handoff.snapshot.cursors.len() != members.len() {
            return Err(AllocError::SnapshotCorrupt);
        }
        let svc = Self::start_group_inner(
            members,
            policy,
            route,
            handoff.san.clone(),
            handoff.lin.clone(),
        );
        svc.restore_state(&handoff.snapshot)?;
        Ok(svc)
    }
}

/// Everything a restarted service inherits from its predecessor: the
/// durable control-plane snapshot plus (under `OURO_SAN=1`) the shadow
/// heap whose live set and address histories must span the restart.
/// Produced by [`AllocService::prepare_handoff`], consumed by
/// [`AllocService::start_group_restored`]. For a cross-process restart,
/// persist `snapshot` with [`ServiceSnapshot::save`] and rebuild the
/// handoff from [`ServiceSnapshot::load`].
pub struct Handoff {
    /// The durable control-plane state.
    pub snapshot: ServiceSnapshot,
    /// The predecessor's shadow heap, if the sanitizer was armed.
    pub san: Option<Arc<crate::check::sanitizer::ShadowHeap>>,
    /// The predecessor's op-history recorder, if `OURO_LIN` was armed —
    /// the successor records into the same history, so the
    /// linearizability check spans the restart.
    pub lin: Option<Arc<HistoryRecorder>>,
    /// The predecessor's members, by parts: profile + backend (a fresh
    /// `Device` is rebuilt from them) and — crucially — the *same*
    /// allocator `Arc`, so the successor serves the same heaps and
    /// every block live at the restart is still live after it.
    members: Vec<(DeviceProfile, Arc<dyn Backend>, Arc<dyn DeviceAllocator>)>,
}

impl Handoff {
    /// Build a handoff from a snapshot alone (e.g. one loaded from
    /// disk in a fresh process, where no in-memory shadow heap or heap
    /// state exists). [`Handoff::rebuild_members`] is empty for such a
    /// handoff — the caller must construct the successor's members
    /// itself and use [`AllocService::start_group_restored`] directly.
    pub fn from_snapshot(snapshot: ServiceSnapshot) -> Self {
        Handoff { snapshot, san: None, lin: None, members: Vec::new() }
    }

    /// Reconstruct the predecessor's member list for the successor:
    /// fresh `Device`s (same profile and backend), the same allocator
    /// handles — live heap state survives the restart intact.
    pub fn rebuild_members(&self) -> Vec<(Device, Arc<dyn DeviceAllocator>)> {
        self.members
            .iter()
            .map(|(profile, backend, alloc)| {
                (
                    Device::new(profile.clone(), backend.clone()),
                    alloc.clone(),
                )
            })
            .collect()
    }
}

impl Drop for AllocService {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Cuda;
    use crate::ouroboros::{build_allocator, HeapConfig, Variant};
    use crate::simt::DeviceProfile;
    use std::sync::Mutex;

    fn service() -> AllocService {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Page, &HeapConfig::test_small());
        AllocService::start(device, alloc, BatchPolicy::default())
    }

    fn group(n: usize, route: RoutePolicy) -> AllocService {
        AllocService::start_named_group(
            &vec![("t2000", Variant::Page); n],
            &HeapConfig::test_small(),
            BatchPolicy::default(),
            route,
            Arc::new(Cuda::new()),
        )
    }

    #[test]
    fn alloc_free_roundtrip_through_service() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(1000).unwrap();
        let b = c.alloc(1000).unwrap();
        assert_ne!(a, b);
        c.free(a).unwrap();
        c.free(b).unwrap();
        assert!(svc.stats().ops.load(Ordering::Relaxed) >= 4);
        // Single-device group: global addresses are untagged.
        assert_eq!(a.device(), 0);
        assert_eq!(a.raw(), a.local());
    }

    #[test]
    fn async_submit_wait_matches_blocking() {
        let svc = service();
        let c = svc.client();
        let t = c.submit_alloc(512).unwrap();
        let a = c.wait(t).unwrap().into_alloc().unwrap();
        let tf = c.submit_free(a).unwrap();
        c.wait(tf).unwrap().into_free().unwrap();
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn pipelined_submits_batch_and_wait_all_drains() {
        let svc = service();
        let c = svc.client();
        // 32 same-class ops in flight from ONE client thread: the whole
        // point of the pipeline — the lane can gather a wide batch
        // without 32 blocking threads.
        let tickets: Vec<Ticket> =
            (0..32).map(|_| c.submit_alloc(1000).unwrap()).collect();
        assert_eq!(c.in_flight(), 32);
        let done = c.wait_all();
        assert_eq!(done.len(), 32);
        assert_eq!(c.in_flight(), 0);
        let mut addrs: Vec<GlobalAddr> = done
            .into_iter()
            .map(|(_, r)| r.unwrap().into_alloc().unwrap())
            .collect();
        let n = addrs.len();
        addrs.sort_unstable();
        addrs.dedup();
        assert_eq!(addrs.len(), n, "pipeline handed out duplicate addresses");
        for a in addrs {
            c.free(a).unwrap();
        }
        // Ticket identities round-trip (first ticket was for lane q6 on
        // device 0).
        assert_eq!(tickets[0].lane(), 6);
        assert_eq!(tickets[0].device(), 0);
        // The pipeline actually ran deep.
        assert!(svc.ring_high_water()[6] > 1);
        assert!(svc.stats().mean_depth() > 1.0);
    }

    #[test]
    fn poll_reaps_exactly_once() {
        let svc = service();
        let c = svc.client();
        let t = c.submit_alloc(64).unwrap();
        // Spin-poll until complete.
        let completion = loop {
            if let Some(v) = c.poll(t) {
                break v;
            }
            std::thread::yield_now();
        };
        let a = completion.into_alloc().unwrap();
        assert_eq!(c.poll(t), None, "second poll of a reaped ticket");
        c.free(a).unwrap();
    }

    #[test]
    fn concurrent_clients_get_unique_addresses() {
        let svc = service();
        let addrs = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = svc.client();
                let addrs = &addrs;
                s.spawn(move || {
                    let mut mine = Vec::new();
                    for _ in 0..20 {
                        mine.push(c.alloc(64).unwrap());
                    }
                    addrs.lock().unwrap().extend(mine);
                });
            }
        });
        let mut got = addrs.into_inner().unwrap();
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "service handed out duplicate addresses");
        // Batching actually happened (mean batch > 1 with 8 clients).
        assert!(svc.stats().mean_batch() > 1.0);
    }

    /// Satellite regression: reaping a deep pipeline must cost O(1)
    /// ledger work per op, not an O(n) scan + shift under the
    /// outstanding mutex. Asserted op-count-wise (`Outstanding::work`),
    /// no wall-clock flakiness: the old Vec scheme did ~n²/2 element
    /// touches for this exact drain (≈131k at depth 512); the ledger
    /// is bounded at a small constant per op including compaction.
    #[test]
    fn deep_pipeline_reap_cost_is_linear() {
        const DEPTH: usize = 512;
        let svc = service();
        let c = svc.client();
        let tickets: Vec<Ticket> =
            (0..DEPTH).map(|_| c.submit_alloc(1000).unwrap()).collect();
        assert_eq!(c.in_flight(), DEPTH);
        let mut addrs = Vec::with_capacity(DEPTH);
        for t in tickets {
            addrs.push(c.wait(t).unwrap().into_alloc().unwrap());
        }
        assert_eq!(c.in_flight(), 0);
        let work = c.ledger_work();
        assert!(
            work <= (DEPTH as u64) * 8,
            "outstanding ledger did {work} element touches for {} ops — \
             reap cost has regressed toward the old quadratic scan",
            2 * DEPTH
        );
        for a in addrs {
            c.free(a).unwrap();
        }
    }

    #[test]
    fn interleaved_reaps_preserve_wait_all_submission_order() {
        let svc = service();
        let c = svc.client();
        let tickets: Vec<Ticket> =
            (0..8).map(|_| c.submit_alloc(1000).unwrap()).collect();
        // Reap two from the middle out of order; tombstones must keep
        // the rest in submission order for wait_all.
        let a3 = c.wait(tickets[3]).unwrap().into_alloc().unwrap();
        let a1 = c.wait(tickets[1]).unwrap().into_alloc().unwrap();
        assert_eq!(c.in_flight(), 6);
        let drained = c.wait_all();
        let expect: Vec<Ticket> = tickets
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != 1 && *i != 3)
            .map(|(_, t)| *t)
            .collect();
        let got: Vec<Ticket> = drained.iter().map(|(t, _)| *t).collect();
        assert_eq!(got, expect, "wait_all must keep submission order");
        let mut addrs = vec![a1, a3];
        for (_, r) in drained {
            addrs.push(r.unwrap().into_alloc().unwrap());
        }
        for a in addrs {
            c.free(a).unwrap();
        }
    }

    #[test]
    fn oversize_rejected_through_service() {
        let svc = service();
        let c = svc.client();
        assert_eq!(c.alloc(9000), Err(AllocError::TooLarge(9000)));
        assert_eq!(c.alloc(0), Err(AllocError::ZeroSize));
    }

    #[test]
    fn out_of_heap_free_rejected_at_submit() {
        let svc = service();
        let c = svc.client();
        let wild = GlobalAddr::from_raw(0xDEAD_0000);
        let before = svc.stats().batches.load(Ordering::Relaxed);
        assert_eq!(
            c.submit_free(wild).unwrap_err(),
            AllocError::InvalidFree(0xDEAD_0000)
        );
        assert_eq!(c.free(wild), Err(AllocError::InvalidFree(0xDEAD_0000)));
        assert_eq!(svc.stats().invalid_frees.load(Ordering::Relaxed), 2);
        // The wild frees never occupied a lane batch.
        assert_eq!(svc.stats().batches.load(Ordering::Relaxed), before);
        assert_eq!(c.in_flight(), 0);
    }

    #[test]
    fn free_with_out_of_range_device_tag_rejected() {
        let svc = group(2, RoutePolicy::RoundRobin);
        let c = svc.client();
        // In-bounds local offset, but device 5 of a 2-device group.
        let phantom = GlobalAddr::new(5, 16);
        assert_eq!(
            c.free(phantom),
            Err(AllocError::InvalidFree(phantom.raw()))
        );
        assert_eq!(svc.stats().invalid_frees.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn shutdown_is_clean() {
        let svc = service();
        let c = svc.client();
        c.alloc(128).unwrap();
        let ops = svc.shutdown();
        assert!(ops >= 1);
    }

    #[test]
    fn dead_service_reports_service_down_not_corruption() {
        let svc = service();
        let c = svc.client();
        let a = c.alloc(256).unwrap();
        c.free(a).unwrap();
        svc.shutdown();
        assert_eq!(c.alloc(256), Err(AllocError::ServiceDown));
        assert_eq!(c.free(a), Err(AllocError::ServiceDown));
        assert!(c.submit_alloc(256).is_err());
    }

    #[test]
    fn submitted_work_completes_across_shutdown() {
        let svc = service();
        let c = svc.client();
        let tickets: Vec<Ticket> =
            (0..8).map(|_| c.submit_alloc(100).unwrap()).collect();
        // Shutdown drains accepted ops before the workers exit, so every
        // ticket still resolves to a real completion.
        svc.shutdown();
        for t in tickets {
            c.wait(t).unwrap().into_alloc().unwrap();
        }
    }

    #[test]
    fn lanes_shard_by_size_class() {
        let svc = service();
        let c = svc.client();
        // Three distinct classes: q0 (16 B), q6 (1000 B), q9 (8 KiB).
        let mut addrs = Vec::new();
        for &size in &[16u32, 1000, 8192] {
            for _ in 0..4 {
                addrs.push(c.alloc(size).unwrap());
            }
        }
        for a in addrs {
            c.free(a).unwrap();
        }
        let lanes = svc.stats().lane_batches();
        assert_eq!(lanes.len(), NUM_QUEUES);
        for q in [0usize, 6, 9] {
            assert!(lanes[q] > 0, "lane {q} saw no batches: {lanes:?}");
        }
        // Classes that never saw a request stay silent lanes.
        assert_eq!(lanes[3], 0, "unexpected traffic on idle lane: {lanes:?}");
        // Per-lane counts are a partition of the aggregate.
        assert_eq!(
            lanes.iter().sum::<u64>(),
            svc.stats().batches.load(Ordering::Relaxed)
        );
        assert_eq!(
            svc.stats().lane_ops().iter().sum::<u64>(),
            svc.stats().ops.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn single_lane_policy_still_works() {
        let device =
            Device::new(DeviceProfile::t2000(), Arc::new(Cuda::new()));
        let alloc = build_allocator(Variant::Chunk, &HeapConfig::test_small());
        let svc =
            AllocService::start(device, alloc, BatchPolicy::single_lane());
        let c = svc.client();
        let addrs: Vec<GlobalAddr> = (0u32..16)
            .map(|i| c.alloc(16u32 << (i % 5)).unwrap())
            .collect();
        for a in addrs {
            c.free(a).unwrap();
        }
        assert_eq!(svc.stats().lane_batches().len(), 1);
        assert!(svc.stats().lane_batches()[0] > 0);
    }

    // ---- device-group topology ------------------------------------------

    #[test]
    fn round_robin_spreads_allocs_across_devices() {
        let svc = group(2, RoutePolicy::RoundRobin);
        let c = svc.client();
        let addrs: Vec<GlobalAddr> =
            (0..8).map(|_| c.alloc(1000).unwrap()).collect();
        // A single serial client round-robins exactly.
        let on_dev0 = addrs.iter().filter(|a| a.device() == 0).count();
        let on_dev1 = addrs.iter().filter(|a| a.device() == 1).count();
        assert_eq!((on_dev0, on_dev1), (4, 4), "{addrs:?}");
        for a in addrs {
            c.free(a).unwrap();
        }
        let snap = svc.snapshot();
        assert_eq!(snap.devices.len(), 2);
        for d in &snap.devices {
            assert_eq!(d.allocs, 4, "{snap:?}");
            assert_eq!(d.frees, 4, "frees must route home: {snap:?}");
            assert!(d.device_us > 0.0);
        }
        // Per-device rollups partition the aggregates.
        assert_eq!(
            snap.devices.iter().map(|d| d.ops).sum::<u64>(),
            snap.ops
        );
        assert_eq!(
            snap.devices.iter().map(|d| d.batches).sum::<u64>(),
            snap.batches
        );
        // Flat lane vector covers both devices.
        assert_eq!(snap.lane_batches.len(), 2 * NUM_QUEUES);
    }

    #[test]
    fn client_affinity_pins_allocs_and_frees_route_home() {
        let svc = group(2, RoutePolicy::ClientAffinity);
        let c0 = svc.client();
        let c1 = svc.client();
        assert_eq!((c0.affinity(), c1.affinity()), (0, 1));
        let a0: Vec<GlobalAddr> =
            (0..3).map(|_| c0.alloc(256).unwrap()).collect();
        let a1: Vec<GlobalAddr> =
            (0..3).map(|_| c1.alloc(256).unwrap()).collect();
        assert!(a0.iter().all(|a| a.device() == 0), "{a0:?}");
        assert!(a1.iter().all(|a| a.device() == 1), "{a1:?}");
        // Cross-device frees: each client frees the OTHER client's
        // memory; the ops must still land on the owning device.
        for a in a1 {
            c0.free(a).unwrap();
        }
        for a in a0 {
            c1.free(a).unwrap();
        }
        let snap = svc.snapshot();
        for d in &snap.devices {
            assert_eq!(d.allocs, 3, "{snap:?}");
            assert_eq!(d.frees, 3, "{snap:?}");
        }
    }

    #[test]
    fn least_loaded_balances_by_ring_occupancy() {
        let svc = group(2, RoutePolicy::LeastLoaded);
        let c = svc.client();
        // Submit without reaping: occupancy rises as we go, so the
        // router must alternate devices (ties rotate with the cursor).
        let tickets: Vec<Ticket> =
            (0..16).map(|_| c.submit_alloc(1000).unwrap()).collect();
        let on_dev0 = tickets.iter().filter(|t| t.device() == 0).count();
        assert_eq!(on_dev0, 8, "least-loaded must balance: {tickets:?}");
        let addrs: Vec<GlobalAddr> = c
            .wait_all()
            .into_iter()
            .map(|(_, r)| r.unwrap().into_alloc().unwrap())
            .collect();
        for a in addrs {
            c.free(a).unwrap();
        }
        let snap = svc.snapshot();
        for d in &snap.devices {
            assert_eq!(d.allocs, 8, "{snap:?}");
            assert_eq!(d.frees, 8, "{snap:?}");
        }
    }

    #[test]
    fn foreign_ticket_is_deterministically_rejected() {
        let svc1 = service();
        let svc2 = service();
        let c1 = svc1.client();
        let c2 = svc2.client();
        let t = c1.submit_alloc(512).unwrap();
        // The other service rejects the ticket without touching any
        // ring: wait errors, poll stays None — never a hang, never
        // another op's payload.
        assert_eq!(c2.wait(t), Err(AllocError::ForeignTicket));
        assert_eq!(c2.poll(t), None);
        // The minting service still serves it.
        let a = c1.wait(t).unwrap().into_alloc().unwrap();
        c1.free(a).unwrap();
    }

    #[test]
    fn cross_handle_reap_is_exactly_once_then_stale() {
        let svc = service();
        let c1 = svc.client();
        let c2 = c1.clone();
        let t = c1.submit_alloc(128).unwrap();
        // Another handle of the same service may reap the ticket...
        let a = c2.wait(t).unwrap().into_alloc().unwrap();
        // ...after which it is stale everywhere: poll never fires,
        // wait errors deterministically (documented semantics).
        assert_eq!(c1.poll(t), None);
        assert_eq!(c1.wait(t), Err(AllocError::ServiceDown));
        // The submitter's wait_all reports the same stale error.
        let t2 = c1.submit_alloc(128).unwrap();
        let _ = c2.wait(t2);
        let drained = c1.wait_all();
        assert!(drained
            .iter()
            .all(|(_, r)| *r == Err(AllocError::ServiceDown)));
        c2.free(a).unwrap();
    }
}
