//! Warp-shaped request batching — the avail ring of the async pipeline.
//!
//! The TPU-stack analogue of the warp-vote cooperation the paper wrestles
//! with (DESIGN.md §4c): concurrent allocation requests arriving at the
//! coordinator are coalesced into warp-width batches before being issued
//! to the device, so one warp-collective bulk queue operation serves the
//! whole group — exactly the amortisation `__activemask()` voting
//! achieves inside a CUDA kernel. The sharded [`super::service`] runs one
//! `Batcher` per request lane.
//!
//! Since the async ticket pipeline, a batcher carries **descriptor ids**
//! into the lane's ticket ring (`ring.rs`), not op payloads, and the
//! lane is **double-buffered**: `next_batch` hands the whole fill buffer
//! to the device worker with an O(1) swap against a recycled buffer, so
//! clients fill batch N+1 while the worker drains batch N through the
//! coalesced bulk paths — the device never idles behind batch gathering,
//! and the hot path allocates nothing in steady state.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ouroboros::params::NUM_QUEUES;

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Batch size at which the straggler window closes early; the
    /// double-buffer swap itself takes everything queued, so a burst
    /// deeper than `max_batch` still dispatches as one batch.
    pub max_batch: usize,
    /// How long to hold an underfull batch open for stragglers.
    pub window: Duration,
    /// Independent request lanes the service shards into (size class `q`
    /// maps to lane `q * lanes / NUM_QUEUES`). 1 = the seed's
    /// single-batcher *topology* (dispatch still uses the new bulk
    /// paths), kept as the benchmark baseline for the sharding effect.
    pub lanes: usize,
    /// Device worker threads dispatching each lane's batches.
    pub workers_per_lane: usize,
    /// Descriptors per lane ticket ring — the maximum in-flight ops a
    /// lane can hold; submission blocks (backpressure) when exceeded.
    pub ring_slots: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            window: Duration::from_micros(200),
            lanes: NUM_QUEUES,
            workers_per_lane: 1,
            ring_slots: 1024,
        }
    }
}

impl BatchPolicy {
    /// The pre-sharding topology: one lane, one worker (bulk dispatch
    /// included — see the `lanes` field docs).
    pub fn single_lane() -> Self {
        BatchPolicy { lanes: 1, ..BatchPolicy::default() }
    }
}

#[derive(Default)]
pub struct Batcher {
    /// The fill half of the double buffer: descriptor ids submitted
    /// since the last swap.
    fill: Mutex<Vec<u32>>,
    cv: Condvar,
    pub shutdown: AtomicBool,
    /// Recycled drain buffers handed back by [`Batcher::recycle`]; a
    /// swap pops one instead of allocating.
    spare: Mutex<Vec<Vec<u32>>>,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue descriptor `slot` for the next batch. Returns `false` —
    /// with the slot NOT queued — once the batcher has shut down, so
    /// callers can abort the ring claim and surface `ServiceDown`. The
    /// shutdown check happens under the fill lock: an accepted slot is
    /// always visible to the worker's final drain.
    pub fn submit(&self, slot: u32) -> bool {
        let mut q = self.fill.lock().unwrap();
        // ordering: Acquire; pairs with stop()/restart() Release
        if self.shutdown.load(Ordering::Acquire) {
            return false;
        }
        q.push(slot);
        drop(q);
        // notify_all, not notify_one: with several workers parked on the
        // same condvar (phase-1 and phase-2 waits share it), a single
        // token could wake only a straggler-window waiter and strand the
        // op until its timeout.
        self.cv.notify_all();
        true
    }

    pub fn pending(&self) -> usize {
        self.fill.lock().unwrap().len()
    }

    pub fn stop(&self) {
        // ordering: Release; queued ops visible before the stop
        self.shutdown.store(true, Ordering::Release);
        // Lock barrier: any submit that raced past its shutdown check has
        // published its slot before this; later submits see the flag.
        drop(self.fill.lock().unwrap());
        self.cv.notify_all();
    }

    /// Re-arm a stopped batcher for a readmitted lane's fresh workers.
    /// Only valid once the old workers' final drain emptied the fill
    /// buffer and the workers were joined — a readmit owns this window
    /// exclusively (the control plane serialises on the rebalance lock).
    pub fn restart(&self) {
        let q = self.fill.lock().unwrap();
        debug_assert!(q.is_empty(), "restarting a batcher with queued work");
        // ordering: Release; clean batcher visible before reuse
        self.shutdown.store(false, Ordering::Release);
        drop(q);
    }

    /// Block for the next batch: wait for the first op, hold the batch
    /// open up to `policy.window` (or until `max_batch` deep), then swap
    /// the whole fill buffer out in O(1). Returns `None` on shutdown
    /// with an empty queue. Pass drained buffers back via
    /// [`Batcher::recycle`] to keep the double buffer allocation-free.
    pub fn next_batch(&self, policy: &BatchPolicy) -> Option<Vec<u32>> {
        let mut q = self.fill.lock().unwrap();
        // Phase 1: wait for any work. A plain condvar wait with the
        // predicate re-checked under the lock — `submit` publishes the op
        // and notifies while holding/after the same lock, so a request
        // submitted concurrently with this wait is picked up immediately
        // (no timeout poll; the seed's 5 ms `wait_timeout` workaround hid
        // a lost-notification bug and cost worst-case 5 ms latency).
        loop {
            if !q.is_empty() {
                break;
            }
            // ordering: Acquire; pairs with stop()/restart() Release
            if self.shutdown.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
        // Phase 2: hold the window open for stragglers — but close early
        // if a sub-window wait brings no growth (otherwise an idle
        // single client pays the full window on every op; see
        // EXPERIMENTS.md §Perf L3 iteration 3).
        let deadline = Instant::now() + policy.window;
        let probe = (policy.window / 4).max(Duration::from_micros(10));
        while q.len() < policy.max_batch
            // ordering: Acquire; pairs with stop()/restart() Release
            && !self.shutdown.load(Ordering::Acquire)
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let before = q.len();
            let wait = probe.min(deadline - now);
            let (guard, _) = self.cv.wait_timeout(q, wait).unwrap();
            q = guard;
            if q.len() == before {
                break; // idle: no stragglers coming
            }
        }
        // The swap: hand the full buffer to the caller, leave a recycled
        // empty one filling. Clients never wait on the drain.
        let mut batch = self
            .spare
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(q.len().max(policy.max_batch)));
        std::mem::swap(&mut *q, &mut batch);
        Some(batch)
    }

    /// Return a drained batch buffer for reuse by the next swap.
    pub fn recycle(&self, mut buf: Vec<u32>) {
        buf.clear();
        let mut spare = self.spare.lock().unwrap();
        // One buffer per in-flight dispatch is enough; cap the pool so a
        // burst of giant batches doesn't pin memory forever.
        if spare.len() < 4 {
            spare.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn swap_takes_whole_fill_buffer() {
        let b = Batcher::new();
        for i in 0..40 {
            assert!(b.submit(i));
        }
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::ZERO,
            ..Default::default()
        };
        // Double-buffer swap: one batch carries the whole burst (deeper
        // than max_batch — the cap only gates the straggler window).
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 40);
        assert_eq!(batch, (0..40).collect::<Vec<u32>>());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn recycled_buffer_is_reused() {
        let b = Batcher::new();
        let policy = BatchPolicy {
            max_batch: 8,
            window: Duration::ZERO,
            ..Default::default()
        };
        // The two buffers ping-pong: the recycled batch becomes the next
        // fill buffer, so the buffer returned on cycle 3 is the same
        // allocation as cycle 1's.
        b.submit(1);
        let batch1 = b.next_batch(&policy).unwrap();
        let ptr1 = batch1.as_ptr();
        b.recycle(batch1);
        b.submit(2);
        let batch2 = b.next_batch(&policy).unwrap();
        assert_eq!(batch2, vec![2]);
        b.recycle(batch2);
        b.submit(3);
        let batch3 = b.next_batch(&policy).unwrap();
        assert_eq!(batch3, vec![3]);
        assert_eq!(batch3.as_ptr(), ptr1, "double buffer must ping-pong");
    }

    #[test]
    fn clients_fill_next_batch_while_drain_outstanding() {
        let b = Batcher::new();
        let policy = BatchPolicy {
            max_batch: 4,
            window: Duration::ZERO,
            ..Default::default()
        };
        b.submit(10);
        let draining = b.next_batch(&policy).unwrap();
        assert_eq!(draining, vec![10]);
        // While the worker "dispatches" `draining`, new submits land in
        // the other buffer immediately.
        assert!(b.submit(11));
        assert!(b.submit(12));
        assert_eq!(b.pending(), 2);
        let next = b.next_batch(&policy).unwrap();
        assert_eq!(next, vec![11, 12]);
        b.recycle(draining);
        b.recycle(next);
    }

    #[test]
    fn window_gathers_stragglers() {
        let b = Arc::new(Batcher::new());
        b.submit(1);
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            b2.submit(2);
        });
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::from_millis(50),
            ..Default::default()
        };
        let batch = b.next_batch(&policy).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler should join the open batch");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new();
        b.submit(1);
        b.stop();
        let policy = BatchPolicy::default();
        assert_eq!(b.next_batch(&policy).unwrap().len(), 1);
        assert!(b.next_batch(&policy).is_none());
    }

    #[test]
    fn submit_after_stop_rejected() {
        let b = Batcher::new();
        b.stop();
        assert!(!b.submit(1));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn restart_rearms_a_stopped_batcher() {
        let b = Batcher::new();
        b.stop();
        assert!(!b.submit(1));
        b.restart();
        assert!(b.submit(2), "restarted batcher must accept work again");
        let policy = BatchPolicy {
            max_batch: 8,
            window: Duration::ZERO,
            ..Default::default()
        };
        assert_eq!(b.next_batch(&policy).unwrap(), vec![2]);
    }

    /// The lost-notification regression: a waiter blocked in phase 1 must
    /// be woken by a concurrent submit well before the seed's 5 ms poll
    /// interval would have noticed it.
    #[test]
    fn concurrent_submit_wakes_phase1_waiter() {
        let b = Arc::new(Batcher::new());
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let policy = BatchPolicy {
                max_batch: 1,
                window: Duration::ZERO,
                ..Default::default()
            };
            let t0 = Instant::now();
            let batch = b2.next_batch(&policy).unwrap();
            (batch.len(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(b.submit(7));
        let (len, waited) = t.join().unwrap();
        assert_eq!(len, 1);
        assert!(
            waited < Duration::from_secs(2),
            "phase-1 wait did not wake promptly ({waited:?})"
        );
    }

    #[test]
    fn default_policy_is_sharded() {
        let p = BatchPolicy::default();
        assert_eq!(p.lanes, NUM_QUEUES);
        assert_eq!(BatchPolicy::single_lane().lanes, 1);
        assert!(p.ring_slots >= p.max_batch);
    }
}
