//! Warp-shaped request batching — the avail ring of the async pipeline.
//!
//! The TPU-stack analogue of the warp-vote cooperation the paper wrestles
//! with (DESIGN.md §4c): concurrent allocation requests arriving at the
//! coordinator are coalesced into warp-width batches before being issued
//! to the device, so one warp-collective bulk queue operation serves the
//! whole group — exactly the amortisation `__activemask()` voting
//! achieves inside a CUDA kernel. The sharded [`super::service`] runs one
//! `Batcher` per request lane.
//!
//! Since the async ticket pipeline, a batcher carries **descriptor ids**
//! into the lane's ticket ring (`ring.rs`), not op payloads, and the
//! lane is **double-buffered**: `next_batch` hands the whole fill buffer
//! to the device worker with an O(1) swap against a recycled buffer, so
//! clients fill batch N+1 while the worker drains batch N through the
//! coalesced bulk paths — the device never idles behind batch gathering,
//! and the hot path allocates nothing in steady state.
//!
//! # Doorbell coalescing (virtio avail-ring `avail_event` discipline)
//!
//! The eager design notified the worker condvar on **every** submit —
//! under an 8-client depth-32 churn, that is one syscall-bound wakeup
//! per op landing on a worker that is already awake draining the other
//! buffer. The batcher instead mirrors the ticket ring's EVENT_IDX
//! protocol on the submit side:
//!
//! * A worker parked in the phase-1 wait (empty fill buffer) registers
//!   in `parked`; submits always ring the doorbell for parked workers —
//!   a phase-1 wait has no timeout, so this is the correctness half.
//! * A worker gathering stragglers (phase 2) publishes an
//!   **`avail_event`** watermark — "kick me when the fill buffer
//!   reaches N" (the batch-close threshold `max_batch`). Submits below
//!   the watermark stay silent: the worker's bounded probe
//!   (`window/4`, ≥ 10 µs) re-checks growth anyway, so a suppressed
//!   straggler costs at most one probe of extra latency, never a hang.
//! * While the worker is off dispatching (between the buffer swap and
//!   its next `next_batch`), the watermark parks at `u32::MAX`: nobody
//!   is listening, no doorbell rings.
//!
//! Every flag and watermark is read and written **under the fill
//! mutex**, so no fences are needed — the mutex orders the handshake.
//! `Batcher::with_notify(true)` restores the eager baseline the bench
//! compares against.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Condvar;
use std::time::{Duration, Instant};

use crate::check::lockgraph::{self, classes, OrderedMutex};
use crate::ouroboros::params::NUM_QUEUES;

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Batch size at which the straggler window closes early; the
    /// double-buffer swap itself takes everything queued, so a burst
    /// deeper than `max_batch` still dispatches as one batch.
    pub max_batch: usize,
    /// How long to hold an underfull batch open for stragglers.
    pub window: Duration,
    /// Independent request lanes the service shards into (size class `q`
    /// maps to lane `q * lanes / NUM_QUEUES`). 1 = the seed's
    /// single-batcher *topology* (dispatch still uses the new bulk
    /// paths), kept as the benchmark baseline for the sharding effect.
    pub lanes: usize,
    /// Device worker threads dispatching each lane's batches.
    pub workers_per_lane: usize,
    /// Descriptors per lane ticket ring — the maximum in-flight ops a
    /// lane can hold; submission blocks (backpressure) when exceeded.
    pub ring_slots: usize,
    /// `true` disables the EVENT_IDX wakeup-suppression discipline on
    /// the lanes' rings and batchers: every completion batch broadcasts
    /// and every submit rings the worker doorbell, whether or not
    /// anyone is listening. The pre-PR-9 behaviour, kept as the bench's
    /// comparison baseline; production topologies leave it `false`.
    pub eager_notify: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            window: Duration::from_micros(200),
            lanes: NUM_QUEUES,
            workers_per_lane: 1,
            ring_slots: 1024,
            eager_notify: false,
        }
    }
}

impl BatchPolicy {
    /// The pre-sharding topology: one lane, one worker (bulk dispatch
    /// included — see the `lanes` field docs).
    pub fn single_lane() -> Self {
        BatchPolicy { lanes: 1, ..BatchPolicy::default() }
    }
}

pub struct Batcher {
    /// The fill half of the double buffer: descriptor ids submitted
    /// since the last swap.
    fill: OrderedMutex<Vec<u32>>,
    cv: Condvar,
    pub shutdown: AtomicBool,
    /// Recycled drain buffers handed back by [`Batcher::recycle`]; a
    /// swap pops one instead of allocating.
    spare: OrderedMutex<Vec<Vec<u32>>>,
    /// Eager baseline: every submit rings the doorbell (module docs).
    eager: bool,
    /// Workers parked in the phase-1 (untimed) wait. Read and written
    /// only under the fill mutex; a parked worker must always be kicked.
    parked: AtomicU32,
    /// The avail-side watermark: "ring the doorbell when the fill
    /// buffer reaches this depth". Phase-2 workers publish the
    /// batch-close threshold; a dispatching worker parks it at
    /// `u32::MAX`. Read and written only under the fill mutex. The
    /// default (0) is "always ring" — safe for a batcher nobody has
    /// drained yet.
    avail_event: AtomicU32,
    /// Doorbell decisions: rung vs elided — summed into
    /// `StatsSnapshot::doorbell_{delivered,suppressed}`.
    delivered: AtomicU64,
    suppressed: AtomicU64,
}

impl Default for Batcher {
    fn default() -> Self {
        Batcher {
            fill: OrderedMutex::new(&classes::BATCHER_FILL, Vec::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            spare: OrderedMutex::new(&classes::BATCHER_SPARE, Vec::new()),
            eager: false,
            parked: AtomicU32::new(0),
            avail_event: AtomicU32::new(0),
            delivered: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
        }
    }
}

impl Batcher {
    /// A batcher with doorbell coalescing armed (production default).
    pub fn new() -> Self {
        Self::default()
    }

    /// `eager = true` builds the pre-suppression baseline: every submit
    /// notifies the worker condvar (the bench's comparison leg).
    pub fn with_notify(eager: bool) -> Self {
        Batcher { eager, ..Self::default() }
    }

    /// (delivered, suppressed) doorbell decisions so far.
    pub fn doorbells(&self) -> (u64, u64) {
        // ordering: stat read
        (self.delivered.load(Ordering::Relaxed), self.suppressed.load(Ordering::Relaxed))
    }

    /// Queue descriptor `slot` for the next batch. Returns `false` —
    /// with the slot NOT queued — once the batcher has shut down, so
    /// callers can abort the ring claim and surface `ServiceDown`. The
    /// shutdown check happens under the fill lock: an accepted slot is
    /// always visible to the worker's final drain.
    ///
    /// The doorbell only rings if a worker is parked in the phase-1
    /// wait or this push filled the buffer to the worker-published
    /// `avail_event` watermark — both read under the same fill mutex
    /// the worker publishes them under, so the decision races with
    /// nothing.
    pub fn submit(&self, slot: u32) -> bool {
        let mut q = self.fill.lock().unwrap();
        // ordering: Acquire; pairs with stop()/restart() Release
        if self.shutdown.load(Ordering::Acquire) {
            return false;
        }
        q.push(slot);
        let ring = self.eager
            // ordering: Relaxed; the fill mutex orders the handshake
            || self.parked.load(Ordering::Relaxed) != 0
            // ordering: Relaxed; the fill mutex orders the handshake
            || q.len() as u32 >= self.avail_event.load(Ordering::Relaxed);
        drop(q);
        if ring {
            self.delivered.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            // notify_all, not notify_one: with several workers parked on
            // the same condvar (phase-1 and phase-2 waits share it), a
            // single token could wake only a straggler-window waiter and
            // strand the op until its timeout.
            self.cv.notify_all();
        } else {
            self.suppressed.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        }
        true
    }

    pub fn pending(&self) -> usize {
        self.fill.lock().unwrap().len()
    }

    pub fn stop(&self) {
        // ordering: Release; queued ops visible before the stop
        self.shutdown.store(true, Ordering::Release);
        // Lock barrier: any submit that raced past its shutdown check has
        // published its slot before this; later submits see the flag.
        drop(self.fill.lock().unwrap());
        self.cv.notify_all();
    }

    /// Re-arm a stopped batcher for a readmitted lane's fresh workers.
    /// Only valid once the old workers' final drain emptied the fill
    /// buffer and the workers were joined — a readmit owns this window
    /// exclusively (the control plane serialises on the rebalance lock).
    pub fn restart(&self) {
        let q = self.fill.lock().unwrap();
        debug_assert!(q.is_empty(), "restarting a batcher with queued work");
        // Re-arm the doorbell: the dead workers' parked-at-MAX watermark
        // must not silence submits racing the fresh workers' first park.
        // ordering: Relaxed; the fill mutex orders the handshake
        self.avail_event.store(0, Ordering::Relaxed);
        // ordering: Release; clean batcher visible before reuse
        self.shutdown.store(false, Ordering::Release);
        drop(q);
    }

    /// Block for the next batch: wait for the first op, hold the batch
    /// open up to `policy.window` (or until `max_batch` deep), then swap
    /// the whole fill buffer out in O(1). Returns `None` on shutdown
    /// with an empty queue. Pass drained buffers back via
    /// [`Batcher::recycle`] to keep the double buffer allocation-free.
    pub fn next_batch(&self, policy: &BatchPolicy) -> Option<Vec<u32>> {
        let mut q = self.fill.lock().unwrap();
        // Phase 1: wait for any work. A plain condvar wait with the
        // predicate re-checked under the lock — `submit` publishes the op
        // and notifies while holding/after the same lock, so a request
        // submitted concurrently with this wait is picked up immediately
        // (no timeout poll; the seed's 5 ms `wait_timeout` workaround hid
        // a lost-notification bug and cost worst-case 5 ms latency).
        // A phase-1 parker registers in `parked` (under this mutex):
        // this untimed wait has no probe to fall back on, so submits
        // always ring the doorbell for it.
        loop {
            if !q.is_empty() {
                break;
            }
            // ordering: Acquire; pairs with stop()/restart() Release
            if self.shutdown.load(Ordering::Acquire) {
                return None;
            }
            // ordering: Relaxed; the fill mutex orders the handshake
            self.parked.fetch_add(1, Ordering::Relaxed);
            q = lockgraph::wait(&self.cv, q);
            // ordering: Relaxed; reacquired the fill mutex
            self.parked.fetch_sub(1, Ordering::Relaxed);
        }
        // Phase 2: hold the window open for stragglers — but close early
        // if a sub-window wait brings no growth (otherwise an idle
        // single client pays the full window on every op; see
        // EXPERIMENTS.md §Perf L3 iteration 3).
        //
        // Doorbell watermark: only a submit that fills the batch to its
        // close threshold needs to cut the window short; sub-watermark
        // stragglers are picked up by the bounded probe below at no
        // more than one probe of extra latency. (With several phase-2
        // workers the last swap's parked-at-MAX store can clobber this
        // — also probe-bounded, see the module docs.)
        if !self.eager {
            // ordering: Relaxed; the fill mutex orders the handshake
            self.avail_event.store(policy.max_batch as u32, Ordering::Relaxed);
        }
        let deadline = Instant::now() + policy.window;
        let probe = (policy.window / 4).max(Duration::from_micros(10));
        while q.len() < policy.max_batch
            // ordering: Acquire; pairs with stop()/restart() Release
            && !self.shutdown.load(Ordering::Acquire)
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let before = q.len();
            let wait = probe.min(deadline - now);
            let (guard, _) = lockgraph::wait_timeout(&self.cv, q, wait);
            q = guard;
            if q.len() == before {
                break; // idle: no stragglers coming
            }
        }
        // The swap: hand the full buffer to the caller, leave a recycled
        // empty one filling. Clients never wait on the drain.
        let mut batch = self
            .spare
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(q.len().max(policy.max_batch)));
        std::mem::swap(&mut *q, &mut batch);
        // Off to dispatch: park the doorbell — submits landing in the
        // fresh fill buffer have nobody to wake until this worker (or a
        // peer) re-enters `next_batch`, whose phase-1 check sees them.
        if !self.eager {
            // ordering: Relaxed; the fill mutex orders the handshake
            self.avail_event.store(u32::MAX, Ordering::Relaxed);
        }
        Some(batch)
    }

    /// Return a drained batch buffer for reuse by the next swap.
    pub fn recycle(&self, mut buf: Vec<u32>) {
        buf.clear();
        let mut spare = self.spare.lock().unwrap();
        // One buffer per in-flight dispatch is enough; cap the pool so a
        // burst of giant batches doesn't pin memory forever.
        if spare.len() < 4 {
            spare.push(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn swap_takes_whole_fill_buffer() {
        let b = Batcher::new();
        for i in 0..40 {
            assert!(b.submit(i));
        }
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::ZERO,
            ..Default::default()
        };
        // Double-buffer swap: one batch carries the whole burst (deeper
        // than max_batch — the cap only gates the straggler window).
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 40);
        assert_eq!(batch, (0..40).collect::<Vec<u32>>());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn recycled_buffer_is_reused() {
        let b = Batcher::new();
        let policy = BatchPolicy {
            max_batch: 8,
            window: Duration::ZERO,
            ..Default::default()
        };
        // The two buffers ping-pong: the recycled batch becomes the next
        // fill buffer, so the buffer returned on cycle 3 is the same
        // allocation as cycle 1's.
        b.submit(1);
        let batch1 = b.next_batch(&policy).unwrap();
        let ptr1 = batch1.as_ptr();
        b.recycle(batch1);
        b.submit(2);
        let batch2 = b.next_batch(&policy).unwrap();
        assert_eq!(batch2, vec![2]);
        b.recycle(batch2);
        b.submit(3);
        let batch3 = b.next_batch(&policy).unwrap();
        assert_eq!(batch3, vec![3]);
        assert_eq!(batch3.as_ptr(), ptr1, "double buffer must ping-pong");
    }

    #[test]
    fn clients_fill_next_batch_while_drain_outstanding() {
        let b = Batcher::new();
        let policy = BatchPolicy {
            max_batch: 4,
            window: Duration::ZERO,
            ..Default::default()
        };
        b.submit(10);
        let draining = b.next_batch(&policy).unwrap();
        assert_eq!(draining, vec![10]);
        // While the worker "dispatches" `draining`, new submits land in
        // the other buffer immediately.
        assert!(b.submit(11));
        assert!(b.submit(12));
        assert_eq!(b.pending(), 2);
        let next = b.next_batch(&policy).unwrap();
        assert_eq!(next, vec![11, 12]);
        b.recycle(draining);
        b.recycle(next);
    }

    #[test]
    fn window_gathers_stragglers() {
        let b = Arc::new(Batcher::new());
        b.submit(1);
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            b2.submit(2);
        });
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::from_millis(50),
            ..Default::default()
        };
        let batch = b.next_batch(&policy).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler should join the open batch");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new();
        b.submit(1);
        b.stop();
        let policy = BatchPolicy::default();
        assert_eq!(b.next_batch(&policy).unwrap().len(), 1);
        assert!(b.next_batch(&policy).is_none());
    }

    #[test]
    fn submit_after_stop_rejected() {
        let b = Batcher::new();
        b.stop();
        assert!(!b.submit(1));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn restart_rearms_a_stopped_batcher() {
        let b = Batcher::new();
        b.stop();
        assert!(!b.submit(1));
        b.restart();
        assert!(b.submit(2), "restarted batcher must accept work again");
        let policy = BatchPolicy {
            max_batch: 8,
            window: Duration::ZERO,
            ..Default::default()
        };
        assert_eq!(b.next_batch(&policy).unwrap(), vec![2]);
    }

    /// The lost-notification regression: a waiter blocked in phase 1 must
    /// be woken by a concurrent submit well before the seed's 5 ms poll
    /// interval would have noticed it.
    #[test]
    fn concurrent_submit_wakes_phase1_waiter() {
        let b = Arc::new(Batcher::new());
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let policy = BatchPolicy {
                max_batch: 1,
                window: Duration::ZERO,
                ..Default::default()
            };
            let t0 = Instant::now();
            let batch = b2.next_batch(&policy).unwrap();
            (batch.len(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(b.submit(7));
        let (len, waited) = t.join().unwrap();
        assert_eq!(len, 1);
        assert!(
            waited < Duration::from_secs(2),
            "phase-1 wait did not wake promptly ({waited:?})"
        );
    }

    /// While the worker is off dispatching (post-swap), submits land
    /// silently — the doorbell parks at `u32::MAX` until the worker
    /// re-enters `next_batch`.
    #[test]
    fn doorbell_parks_while_worker_dispatches() {
        let b = Batcher::new();
        let policy = BatchPolicy {
            max_batch: 8,
            window: Duration::ZERO,
            ..Default::default()
        };
        b.submit(1);
        let draining = b.next_batch(&policy).unwrap();
        let (_, s0) = b.doorbells();
        // The worker is "dispatching" `draining`: these submits must
        // not ring (nobody is listening).
        b.submit(2);
        b.submit(3);
        let (_, s1) = b.doorbells();
        assert_eq!(s1 - s0, 2, "mid-dispatch submits must stay silent");
        // ...and the worker still picks them up on its next pass.
        assert_eq!(b.next_batch(&policy).unwrap(), vec![2, 3]);
        b.recycle(draining);
    }

    /// A submit that fills the batch to `max_batch` must ring through
    /// the phase-2 watermark and close the straggler window early —
    /// well before the (deliberately huge) window expires.
    #[test]
    fn batch_filling_submit_rings_the_phase2_doorbell() {
        let b = Arc::new(Batcher::new());
        let b2 = b.clone();
        let worker = std::thread::spawn(move || {
            let policy = BatchPolicy {
                max_batch: 4,
                window: Duration::from_secs(5),
                ..Default::default()
            };
            let t0 = Instant::now();
            let batch = b2.next_batch(&policy).unwrap();
            (batch.len(), t0.elapsed())
        });
        // Give the worker time to park, then feed a full batch.
        std::thread::sleep(Duration::from_millis(20));
        for i in 0..4 {
            assert!(b.submit(i));
        }
        let (len, waited) = worker.join().unwrap();
        assert_eq!(len, 4);
        assert!(
            waited < Duration::from_secs(4),
            "the max_batch-th submit must close the window early \
             ({waited:?})"
        );
    }

    #[test]
    fn eager_batcher_rings_every_submit() {
        let b = Batcher::with_notify(true);
        for i in 0..3 {
            assert!(b.submit(i));
        }
        let (delivered, suppressed) = b.doorbells();
        assert_eq!(delivered, 3);
        assert_eq!(suppressed, 0);
    }

    #[test]
    fn default_policy_is_sharded() {
        let p = BatchPolicy::default();
        assert_eq!(p.lanes, NUM_QUEUES);
        assert_eq!(BatchPolicy::single_lane().lanes, 1);
        assert!(p.ring_slots >= p.max_batch);
    }
}
