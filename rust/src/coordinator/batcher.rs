//! Warp-shaped request batching.
//!
//! The TPU-stack analogue of the warp-vote cooperation the paper wrestles
//! with (DESIGN.md §4c): concurrent allocation requests arriving at the
//! coordinator are coalesced into warp-width batches before being issued
//! to the device, so one warp-collective `warp_malloc` serves the whole
//! group — exactly the amortisation `__activemask()` voting achieves
//! inside a CUDA kernel.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ouroboros::AllocError;

/// One queued request.
pub enum Op {
    Alloc {
        size: u32,
        reply: std::sync::mpsc::Sender<Result<u32, AllocError>>,
    },
    Free {
        addr: u32,
        reply: std::sync::mpsc::Sender<Result<(), AllocError>>,
    },
}

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Maximum ops per batch; default = warp width.
    pub max_batch: usize,
    /// How long to hold an underfull batch open for stragglers.
    pub window: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, window: Duration::from_micros(200) }
    }
}

#[derive(Default)]
pub struct Batcher {
    queue: Mutex<VecDeque<Op>>,
    cv: Condvar,
    pub shutdown: AtomicBool,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn submit(&self, op: Op) {
        self.queue.lock().unwrap().push_back(op);
        self.cv.notify_one();
    }

    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cv.notify_all();
    }

    /// Block for the next batch: wait for the first op, then hold the
    /// batch open up to `policy.window` (or until full). Returns `None`
    /// on shutdown with an empty queue.
    pub fn next_batch(&self, policy: &BatchPolicy) -> Option<Vec<Op>> {
        let mut q = self.queue.lock().unwrap();
        // Phase 1: wait for any work.
        loop {
            if !q.is_empty() {
                break;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(q, Duration::from_millis(5))
                .unwrap();
            q = guard;
        }
        // Phase 2: hold the window open for stragglers — but close early
        // if a sub-window wait brings no growth (otherwise an idle
        // single client pays the full window on every op; see
        // EXPERIMENTS.md §Perf L3 iteration 3).
        let deadline = Instant::now() + policy.window;
        let probe = (policy.window / 4).max(Duration::from_micros(10));
        while q.len() < policy.max_batch
            && !self.shutdown.load(Ordering::Acquire)
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let before = q.len();
            let wait = probe.min(deadline - now);
            let (guard, _) = self.cv.wait_timeout(q, wait).unwrap();
            q = guard;
            if q.len() == before {
                break; // idle: no stragglers coming
            }
        }
        let take = q.len().min(policy.max_batch);
        Some(q.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn alloc_op(size: u32) -> (Op, std::sync::mpsc::Receiver<Result<u32, AllocError>>) {
        let (tx, rx) = channel();
        (Op::Alloc { size, reply: tx }, rx)
    }

    #[test]
    fn collects_up_to_max_batch() {
        let b = Batcher::new();
        for i in 0..40 {
            b.submit(alloc_op(i + 1).0);
        }
        let policy = BatchPolicy { max_batch: 32, window: Duration::ZERO };
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 32);
        assert_eq!(b.pending(), 8);
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 8);
    }

    #[test]
    fn window_gathers_stragglers() {
        let b = Arc::new(Batcher::new());
        b.submit(alloc_op(1).0);
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            b2.submit(alloc_op(2).0);
        });
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::from_millis(50),
        };
        let batch = b.next_batch(&policy).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler should join the open batch");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new();
        b.submit(alloc_op(1).0);
        b.stop();
        let policy = BatchPolicy::default();
        assert_eq!(b.next_batch(&policy).unwrap().len(), 1);
        assert!(b.next_batch(&policy).is_none());
    }
}
