//! Warp-shaped request batching.
//!
//! The TPU-stack analogue of the warp-vote cooperation the paper wrestles
//! with (DESIGN.md §4c): concurrent allocation requests arriving at the
//! coordinator are coalesced into warp-width batches before being issued
//! to the device, so one warp-collective bulk queue operation serves the
//! whole group — exactly the amortisation `__activemask()` voting
//! achieves inside a CUDA kernel. The sharded [`super::service`] runs one
//! `Batcher` per request lane.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ouroboros::params::NUM_QUEUES;
use crate::ouroboros::AllocError;

/// One queued request.
pub enum Op {
    Alloc {
        size: u32,
        reply: std::sync::mpsc::Sender<Result<u32, AllocError>>,
    },
    Free {
        addr: u32,
        reply: std::sync::mpsc::Sender<Result<(), AllocError>>,
    },
}

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Maximum ops per batch; default = warp width.
    pub max_batch: usize,
    /// How long to hold an underfull batch open for stragglers.
    pub window: Duration,
    /// Independent request lanes the service shards into (size class `q`
    /// maps to lane `q * lanes / NUM_QUEUES`). 1 = the seed's
    /// single-batcher *topology* (dispatch still uses the new bulk
    /// paths), kept as the benchmark baseline for the sharding effect.
    pub lanes: usize,
    /// Device worker threads dispatching each lane's batches.
    pub workers_per_lane: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            window: Duration::from_micros(200),
            lanes: NUM_QUEUES,
            workers_per_lane: 1,
        }
    }
}

impl BatchPolicy {
    /// The pre-sharding topology: one lane, one worker (bulk dispatch
    /// included — see the `lanes` field docs).
    pub fn single_lane() -> Self {
        BatchPolicy { lanes: 1, ..BatchPolicy::default() }
    }
}

#[derive(Default)]
pub struct Batcher {
    queue: Mutex<VecDeque<Op>>,
    cv: Condvar,
    pub shutdown: AtomicBool,
}

impl Batcher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Queue `op` for the next batch. Returns `false` — with the op
    /// dropped — once the batcher has shut down, so callers can surface
    /// `ServiceDown` instead of waiting on a reply that never comes. The
    /// shutdown check happens under the queue lock: an accepted op is
    /// always visible to the worker's final drain.
    pub fn submit(&self, op: Op) -> bool {
        let mut q = self.queue.lock().unwrap();
        if self.shutdown.load(Ordering::Acquire) {
            return false;
        }
        q.push_back(op);
        drop(q);
        // notify_all, not notify_one: with several workers parked on the
        // same condvar (phase-1 and phase-2 waits share it), a single
        // token could wake only a straggler-window waiter and strand the
        // op until its timeout.
        self.cv.notify_all();
        true
    }

    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Release);
        // Lock barrier: any submit that raced past its shutdown check has
        // published its op before this; later submits see the flag.
        drop(self.queue.lock().unwrap());
        self.cv.notify_all();
    }

    /// Block for the next batch: wait for the first op, then hold the
    /// batch open up to `policy.window` (or until full). Returns `None`
    /// on shutdown with an empty queue.
    pub fn next_batch(&self, policy: &BatchPolicy) -> Option<Vec<Op>> {
        let mut q = self.queue.lock().unwrap();
        // Phase 1: wait for any work. A plain condvar wait with the
        // predicate re-checked under the lock — `submit` publishes the op
        // and notifies while holding/after the same lock, so a request
        // submitted concurrently with this wait is picked up immediately
        // (no timeout poll; the seed's 5 ms `wait_timeout` workaround hid
        // a lost-notification bug and cost worst-case 5 ms latency).
        loop {
            if !q.is_empty() {
                break;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
        // Phase 2: hold the window open for stragglers — but close early
        // if a sub-window wait brings no growth (otherwise an idle
        // single client pays the full window on every op; see
        // EXPERIMENTS.md §Perf L3 iteration 3).
        let deadline = Instant::now() + policy.window;
        let probe = (policy.window / 4).max(Duration::from_micros(10));
        while q.len() < policy.max_batch
            && !self.shutdown.load(Ordering::Acquire)
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let before = q.len();
            let wait = probe.min(deadline - now);
            let (guard, _) = self.cv.wait_timeout(q, wait).unwrap();
            q = guard;
            if q.len() == before {
                break; // idle: no stragglers coming
            }
        }
        let take = q.len().min(policy.max_batch);
        Some(q.drain(..take).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn alloc_op(size: u32) -> (Op, std::sync::mpsc::Receiver<Result<u32, AllocError>>) {
        let (tx, rx) = channel();
        (Op::Alloc { size, reply: tx }, rx)
    }

    #[test]
    fn collects_up_to_max_batch() {
        let b = Batcher::new();
        for i in 0..40 {
            assert!(b.submit(alloc_op(i + 1).0));
        }
        let policy = BatchPolicy { max_batch: 32, window: Duration::ZERO, ..Default::default() };
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 32);
        assert_eq!(b.pending(), 8);
        let batch = b.next_batch(&policy).unwrap();
        assert_eq!(batch.len(), 8);
    }

    #[test]
    fn window_gathers_stragglers() {
        let b = Arc::new(Batcher::new());
        b.submit(alloc_op(1).0);
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(2));
            b2.submit(alloc_op(2).0);
        });
        let policy = BatchPolicy {
            max_batch: 32,
            window: Duration::from_millis(50),
            ..Default::default()
        };
        let batch = b.next_batch(&policy).unwrap();
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler should join the open batch");
    }

    #[test]
    fn shutdown_drains_then_none() {
        let b = Batcher::new();
        b.submit(alloc_op(1).0);
        b.stop();
        let policy = BatchPolicy::default();
        assert_eq!(b.next_batch(&policy).unwrap().len(), 1);
        assert!(b.next_batch(&policy).is_none());
    }

    #[test]
    fn submit_after_stop_rejected() {
        let b = Batcher::new();
        b.stop();
        assert!(!b.submit(alloc_op(1).0));
        assert_eq!(b.pending(), 0);
    }

    /// The lost-notification regression: a waiter blocked in phase 1 must
    /// be woken by a concurrent submit well before the seed's 5 ms poll
    /// interval would have noticed it.
    #[test]
    fn concurrent_submit_wakes_phase1_waiter() {
        let b = Arc::new(Batcher::new());
        let b2 = b.clone();
        let t = std::thread::spawn(move || {
            let policy = BatchPolicy {
                max_batch: 1,
                window: Duration::ZERO,
                ..Default::default()
            };
            let t0 = Instant::now();
            let batch = b2.next_batch(&policy).unwrap();
            (batch.len(), t0.elapsed())
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(b.submit(alloc_op(7).0));
        let (len, waited) = t.join().unwrap();
        assert_eq!(len, 1);
        assert!(
            waited < Duration::from_secs(2),
            "phase-1 wait did not wake promptly ({waited:?})"
        );
    }

    #[test]
    fn default_policy_is_sharded() {
        let p = BatchPolicy::default();
        assert_eq!(p.lanes, NUM_QUEUES);
        assert_eq!(BatchPolicy::single_lane().lanes, 1);
    }
}
