//! Self-contained utilities replacing crates unavailable in the offline
//! image (rand, clap, criterion, proptest).

pub mod bench;
pub mod cli;
pub mod prop;
pub mod rng;
