//! Self-contained utilities replacing crates unavailable in the offline
//! image (rand, clap, criterion, proptest, anyhow, thiserror).

pub mod bench;
pub mod cli;
pub mod errs;
pub mod prop;
pub mod rng;
