//! Minimal `anyhow`-compatible error plumbing.
//!
//! The offline image ships no crate registry, so the `anyhow` crate the
//! coordinator/harness layers want is replaced by this self-contained
//! equivalent: a string-backed [`Error`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Call sites use
//! `use crate::util::errs as anyhow;` (or import items directly) so the
//! code reads exactly like the real thing and can swap back if the crate
//! ever becomes available.

use std::fmt;

/// A boxed-string error with accumulated context, printed as
/// `outermost context: ...: root cause`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like anyhow: any std error converts implicitly (Error itself does not
// implement std::error::Error, which keeps this blanket impl coherent).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context` equivalent for results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::errs::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::errs::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::errs::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

// Make the macros reachable through this module path too, so an alias
// like `use ouroboros_tpu::util::errs as anyhow;` gives call sites the
// familiar `anyhow::ensure!(..)` spelling.
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broken {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broken 42");
        assert_eq!(format!("{e:?}"), "broken 42");
    }

    #[test]
    fn anyhow_macro_forms() {
        let x = 7;
        assert_eq!(anyhow!("inline {x}").to_string(), "inline 7");
        assert_eq!(anyhow!("fmt {}", 3).to_string(), "fmt 3");
        let s = String::from("owned");
        assert_eq!(anyhow!(s).to_string(), "owned");
    }

    #[test]
    fn ensure_forms() {
        fn check(v: u32) -> Result<()> {
            ensure!(v < 10);
            ensure!(v != 3, "three is right out (got {v})");
            Ok(())
        }
        assert!(check(2).is_ok());
        assert!(check(3).unwrap_err().to_string().contains("three"));
        assert!(check(11).unwrap_err().to_string().contains("v < 10"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let o2: Option<u32> = Some(5);
        assert_eq!(o2.with_context(|| "unused").unwrap(), 5);
    }

    #[test]
    fn std_errors_convert() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/nonexistent/errs/test")?;
            Ok(s)
        }
        assert!(io().is_err());
    }
}
