//! Minimal deterministic PRNG (xorshift64* / splitmix64).
//!
//! The offline crate set has no `rand`; everything stochastic in the
//! simulator, the property tests and the workload generators goes through
//! this module so runs are reproducible from a single seed.

/// splitmix64 step — used to seed and to decorrelate streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xorshift64* generator; cheap, passes BigCrush small-set, plenty for
/// workload generation and property shrinking.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        // splitmix the seed so close seeds give decorrelated streams.
        let state = splitmix64(&mut s) | 1;
        Rng { state }
    }

    /// Derive an independent stream (for per-thread/per-warp rngs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        let mut s = self.next_u64() ^ salt.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut s))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; n must be > 0. Lemire-style rejection-free
    /// multiply-shift (bias < 2^-32, irrelevant at our sample counts).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive_endpoints_reachable() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.range(3, 6) {
                3 => lo_seen = true,
                6 => hi_seen = true,
                v => assert!((3..=6).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn forked_streams_decorrelated() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let overlap = (0..64)
            .filter(|_| a.next_u32() == b.next_u32())
            .count();
        assert!(overlap < 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
