//! Tiny argument parser (no clap in the offline image).
//!
//! Supports `command --key value --flag` style invocations, `--key=value`,
//! and typed accessors with defaults. Unknown-flag detection is the
//! caller's job via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got `{v}`")
                })
            })
            .unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.u64_or(name, default as u64) as usize
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a float, got `{v}`")
                })
            })
            .unwrap_or(default)
    }

    /// List of comma-separated values.
    pub fn list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    /// Error out on options/flags the command never consulted (typo guard).
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown arguments: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_positional_options_flags() {
        let a = args("figures --fig 2 --backend cuda --verbose");
        assert_eq!(a.positional(0), Some("figures"));
        assert_eq!(a.get("fig"), Some("2"));
        assert_eq!(a.get("backend"), Some("cuda"));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = args("run --threads=1024");
        assert_eq!(a.u64_or("threads", 1), 1024);
    }

    #[test]
    fn typed_defaults() {
        let a = args("run");
        assert_eq!(a.u64_or("iters", 10), 10);
        assert_eq!(a.f64_or("scale", 1.5), 1.5);
        assert_eq!(a.get_or("out", "results"), "results");
    }

    #[test]
    fn list_values() {
        let a = args("x --backends cuda,sycl,acpp");
        assert_eq!(
            a.list("backends").unwrap(),
            vec!["cuda".to_string(), "sycl".into(), "acpp".into()]
        );
        let b = args("x --backends cuda,,sycl,");
        assert_eq!(
            b.list("backends").unwrap(),
            vec!["cuda".to_string(), "sycl".into()]
        );
    }

    #[test]
    fn finish_flags_unknown() {
        let a = args("x --known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.finish().is_err());
    }

    #[test]
    fn trailing_flag_not_eating_next_option() {
        let a = args("x --verbose --n 3");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.u64_or("n", 0), 3);
    }
}
