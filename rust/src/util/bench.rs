//! Hand-rolled benchmark harness (no criterion in the offline image).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations, robust summary statistics (median / mean / p10 / p90),
//! and a stable one-line report format the figure harness and
//! EXPERIMENTS.md both consume.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_unstable();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
        BenchStats {
            iters: n,
            mean: sum / n as u32,
            median: pct(0.5),
            p10: pct(0.1),
            p90: pct(0.9),
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn run<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let samples = (0..iters.max(1))
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed()
        })
        .collect();
    BenchStats::from_samples(samples)
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// One-line, grep-stable report: `bench <name> median=.. mean=.. p90=..`.
pub fn report(name: &str, s: &BenchStats) {
    println!(
        "bench {name} iters={} median={} mean={} p10={} p90={} min={} max={}",
        s.iters,
        fmt_dur(s.median),
        fmt_dur(s.mean),
        fmt_dur(s.p10),
        fmt_dur(s.p90),
        fmt_dur(s.min),
        fmt_dur(s.max),
    );
}

/// Convenience wrapper used by the `benches/` targets.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, f: impl FnMut() -> R) {
    let stats = run(warmup, iters, f);
    report(name, &stats);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_invariants() {
        let s = BenchStats::from_samples(
            (1..=100).map(Duration::from_micros).collect(),
        );
        assert!(s.min <= s.p10);
        assert!(s.p10 <= s.median);
        assert!(s.median <= s.p90);
        assert!(s.p90 <= s.max);
        assert_eq!(s.iters, 100);
    }

    #[test]
    fn run_counts_iterations() {
        let mut n = 0usize;
        let s = run(2, 5, || n += 1);
        assert_eq!(n, 7); // warmup + timed
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn fmt_scales() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12ns");
        assert!(fmt_dur(Duration::from_micros(12)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
