//! Hand-rolled property-testing harness (no proptest in the offline image).
//!
//! A property is a closure over a [`Gen`] case generator; the harness runs
//! it for `cases` seeds and, on failure, retries the failing seed with
//! progressively "smaller" generator budgets to report a reduced case.
//! Seeds are deterministic but overridable via `OURO_PROP_SEED`, and case
//! counts via `OURO_PROP_CASES`, so CI failures are reproducible locally.

use super::rng::Rng;

/// Per-case generation context handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size budget in [0,1]: shrink passes rerun with smaller budgets so
    /// `sized_*` helpers produce smaller structures.
    budget: f64,
    pub case_index: usize,
    pub seed: u64,
}

impl Gen {
    #[inline]
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in [lo, hi], scaled toward lo when the budget shrinks.
    pub fn sized_range(&mut self, lo: u64, hi: u64) -> u64 {
        let span = ((hi - lo) as f64 * self.budget).ceil() as u64;
        self.rng.range(lo, lo + span.min(hi - lo))
    }

    /// Vec of `len` in [min_len, max_len] (budget-scaled) via `f`.
    pub fn vec<T>(&mut self, min_len: usize, max_len: usize,
                  mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.sized_range(min_len as u64, max_len as u64) as usize;
        (0..len).map(|_| f(self)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len() as u64) as usize;
        &xs[i]
    }
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok()?.parse().ok()
}

/// Run `prop` for the configured number of cases; panic with the seed and
/// a shrink report on the first failure.
pub fn check(name: &str, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let cases = env_u64("OURO_PROP_CASES").unwrap_or(64) as usize;
    let base_seed = env_u64("OURO_PROP_SEED").unwrap_or(0xC0FFEE);

    for case_index in 0..cases {
        let seed = base_seed
            .wrapping_add(case_index as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), budget: 1.0, case_index, seed };
        if let Err(msg) = prop(&mut g) {
            // Shrink: rerun the same seed with smaller budgets; the last
            // failing budget gives the smallest reproducible case.
            let mut best = (1.0, msg);
            for &b in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let mut g =
                    Gen { rng: Rng::new(seed), budget: b, case_index, seed };
                if let Err(m) = prop(&mut g) {
                    best = (b, m);
                }
            }
            panic!(
                "property `{name}` failed: {}\n  case {case_index}, \
                 seed {seed:#x}, smallest failing budget {}\n  reproduce \
                 with OURO_PROP_SEED={base_seed} OURO_PROP_CASES={cases}",
                best.1, best.0
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", |g| {
            let a = g.rng().next_u32() as u64;
            let b = g.rng().next_u32() as u64;
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_reports_seed() {
        check("always_fails", |_| Err("nope".into()));
    }

    #[test]
    fn sized_range_respects_bounds() {
        check("sized_range_bounds", |g| {
            let v = g.sized_range(10, 20);
            prop_assert!((10..=20).contains(&v), "out of range: {v}");
            Ok(())
        });
    }

    #[test]
    fn vec_len_within_bounds() {
        check("vec_len", |g| {
            let v = g.vec(2, 9, |g| g.bool());
            prop_assert!((2..=9).contains(&v.len()), "len {}", v.len());
            Ok(())
        });
    }
}
