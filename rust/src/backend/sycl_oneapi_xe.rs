//! Ouroboros-SYCL compiled by Intel oneAPI for the Iris Xe iGPU (the
//! paper's Asus NUC 13 datapoint, its cross-platform claim).
//!
//! Same SYCL semantics as the NVIDIA target, but the native SPIR-V
//! consumption path avoids the PTX translation penalty on atomics
//! (overhead ~1.15). Run this backend on `DeviceProfile::iris_xe()`
//! (subgroup width 16, fewer/wider EUs, lower clock) — the harness pairs
//! them automatically.

use super::{Backend, BackoffPolicy, CostTable, VotePolicy};

pub struct SyclOneapiXe {
    costs: CostTable,
}

impl SyclOneapiXe {
    pub fn new() -> Self {
        let costs = CostTable {
            atomic_overhead: 1.15,
            // iGPU: LP-DDR memory path, slower atomic unit.
            atomic: 42.0,
            atomic_service: 10.0,
            mem: 18.0,
            hot_read_stall: 26.0,
            contention_eta: 3.4,
            jit_warmup_us: 24_000.0,
            ..CostTable::baseline()
        };
        SyclOneapiXe { costs }
    }
}

impl Default for SyclOneapiXe {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SyclOneapiXe {
    fn id(&self) -> &'static str {
        "sycl-xe"
    }

    fn label(&self) -> &'static str {
        "oneAPI SYCL (Iris Xe)"
    }

    fn costs(&self) -> &CostTable {
        &self.costs
    }

    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::ConvergedOnly
    }

    fn backoff_policy(&self) -> BackoffPolicy {
        BackoffPolicy::Fence
    }

    fn warp_coalesced(&self) -> bool {
        false
    }
}
