//! Ouroboros-SYCL compiled by AdaptiveCpp (acpp, ex-HipSYCL) targeting
//! CUDA PTX.
//!
//! The paper's §2 shows an active-mask *emulation loop* that "runs as
//! expected" on Intel GPUs and CPUs but **deadlocks on an NVIDIA GPU ...
//! unless all threads in the subgroup are active", and §4 notes the acpp
//! build "would struggle as the number of threads increased, with loops
//! timing out or becoming deadlocked". [`VotePolicy::EmulatedMaskDeadlock`]
//! reproduces exactly that: a subgroup sync issued from a divergent retry
//! path raises a deadlock event that the simulator watchdog converts into
//! the paper's timeouts.

use super::{Backend, BackoffPolicy, CostTable, VotePolicy};

pub struct Acpp {
    costs: CostTable,
}

impl Acpp {
    pub fn new() -> Self {
        let costs = CostTable {
            atomic_overhead: 1.4,
            contention_eta: 3.1,
            jit_warmup_us: 52_000.0,
            ..CostTable::baseline()
        };
        Acpp { costs }
    }
}

impl Default for Acpp {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for Acpp {
    fn id(&self) -> &'static str {
        "acpp"
    }

    fn label(&self) -> &'static str {
        "AdaptiveCpp (NVIDIA)"
    }

    fn costs(&self) -> &CostTable {
        &self.costs
    }

    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::EmulatedMaskDeadlock
    }

    fn backoff_policy(&self) -> BackoffPolicy {
        BackoffPolicy::Fence
    }

    fn warp_coalesced(&self) -> bool {
        false
    }
}
