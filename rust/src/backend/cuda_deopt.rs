//! The paper's "deoptimised" CUDA branch (§3 Methods): embedded PTX
//! replaced by high-level equivalents, `nanosleep` replaced by
//! `atomic_fence`, warp-vote coalescing replaced by the simplified code
//! used in the SYCL versions — the controlled ablation that isolates
//! toolchain codegen from programming-model features.
//!
//! Empirically the paper found this branch "if anything more performant"
//! than the optimised branch on the page allocator; nvcc optimises the
//! plain C++ slightly better than the hand-written PTX. We encode that as
//! a small discount on the atomic path.

use super::{Backend, BackoffPolicy, CostTable, VotePolicy};

pub struct CudaDeopt {
    costs: CostTable,
}

impl CudaDeopt {
    pub fn new() -> Self {
        let costs = CostTable {
            atomic_overhead: 0.95,
            ..CostTable::baseline()
        };
        CudaDeopt { costs }
    }
}

impl Default for CudaDeopt {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for CudaDeopt {
    fn id(&self) -> &'static str {
        "cuda-deopt"
    }

    fn label(&self) -> &'static str {
        "CUDA (deoptimised)"
    }

    fn costs(&self) -> &CostTable {
        &self.costs
    }

    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::ConvergedOnly
    }

    fn backoff_policy(&self) -> BackoffPolicy {
        BackoffPolicy::Fence
    }

    fn warp_coalesced(&self) -> bool {
        false
    }
}
