//! Ouroboros-SYCL compiled by Intel oneAPI (icpx -fsycl
//! -fsycl-targets=nvptx64-nvidia-cuda, Codeplay plugin), run on the same
//! NVIDIA device as the CUDA builds.
//!
//! Semantics per the paper: no masked votes (SYCL group ops require full
//! subgroup participation), `atomic_fence` instead of `nanosleep`, no
//! warp-coalesced queue path, and SPIR-V -> PTX JIT on first launch (the
//! reason the paper reports subsequent-iteration means). The ~2x atomic
//! overhead is the codegen axis that reproduces the paper's page-allocator
//! gap while leaving scan-dominated chunk allocators at ≈parity.

use super::{Backend, BackoffPolicy, CostTable, VotePolicy};

pub struct SyclOneapiNv {
    costs: CostTable,
}

impl SyclOneapiNv {
    pub fn new() -> Self {
        let costs = CostTable {
            atomic_overhead: 2.0,
            contention_eta: 2.9,
            jit_warmup_us: 38_000.0,
            ..CostTable::baseline()
        };
        SyclOneapiNv { costs }
    }
}

impl Default for SyclOneapiNv {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SyclOneapiNv {
    fn id(&self) -> &'static str {
        "sycl-nv"
    }

    fn label(&self) -> &'static str {
        "oneAPI SYCL (NVIDIA)"
    }

    fn costs(&self) -> &CostTable {
        &self.costs
    }

    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::ConvergedOnly
    }

    fn backoff_policy(&self) -> BackoffPolicy {
        BackoffPolicy::Fence
    }

    fn warp_coalesced(&self) -> bool {
        false
    }
}
