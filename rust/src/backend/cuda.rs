//! Optimised CUDA build (the paper's `cuda-ouroboros` branch): nvcc AOT,
//! inline-PTX fast paths, `__activemask()`-masked warp votes, `nanosleep`
//! backoff, warp-coalesced queue operations.

use super::{Backend, BackoffPolicy, CostTable, VotePolicy};

pub struct Cuda {
    costs: CostTable,
}

impl Cuda {
    pub fn new() -> Self {
        // Baseline is defined as this configuration.
        Cuda { costs: CostTable::baseline() }
    }
}

impl Default for Cuda {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for Cuda {
    fn id(&self) -> &'static str {
        "cuda"
    }

    fn label(&self) -> &'static str {
        "CUDA (optimised)"
    }

    fn costs(&self) -> &CostTable {
        &self.costs
    }

    fn vote_policy(&self) -> VotePolicy {
        VotePolicy::MaskedWarp
    }

    fn backoff_policy(&self) -> BackoffPolicy {
        BackoffPolicy::Nanosleep
    }

    fn warp_coalesced(&self) -> bool {
        true
    }
}
