//! Toolchain backend models.
//!
//! The paper compares the *same allocator algorithms* compiled by different
//! toolchains with different programming-model semantics. A [`Backend`]
//! captures exactly the axes the paper identifies (§2–§3):
//!
//! * **vote policy** — can subgroup/warp votes be masked by the active
//!   lane mask (`__activemask()`), must all lanes be converged (SYCL group
//!   ops), or does the paper's active-mask *emulation loop* run (which on
//!   AdaptiveCpp→NVIDIA deadlocks when lanes are divergent)?
//! * **backoff policy** — `nanosleep` throttling (CUDA sm_70+) vs
//!   `atomic_fence` (all SYCL can offer);
//! * **warp-coalesced queue ops** — the optimised CUDA build amortises
//!   queue-counter RMWs across a warp; the "deoptimised" CUDA branch and
//!   both SYCL builds use the simplified per-thread path;
//! * **cost table** — per-op cycle weights; the SYCL→PTX path pays an
//!   atomic-RMW overhead (SPIR-V → PTX JIT codegen), which is the
//!   mechanistic story consistent with the paper's data: page allocators
//!   (pure queue atomics) show ~2x, chunk allocators (scan-dominated)
//!   show ≈parity — see DESIGN.md §3;
//! * **JIT warm-up** — SPIR-V/PTX first-launch translation, reproduced as
//!   a first-iteration surcharge (the reason the paper reports mean-all
//!   and mean-subsequent separately).

mod acpp;
mod cuda;
mod cuda_deopt;
mod sycl_oneapi_nv;
mod sycl_oneapi_xe;

pub use acpp::Acpp;
pub use cuda::Cuda;
pub use cuda_deopt::CudaDeopt;
pub use sycl_oneapi_nv::SyclOneapiNv;
pub use sycl_oneapi_xe::SyclOneapiXe;

use std::sync::Arc;

/// How subgroup votes behave for divergent active masks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VotePolicy {
    /// CUDA `__ballot_sync(__activemask(), ..)`: masked votes are native.
    MaskedWarp,
    /// SYCL 2020 group ops: only well-defined when every lane of the
    /// subgroup participates; divergent paths must serialise via a
    /// leader-election side channel (extra cost, no deadlock).
    ConvergedOnly,
    /// The paper's §2 active-mask emulation loop: works on Intel/CPU, but
    /// deadlocks on NVIDIA when the subgroup is divergent (observed for
    /// AdaptiveCpp). The simulator's watchdog converts the deadlock into
    /// the timeouts the paper reports.
    EmulatedMaskDeadlock,
}

/// How a thread throttles itself when the allocator asks it to back off.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackoffPolicy {
    /// CUDA sm_70+ `nanosleep`: the warp leaves the hot path entirely.
    Nanosleep,
    /// SYCL: all that is available is an `atomic_fence` (paper §2).
    Fence,
}

/// Per-operation cycle weights. All weights are in *device cycles* of the
/// simulated GPU; the `DeviceProfile` clock converts cycles to time.
#[derive(Debug, Clone)]
pub struct CostTable {
    /// Plain ALU op.
    pub alu: f64,
    /// Global-memory access (amortised, coalesced).
    pub mem: f64,
    /// Atomic RMW on global memory (base latency, uncontended).
    pub atomic: f64,
    /// Multiplier on atomic/CAS ops — the toolchain codegen quality axis.
    pub atomic_overhead: f64,
    /// Device-wide *throughput* cost per RMW on the same hot word: the
    /// atomic unit retires one RMW per `atomic_service` cycles per
    /// address. This is the serialization resource that makes total
    /// alloc time grow with thread count (paper right panels).
    pub atomic_service: f64,
    /// Stall charged to a read of a write-hot cache line (bitmap scans
    /// of the front chunk, queue-list walks). A memory-system cost:
    /// identical across toolchains, which is why scan-dominated chunk
    /// allocators sit at parity while RMW-dominated page allocators show
    /// the codegen gap (paper §5).
    pub hot_read_stall: f64,
    /// Extra cycles for each failed CAS attempt.
    pub cas_retry: f64,
    /// Warp vote / subgroup group-op.
    pub vote: f64,
    /// Extra cycles when a ConvergedOnly backend must leader-elect around
    /// a divergent vote.
    pub leader_elect: f64,
    /// atomic_fence.
    pub fence: f64,
    /// nanosleep duration in nanoseconds (Nanosleep policy only).
    pub nanosleep_ns: f64,
    /// Extra cycles added to a hot-word RMW per concurrent contender.
    pub contention_eta: f64,
    /// First-launch JIT translation cost, microseconds.
    pub jit_warmup_us: f64,
    /// Watchdog limit used when a deadlock is detected, microseconds.
    pub watchdog_us: f64,
}

impl CostTable {
    /// Baseline table (optimised CUDA on the T2000); backends derive from
    /// this so relative differences stay in one place.
    pub fn baseline() -> Self {
        CostTable {
            alu: 1.0,
            mem: 12.0,
            atomic: 30.0,
            atomic_overhead: 1.0,
            atomic_service: 6.0,
            hot_read_stall: 18.0,
            cas_retry: 18.0,
            vote: 4.0,
            leader_elect: 40.0,
            fence: 24.0,
            nanosleep_ns: 80.0,
            contention_eta: 2.4,
            jit_warmup_us: 0.0,
            watchdog_us: 250_000.0,
        }
    }
}

/// A toolchain semantic + cost model. See module docs.
pub trait Backend: Send + Sync {
    /// Short stable id used in CLI flags, CSV columns and reports.
    fn id(&self) -> &'static str;
    /// Human-readable label matching the paper's series names.
    fn label(&self) -> &'static str;
    fn costs(&self) -> &CostTable;
    fn vote_policy(&self) -> VotePolicy;
    fn backoff_policy(&self) -> BackoffPolicy;
    /// Whether the allocator build uses warp-coalesced queue operations.
    fn warp_coalesced(&self) -> bool;
}

/// All backends the figure harness sweeps, in the paper's series order.
pub fn all_backends() -> Vec<Arc<dyn Backend>> {
    vec![
        Arc::new(Cuda::new()),
        Arc::new(CudaDeopt::new()),
        Arc::new(SyclOneapiNv::new()),
        Arc::new(Acpp::new()),
        Arc::new(SyclOneapiXe::new()),
    ]
}

/// Look up a backend by CLI id.
pub fn by_id(id: &str) -> Option<Arc<dyn Backend>> {
    all_backends().into_iter().find(|b| b.id() == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_unique_and_resolvable() {
        let all = all_backends();
        let mut ids: Vec<_> = all.iter().map(|b| b.id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), all.len());
        for b in &all {
            assert!(by_id(b.id()).is_some());
        }
        assert!(by_id("nonsense").is_none());
    }

    #[test]
    fn paper_semantics_encoded() {
        assert_eq!(Cuda::new().vote_policy(), VotePolicy::MaskedWarp);
        assert!(Cuda::new().warp_coalesced());
        assert_eq!(Cuda::new().backoff_policy(), BackoffPolicy::Nanosleep);

        assert!(!CudaDeopt::new().warp_coalesced());
        assert_eq!(CudaDeopt::new().backoff_policy(), BackoffPolicy::Fence);

        assert_eq!(SyclOneapiNv::new().vote_policy(), VotePolicy::ConvergedOnly);
        assert_eq!(
            Acpp::new().vote_policy(),
            VotePolicy::EmulatedMaskDeadlock
        );
    }

    #[test]
    fn sycl_pays_atomic_overhead_cuda_does_not() {
        assert!(SyclOneapiNv::new().costs().atomic_overhead > 1.5);
        assert!((Cuda::new().costs().atomic_overhead - 1.0).abs() < 1e-9);
        // The paper: deoptimised CUDA "if anything more performant".
        assert!(CudaDeopt::new().costs().atomic_overhead <= 1.0);
    }

    #[test]
    fn jit_backends_have_warmup() {
        assert_eq!(Cuda::new().costs().jit_warmup_us, 0.0);
        assert!(SyclOneapiNv::new().costs().jit_warmup_us > 0.0);
        assert!(Acpp::new().costs().jit_warmup_us > 0.0);
    }
}
