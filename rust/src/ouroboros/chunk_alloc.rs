//! The chunk-based allocator: "maintains queues of chunks that have free
//! pages, first obtaining a chunk index, then scanning the chunk for free
//! pages. It is a more complex algorithm, but queue sizes are smaller"
//! (paper §4.2).
//!
//! The allocator is a linked list of chunk queues, one per power-of-two
//! size class; resolving the class walks that list, which is the latency
//! growth with allocation size visible in the paper's Figure 2 (left) —
//! charged here per hop. Generic over the queue flavor for the standard
//! (Figure 2) and virtualized (Figures 5, 6) drivers.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::simt::DevCtx;

use super::chunk::STATE_OWNED;
use super::error::AllocError;
use super::heap::Heap;
use super::page_alloc::AllocCounters;
use super::params::NUM_QUEUES;
use super::queue::IdQueue;

/// Spin guard for the bulk path (mirrors `MALLOC_SPIN_LIMIT` on the
/// per-thread path — a correct run never gets near it).
const BULK_SPIN_LIMIT: u32 = 1_000_000;

pub struct ChunkAllocator<Q: IdQueue> {
    heap: Arc<Heap>,
    queues: Vec<Q>,
    /// The queue-list metadata line walked during size-class resolution.
    list_hot: crate::simt::HotSpot,
    pub counters: AllocCounters,
}

impl<Q: IdQueue> ChunkAllocator<Q> {
    pub fn from_parts(heap: Arc<Heap>, queues: Vec<Q>) -> Self {
        assert_eq!(queues.len(), NUM_QUEUES);
        ChunkAllocator {
            heap,
            queues,
            list_hot: crate::simt::HotSpot::with_ways(2),
            counters: AllocCounters::default(),
        }
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }

    pub fn queue(&self, q: usize) -> &Q {
        &self.queues[q]
    }

    /// Walk the linked list of chunk queues to the size class (paper Fig
    /// 2 left: "the effect of having to walk through this link list as
    /// the chunk size increases"). The list nodes are shared metadata
    /// lines — each hop pays a hot-line read stall.
    fn charge_list_walk(&self, ctx: &DevCtx, q: usize) {
        ctx.charge_hot_read(1 + q as u64, &self.list_hot);
    }

    /// Retire the exhausted (or stale) front entry: pop it; if the pop
    /// raced and returned a *different*, still-useful chunk, put that one
    /// back in rotation.
    fn retire_front(&self, ctx: &DevCtx, q: usize, expected: u32) {
        if let Some(got) = self.queues[q].try_dequeue(ctx) {
            if got != expected {
                let h = self.heap.header(got);
                if h.state() == STATE_OWNED
                    && h.queue() == q
                    && h.free_count() > 0
                {
                    let _ = self.queues[q].try_enqueue(ctx, got);
                }
            }
        }
    }

    /// One bounded malloc attempt: read the front chunk, scan its bitmap
    /// for a page, retire it when exhausted; grow when empty.
    pub fn step(&self, ctx: &DevCtx, q: usize) -> Result<Option<u32>, AllocError> {
        self.charge_list_walk(ctx, q);
        if let Some(chunk) = self.queues[q].peek(ctx) {
            let h = self.heap.header(chunk);
            // Entries can go stale after a sweep reclaimed the chunk.
            if h.state() != STATE_OWNED || h.queue() != q {
                // ordering: stat counter
                self.counters.stale_entries.fetch_add(1, Ordering::Relaxed);
                self.retire_front(ctx, q, chunk);
                return Ok(None);
            }
            return match h.reserve_page(ctx) {
                Some((page, left)) => {
                    if left == 0 {
                        // Took the last page: retire the front entry.
                        self.retire_front(ctx, q, chunk);
                    }
                    Ok(Some(Heap::addr_of(chunk, q, page)))
                }
                // Raced to full between peek and scan: retire + retry.
                None => {
                    self.retire_front(ctx, q, chunk);
                    Ok(None)
                }
            };
        }
        // Queue empty: grow by one chunk.
        let chunk = self.heap.alloc_chunk(ctx)?;
        self.counters.grows.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        let h = self.heap.header(chunk);
        h.init_for_queue(ctx, q);
        let (page, left) = h.reserve_page(ctx).expect("fresh chunk full");
        if left > 0 {
            self.queues[q].try_enqueue(ctx, chunk)?;
        }
        Ok(Some(Heap::addr_of(chunk, q, page)))
    }

    /// Coalesced malloc for a same-class group (the service's lane
    /// batches): the queue-list walk and the front-chunk peek are paid
    /// once per group, and the front chunk is drained with consecutive
    /// bitmap reservations instead of re-resolving the size class per
    /// lane — the warp-leader pattern of the optimised CUDA build.
    pub fn bulk_step(
        &self,
        ctx: &DevCtx,
        q: usize,
        n: u32,
        out: &mut Vec<u32>,
    ) -> Result<(), AllocError> {
        self.charge_list_walk(ctx, q);
        let mut spins = 0u32;
        while (out.len() as u32) < n {
            let mut progress = false;
            if let Some(chunk) = self.queues[q].peek(ctx) {
                let h = self.heap.header(chunk);
                if h.state() != STATE_OWNED || h.queue() != q {
                    // ordering: stat counter
                    self.counters.stale_entries.fetch_add(1, Ordering::Relaxed);
                    self.retire_front(ctx, q, chunk);
                } else {
                    // Drain the front chunk for the whole group.
                    while (out.len() as u32) < n {
                        match h.reserve_page(ctx) {
                            Some((page, left)) => {
                                progress = true;
                                out.push(Heap::addr_of(chunk, q, page));
                                if left == 0 {
                                    self.retire_front(ctx, q, chunk);
                                    break;
                                }
                            }
                            None => {
                                self.retire_front(ctx, q, chunk);
                                break;
                            }
                        }
                    }
                }
            } else {
                match self.heap.alloc_chunk(ctx) {
                    Ok(chunk) => {
                        // ordering: stat counter
                        self.counters.grows.fetch_add(1, Ordering::Relaxed);
                        let h = self.heap.header(chunk);
                        h.init_for_queue(ctx, q);
                        let mut has_space = true;
                        while (out.len() as u32) < n {
                            match h.reserve_page(ctx) {
                                Some((page, left)) => {
                                    progress = true;
                                    out.push(Heap::addr_of(chunk, q, page));
                                    if left == 0 {
                                        has_space = false;
                                        break;
                                    }
                                }
                                None => {
                                    has_space = false;
                                    break;
                                }
                            }
                        }
                        if has_space {
                            self.queues[q].try_enqueue(ctx, chunk)?;
                        }
                    }
                    Err(AllocError::OutOfMemory)
                        if !self.queues[q].is_empty() =>
                    {
                        // Lost a race: someone else grew or freed; retry.
                    }
                    Err(e) => return Err(e),
                }
            }
            if !progress {
                spins += 1;
                ctx.backoff(self.heap.hot(), (spins % 9).min(8));
                if spins > BULK_SPIN_LIMIT {
                    return Err(AllocError::QueueCorrupt);
                }
            }
        }
        Ok(())
    }

    pub fn free_addr(&self, ctx: &DevCtx, addr: u32) -> Result<(), AllocError> {
        let (chunk, page) = self.heap.check_addr(addr)?;
        let h = self.heap.header(chunk);
        let (was_set, before) = h.release_page(ctx, page);
        if !was_set {
            return Err(AllocError::InvalidFree(addr));
        }
        self.counters.frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        if before == 0 {
            // Full -> has-space edge: only this freeing lane re-enqueues,
            // so a chunk has at most one in-rotation entry per edge.
            self.queues[h.queue()].try_enqueue(ctx, chunk)?;
        }
        Ok(())
    }

    pub fn metadata_bytes(&self) -> u64 {
        self.queues.iter().map(|q| q.metadata_bytes()).sum()
    }

    /// Quiescent reclaim: fully-free chunks go back to the heap (the
    /// self-eating property); their queue entries are dropped lazily by
    /// the stale check in `step`. Returns chunks reclaimed.
    pub fn sweep(&self, ctx: &DevCtx) -> u32 {
        let mut reclaimed = 0;
        for c in 0..self.heap.num_chunks() {
            let h = self.heap.header(c);
            if h.is_fully_free() && h.cas_state(STATE_OWNED, STATE_OWNED) {
                // Quiescence contract: no concurrent malloc/free while
                // sweeping, so this transition is safe.
                self.heap.release_chunk(ctx, c);
                reclaimed += 1;
            }
        }
        reclaimed
    }
}
