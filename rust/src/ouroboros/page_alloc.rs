//! The page-based allocator: "pages of fixed size are allocated from a
//! queue. Total heap memory is divided amongst the queues, each queue
//! managing a different page size" (paper §4.1).
//!
//! Fast and simple — one dequeue per malloc, one enqueue per free — but
//! it never reclaims chunks (pages of a drained chunk are scattered
//! through the ring), the fragmentation weakness the paper notes.
//! Generic over the queue flavor: `PageAllocator<IndexQueue>` is the
//! standard driver, `PageAllocator<VaQueue>` / `PageAllocator<VlQueue>`
//! the virtualized ones (Figures 1, 3 and 4).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::simt::DevCtx;

use super::chunk::STATE_OWNED;
use super::error::AllocError;
use super::heap::Heap;
use super::params::{pages_per_chunk, MAX_PAGES_PER_CHUNK, NUM_QUEUES};
use super::queue::IdQueue;

/// Page id: `(chunk << PAGE_BITS) | page`.
const PAGE_BITS: u32 = MAX_PAGES_PER_CHUNK.trailing_zeros(); // 9

#[inline]
pub fn encode_pid(chunk: u32, page: u32) -> u32 {
    (chunk << PAGE_BITS) | page
}

#[inline]
pub fn decode_pid(pid: u32) -> (u32, u32) {
    (pid >> PAGE_BITS, pid & (MAX_PAGES_PER_CHUNK - 1))
}

/// Allocator-level counters.
#[derive(Debug, Default)]
pub struct AllocCounters {
    pub mallocs: AtomicU64,
    pub frees: AtomicU64,
    pub grows: AtomicU64,
    pub stale_entries: AtomicU64,
}

pub struct PageAllocator<Q: IdQueue> {
    heap: Arc<Heap>,
    queues: Vec<Q>,
    pub counters: AllocCounters,
}

impl<Q: IdQueue> PageAllocator<Q> {
    pub fn from_parts(heap: Arc<Heap>, queues: Vec<Q>) -> Self {
        assert_eq!(queues.len(), NUM_QUEUES);
        PageAllocator { heap, queues, counters: AllocCounters::default() }
    }

    pub fn heap(&self) -> &Arc<Heap> {
        &self.heap
    }

    pub fn queue(&self, q: usize) -> &Q {
        &self.queues[q]
    }

    /// Mark a dequeued page allocated in its chunk's bitmap. A set bit
    /// here means the queue yielded a page twice — queue corruption.
    fn mark_allocated(&self, ctx: &DevCtx, pid: u32) -> Result<u32, AllocError> {
        let (chunk, page) = decode_pid(pid);
        let h = self.heap.header(chunk);
        if !h.acquire_page(ctx, page) {
            return Err(AllocError::QueueCorrupt);
        }
        Ok(Heap::addr_of(chunk, h.queue(), page))
    }

    /// Split a fresh chunk: the caller keeps `take` pages, the rest go to
    /// the queue.
    fn grow(
        &self,
        ctx: &DevCtx,
        q: usize,
        take: u32,
        out: &mut Vec<u32>,
    ) -> Result<(), AllocError> {
        let chunk = self.heap.alloc_chunk(ctx)?;
        self.counters.grows.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        let h = self.heap.header(chunk);
        h.init_for_queue(ctx, q);
        let ppc = pages_per_chunk(q);
        let take = take.min(ppc);
        for p in 0..take {
            let (page, _) = h.reserve_page(ctx).expect("fresh chunk full");
            debug_assert_eq!(page, p);
            out.push(Heap::addr_of(chunk, q, page));
        }
        let rest: Vec<u32> = (take..ppc).map(|p| encode_pid(chunk, p)).collect();
        // The optimised CUDA build splits fresh chunks with one warp-
        // coalesced bulk enqueue; the deoptimised / SYCL builds use the
        // "simplified" per-page loop (paper §3).
        if ctx.backend().warp_coalesced() {
            self.queues[q].bulk_enqueue(ctx, &rest)
        } else {
            for pid in rest {
                self.queues[q].try_enqueue(ctx, pid)?;
            }
            Ok(())
        }
    }

    /// One bounded malloc attempt: dequeue, else grow.
    pub fn step(&self, ctx: &DevCtx, q: usize) -> Result<Option<u32>, AllocError> {
        if let Some(pid) = self.queues[q].try_dequeue(ctx) {
            return self.mark_allocated(ctx, pid).map(Some);
        }
        let mut one = Vec::with_capacity(1);
        match self.grow(ctx, q, 1, &mut one) {
            Ok(()) => Ok(one.pop()),
            Err(AllocError::OutOfMemory) if !self.queues[q].is_empty() => {
                // Lost a race: someone else grew or freed; retry.
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }

    /// Coalesced step: one bulk dequeue for the whole warp group; grow
    /// covers any shortfall directly (fresh pages bypass the queue).
    pub fn bulk_step(
        &self,
        ctx: &DevCtx,
        q: usize,
        n: u32,
        out: &mut Vec<u32>,
    ) -> Result<(), AllocError> {
        let mut pids = Vec::with_capacity(n as usize);
        self.queues[q].bulk_dequeue(ctx, n, &mut pids);
        for pid in pids {
            out.push(self.mark_allocated(ctx, pid)?);
        }
        while (out.len() as u32) < n {
            let missing = n - out.len() as u32;
            match self.grow(ctx, q, missing, out) {
                Ok(()) => {}
                Err(AllocError::OutOfMemory) if !self.queues[q].is_empty() => {
                    let mut more = Vec::new();
                    self.queues[q].bulk_dequeue(ctx, missing, &mut more);
                    for pid in more {
                        out.push(self.mark_allocated(ctx, pid)?);
                    }
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    pub fn free_addr(&self, ctx: &DevCtx, addr: u32) -> Result<(), AllocError> {
        let (chunk, page) = self.heap.check_addr(addr)?;
        let h = self.heap.header(chunk);
        let (was_set, _) = h.release_page(ctx, page);
        if !was_set {
            return Err(AllocError::InvalidFree(addr));
        }
        self.counters.frees.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
        let q = h.queue();
        self.queues[q].try_enqueue(ctx, encode_pid(chunk, page))
    }

    /// Coalesced free: release every page bit first, then return the
    /// freed page ids to each ring with a single admission + tail
    /// reservation per size class (`bulk_enqueue`) instead of one
    /// count/back RMW pair per page. The service's sharded lanes batch
    /// same-class frees, so the common case is exactly one bulk enqueue.
    pub fn bulk_free(
        &self,
        ctx: &DevCtx,
        addrs: &[u32],
    ) -> Vec<Result<(), AllocError>> {
        let mut results: Vec<Result<(), AllocError>> =
            Vec::with_capacity(addrs.len());
        // (queue, pid, index into results) for pages released in phase 1.
        let mut freed: Vec<(usize, u32, usize)> = Vec::with_capacity(addrs.len());
        for (i, &addr) in addrs.iter().enumerate() {
            match self.heap.check_addr(addr) {
                Ok((chunk, page)) => {
                    let h = self.heap.header(chunk);
                    let (was_set, _) = h.release_page(ctx, page);
                    if was_set {
                        // ordering: stat counter
                        self.counters.frees.fetch_add(1, Ordering::Relaxed);
                        freed.push((h.queue(), encode_pid(chunk, page), i));
                        results.push(Ok(()));
                    } else {
                        results.push(Err(AllocError::InvalidFree(addr)));
                    }
                }
                Err(e) => results.push(Err(e)),
            }
        }
        let mut group_q = usize::MAX;
        let mut pids: Vec<u32> = Vec::new();
        let mut idxs: Vec<usize> = Vec::new();
        let mut flush = |q: usize, pids: &mut Vec<u32>, idxs: &mut Vec<usize>| {
            if pids.is_empty() {
                return;
            }
            if self.queues[q].bulk_enqueue(ctx, pids).is_err() {
                // Bulk admission failed (ring full): fall back per page so
                // failures attribute to the right addresses.
                for (pid, &i) in pids.iter().zip(idxs.iter()) {
                    if let Err(e) = self.queues[q].try_enqueue(ctx, *pid) {
                        results[i] = Err(e);
                    }
                }
            }
            pids.clear();
            idxs.clear();
        };
        for (q, pid, i) in freed {
            if q != group_q {
                flush(group_q.min(NUM_QUEUES - 1), &mut pids, &mut idxs);
                group_q = q;
            }
            pids.push(pid);
            idxs.push(i);
        }
        flush(group_q.min(NUM_QUEUES - 1), &mut pids, &mut idxs);
        results
    }

    pub fn metadata_bytes(&self) -> u64 {
        self.queues.iter().map(|q| q.metadata_bytes()).sum()
    }

    /// Page allocators cannot reclaim chunks (their free pages are
    /// scattered through the ring) — the fragmentation cost the paper
    /// calls out for this variant.
    pub fn sweep(&self, _ctx: &DevCtx) -> u32 {
        0
    }

    /// Sanity check used by tests and the service: every owned chunk's
    /// free count is consistent with its bitmap (quiescent only).
    pub fn debug_consistent(&self) -> bool {
        (0..self.heap.num_chunks()).all(|c| {
            let h = self.heap.header(c);
            if h.state() != STATE_OWNED {
                return true;
            }
            let ppc = pages_per_chunk(h.queue());
            let used: u32 = h
                .snapshot_bitmap()
                .iter()
                .enumerate()
                .map(|(w, &word)| {
                    let lo = w as u32 * 32;
                    let valid = if lo + 32 <= ppc {
                        u32::MAX
                    } else if lo >= ppc {
                        0
                    } else {
                        (1u32 << (ppc - lo)) - 1
                    };
                    (word & valid).count_ones()
                })
                .sum();
            used + h.free_count() == ppc
        })
    }
}
