//! Allocator geometry — the size-class layout shared with the python
//! compile path (python/compile/params.py; cross-checked at runtime
//! against artifacts/manifest.txt by `runtime::artifact`).
//!
//! Ouroboros defaults: 8 KiB chunks, smallest page 16 B, one queue per
//! power-of-two page size. A request of `s` bytes is served from the
//! smallest page ≥ s; queue `i` serves pages of `SMALLEST_PAGE << i`.

/// Queue-0 page size, bytes.
pub const SMALLEST_PAGE: u32 = 16;
/// Number of size-class queues (pages 16 B .. 8 KiB).
pub const NUM_QUEUES: usize = 10;
/// Chunk size, bytes (== largest page).
pub const CHUNK_SIZE: u32 = SMALLEST_PAGE << (NUM_QUEUES - 1);
/// Upper bound of pages per chunk (queue 0).
pub const MAX_PAGES_PER_CHUNK: u32 = CHUNK_SIZE / SMALLEST_PAGE;
/// u32 words in a chunk occupancy bitmap.
pub const BITMAP_WORDS: usize = (MAX_PAGES_PER_CHUNK / 32) as usize;
/// u32 words of payload in a chunk.
pub const CHUNK_WORDS: usize = (CHUNK_SIZE / 4) as usize;

/// Page size served by queue `q`.
#[inline]
pub const fn page_size(q: usize) -> u32 {
    SMALLEST_PAGE << q
}

/// Pages a chunk yields when owned by queue `q`.
#[inline]
pub const fn pages_per_chunk(q: usize) -> u32 {
    CHUNK_SIZE / page_size(q)
}

/// Size-class queue serving a request of `size` bytes (host-side mirror
/// of the `size_to_queue` Pallas kernel). `None` if the request exceeds
/// the largest page.
#[inline]
pub fn queue_for_size(size: u32) -> Option<usize> {
    if size == 0 || size > CHUNK_SIZE {
        return None;
    }
    let q = (32 - (size - 1).leading_zeros()).saturating_sub(4) as usize;
    // size<=16 -> 0; 17..32 -> 1; ... 4097..8192 -> 9.
    Some(if size <= SMALLEST_PAGE { 0 } else { q })
}

/// Heap/runtime configuration for one allocator instance.
#[derive(Debug, Clone)]
pub struct HeapConfig {
    /// Chunks in the preallocated heap ("trivial change to reduce the
    /// total amount of heap space available" — paper §3; default 4096
    /// chunks = 32 MiB, scaled to this testbed).
    pub num_chunks: u32,
    /// Capacity (entries) of each *standard* index queue. Ouroboros
    /// sizes these worst-case: every chunk's pages could sit in one
    /// queue; the virtualized variants exist precisely to shrink this.
    pub queue_capacity: u32,
    /// Entries per virtual-queue segment (fits in one chunk minus the
    /// segment header words).
    pub seg_capacity: u32,
    /// Directory slots for the virtualized-array queue.
    pub va_dir_slots: u32,
    /// Whether to materialise page payloads in the simulated heap data
    /// region (the driver's write/verify phase; disable for pure
    /// queue-throughput measurements).
    pub materialise_data: bool,
}

impl Default for HeapConfig {
    fn default() -> Self {
        let num_chunks = 4096;
        HeapConfig {
            num_chunks,
            queue_capacity: num_chunks * MAX_PAGES_PER_CHUNK / 4,
            seg_capacity: (CHUNK_WORDS - SEG_HEADER_WORDS) as u32,
            va_dir_slots: 64,
            materialise_data: true,
        }
    }
}

impl HeapConfig {
    /// Small deterministic config for unit tests.
    pub fn test_small() -> Self {
        HeapConfig {
            num_chunks: 64,
            queue_capacity: 4096,
            seg_capacity: (CHUNK_WORDS - SEG_HEADER_WORDS) as u32,
            va_dir_slots: 16,
            materialise_data: true,
        }
    }

    pub fn heap_bytes(&self) -> u64 {
        self.num_chunks as u64 * CHUNK_SIZE as u64
    }
}

/// Words reserved at the head of a virtual-queue segment (next link +
/// reader fence word).
pub const SEG_HEADER_WORDS: usize = 2;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_python_manifest() {
        // Mirror of python/compile/params.py — guarded again at runtime.
        assert_eq!(SMALLEST_PAGE, 16);
        assert_eq!(NUM_QUEUES, 10);
        assert_eq!(CHUNK_SIZE, 8192);
        assert_eq!(MAX_PAGES_PER_CHUNK, 512);
        assert_eq!(BITMAP_WORDS, 16);
    }

    #[test]
    fn page_sizes_double() {
        for q in 0..NUM_QUEUES {
            assert_eq!(page_size(q), 16 << q);
        }
        assert_eq!(page_size(NUM_QUEUES - 1), CHUNK_SIZE);
    }

    #[test]
    fn pages_per_chunk_inverse() {
        for q in 0..NUM_QUEUES {
            assert_eq!(pages_per_chunk(q) * page_size(q), CHUNK_SIZE);
        }
        assert_eq!(pages_per_chunk(0), 512);
        assert_eq!(pages_per_chunk(NUM_QUEUES - 1), 1);
    }

    #[test]
    fn queue_for_size_boundaries() {
        assert_eq!(queue_for_size(0), None);
        assert_eq!(queue_for_size(1), Some(0));
        assert_eq!(queue_for_size(16), Some(0));
        assert_eq!(queue_for_size(17), Some(1));
        assert_eq!(queue_for_size(32), Some(1));
        assert_eq!(queue_for_size(33), Some(2));
        assert_eq!(queue_for_size(1000), Some(6)); // paper's 1000 B case
        assert_eq!(queue_for_size(1024), Some(6));
        assert_eq!(queue_for_size(1025), Some(7));
        assert_eq!(queue_for_size(8192), Some(9));
        assert_eq!(queue_for_size(8193), None);
    }

    #[test]
    fn queue_for_size_fits_and_is_minimal() {
        for s in 1..=CHUNK_SIZE {
            let q = queue_for_size(s).unwrap();
            assert!(page_size(q) >= s, "size {s} -> q{q}");
            if q > 0 {
                assert!(page_size(q - 1) < s, "size {s} -> q{q} not minimal");
            }
        }
    }

    #[test]
    fn default_config_sane() {
        let c = HeapConfig::default();
        assert!(c.heap_bytes() >= 32 << 20);
        assert!(c.seg_capacity as usize <= CHUNK_WORDS - SEG_HEADER_WORDS);
    }
}
