//! Allocator error taxonomy.

use thiserror::Error;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Error)]
pub enum AllocError {
    /// Heap exhausted (no free chunk and the size-class queue is empty).
    #[error("out of device heap memory")]
    OutOfMemory,
    /// Request exceeds the largest page (> CHUNK_SIZE).
    #[error("allocation size {0} exceeds largest page")]
    TooLarge(u32),
    /// Zero-byte request.
    #[error("zero-size allocation")]
    ZeroSize,
    /// `free` of an address that is not currently allocated (double free
    /// or wild pointer).
    #[error("invalid free of address {0:#x}")]
    InvalidFree(u32),
    /// Internal queue accounting failure — always a bug; surfaced rather
    /// than masked so tests catch it.
    #[error("queue accounting corrupted")]
    QueueCorrupt,
}
