//! Allocator error taxonomy (hand-rolled Display/Error impls — the
//! offline image has no `thiserror`).

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocError {
    /// Heap exhausted (no free chunk and the size-class queue is empty).
    OutOfMemory,
    /// Request exceeds the largest page (> CHUNK_SIZE).
    TooLarge(u32),
    /// Zero-byte request.
    ZeroSize,
    /// `free` of an address that is not currently allocated (double free
    /// or wild pointer).
    InvalidFree(u32),
    /// Internal queue accounting failure — always a bug; surfaced rather
    /// than masked so tests catch it.
    QueueCorrupt,
    /// The allocation service's worker threads are gone (service shut
    /// down or crashed). Distinct from [`AllocError::QueueCorrupt`] so a
    /// dead service is never misreported as heap corruption.
    ServiceDown,
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::OutOfMemory => write!(f, "out of device heap memory"),
            AllocError::TooLarge(s) => {
                write!(f, "allocation size {s} exceeds largest page")
            }
            AllocError::ZeroSize => write!(f, "zero-size allocation"),
            AllocError::InvalidFree(a) => {
                write!(f, "invalid free of address {a:#x}")
            }
            AllocError::QueueCorrupt => write!(f, "queue accounting corrupted"),
            AllocError::ServiceDown => {
                write!(f, "allocation service unavailable (worker gone)")
            }
        }
    }
}

impl std::error::Error for AllocError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_stable() {
        assert_eq!(
            AllocError::TooLarge(9000).to_string(),
            "allocation size 9000 exceeds largest page"
        );
        assert_eq!(
            AllocError::InvalidFree(0x10).to_string(),
            "invalid free of address 0x10"
        );
        assert!(AllocError::ServiceDown.to_string().contains("service"));
    }
}
