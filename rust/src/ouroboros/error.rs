//! Allocator error taxonomy (hand-rolled Display/Error impls — the
//! offline image has no `thiserror`).

use std::fmt;

use super::addr::GlobalAddr;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocError {
    /// Heap exhausted (no free chunk and the size-class queue is empty).
    OutOfMemory,
    /// Request exceeds the largest page (> CHUNK_SIZE).
    TooLarge(u32),
    /// Zero-byte request.
    ZeroSize,
    /// `free` of an address that is not currently allocated (double free
    /// or wild pointer). Carries the raw address as seen at the failing
    /// layer: device-local below the service, the device-tagged global
    /// encoding ([`GlobalAddr`]) at the service boundary.
    InvalidFree(u32),
    /// Internal queue accounting failure — always a bug; surfaced rather
    /// than masked so tests catch it.
    QueueCorrupt,
    /// The allocation service's worker threads are gone (service shut
    /// down or crashed). Distinct from [`AllocError::QueueCorrupt`] so a
    /// dead service is never misreported as heap corruption.
    ServiceDown,
    /// A [`crate::coordinator::ring::Ticket`] minted by a *different*
    /// allocation service instance was presented to this one. Always
    /// deterministic — a foreign ticket can never hang a waiter or alias
    /// another op's payload.
    ForeignTicket,
    /// The op targeted a device-group member that has been retired (or
    /// is being retired) via `AllocService::retire_device`. Emitted for
    /// the retiring member's in-flight tickets when its lanes drain, and
    /// for later submits that would land on the dead member — always
    /// deterministic, never a hang. The rest of the group keeps serving.
    DeviceRetired,
    /// `AllocService::readmit_device` refused to bring a member back:
    /// it is not retired (double readmit, readmit of a healthy member,
    /// or readmit while a drain is still running), or its heap still
    /// holds stranded live blocks — the member's address window can
    /// only be re-minted over a provably empty live set.
    ReadmitRefused,
    /// A durability snapshot (`coordinator/snapshot.rs`) failed to
    /// decode: truncated file, checksum mismatch, unsupported version,
    /// or a malformed record. Always deterministic — a corrupt snapshot
    /// is rejected wholesale, never partially applied as a silently
    /// empty forwarding table (which would turn every stale name into
    /// a lost block on restart).
    SnapshotCorrupt,
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::OutOfMemory => write!(f, "out of device heap memory"),
            AllocError::TooLarge(s) => {
                write!(f, "allocation size {s} exceeds largest page")
            }
            AllocError::ZeroSize => write!(f, "zero-size allocation"),
            AllocError::InvalidFree(a) => {
                // When the high bits carry a device tag (service-level
                // errors are minted with the GlobalAddr encoding; plain
                // device-local heaps never exceed the low window), show
                // the decode — marked as an interpretation, since a raw
                // device-layer address this wild is garbage either way.
                let g = GlobalAddr::from_raw(*a);
                if g.device() != 0 {
                    write!(
                        f,
                        "invalid free of address {a:#x} \
                         (device-tagged: device {} + offset {:#x})",
                        g.device(),
                        g.local()
                    )
                } else {
                    write!(f, "invalid free of address {a:#x}")
                }
            }
            AllocError::QueueCorrupt => write!(f, "queue accounting corrupted"),
            AllocError::ServiceDown => {
                write!(f, "allocation service unavailable (worker gone)")
            }
            AllocError::ForeignTicket => {
                write!(f, "ticket belongs to a different allocation service")
            }
            AllocError::DeviceRetired => {
                write!(f, "device-group member retired (drained and removed)")
            }
            AllocError::ReadmitRefused => {
                write!(
                    f,
                    "device-group member cannot be readmitted \
                     (not retired, or live blocks remain on its heap)"
                )
            }
            AllocError::SnapshotCorrupt => {
                write!(
                    f,
                    "durability snapshot rejected \
                     (truncated, bad checksum, or unsupported version)"
                )
            }
        }
    }
}

impl std::error::Error for AllocError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_stable() {
        assert_eq!(
            AllocError::TooLarge(9000).to_string(),
            "allocation size 9000 exceeds largest page"
        );
        assert_eq!(
            AllocError::InvalidFree(0x10).to_string(),
            "invalid free of address 0x10"
        );
        assert!(AllocError::ServiceDown.to_string().contains("service"));
        assert!(AllocError::ForeignTicket.to_string().contains("different"));
        assert!(AllocError::DeviceRetired.to_string().contains("retired"));
        assert!(AllocError::ReadmitRefused.to_string().contains("readmit"));
        assert!(AllocError::SnapshotCorrupt.to_string().contains("snapshot"));
    }

    #[test]
    fn invalid_free_decodes_device_tag() {
        let g = GlobalAddr::new(2, 0x40);
        assert_eq!(
            AllocError::InvalidFree(g.raw()).to_string(),
            format!(
                "invalid free of address {:#x} \
                 (device-tagged: device 2 + offset 0x40)",
                g.raw()
            )
        );
        // Device-0 / device-local addresses keep the compact form.
        assert_eq!(
            AllocError::InvalidFree(0x40).to_string(),
            "invalid free of address 0x40"
        );
    }
}
