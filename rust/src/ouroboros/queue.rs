//! The size-class queue abstraction.
//!
//! Every Ouroboros variant circulates u32 *indices* (page ids or chunk
//! ids) through a bounded MPMC FIFO; the variants differ in where the
//! queue's storage lives (static array vs virtualized chunks) and is what
//! the paper's six drivers compare. [`IdQueue`] is that common contract.

use crate::simt::{DevCtx, HotSpot};

use super::error::AllocError;

/// Bounded MPMC queue of u32 indices.
///
/// Correctness contract (exercised by the property tests):
/// * an enqueued value is dequeued at most once (no duplication);
/// * a dequeued value was previously enqueued (no invention);
/// * `try_enqueue` fails only when full, `try_dequeue` only when empty;
/// * FIFO per producer is *not* guaranteed under concurrency (matches the
///   GPU original — index queues are pools, not strict FIFOs).
pub trait IdQueue: Send + Sync {
    fn try_enqueue(&self, ctx: &DevCtx, v: u32) -> Result<(), AllocError>;
    fn try_dequeue(&self, ctx: &DevCtx) -> Option<u32>;

    /// Read the front entry without consuming it ("first obtaining a
    /// chunk index" — the chunk allocators read the front chunk and only
    /// dequeue it on exhaustion). Returns `None` when empty or when the
    /// front slot is still being published.
    fn peek(&self, ctx: &DevCtx) -> Option<u32>;

    /// The contention point for this queue's counters.
    fn hot(&self) -> &HotSpot;

    /// Approximate live entry count (racy read; exact at quiescence).
    fn len(&self) -> u32;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn capacity(&self) -> u32;

    /// Device-memory footprint of the queue *metadata/storage* in bytes —
    /// the quantity Ouroboros' virtualization shrinks.
    fn metadata_bytes(&self) -> u64;

    /// Warp-coalesced dequeue of up to `n` entries (optimised-CUDA path:
    /// one admission + one head reservation for the whole group). The
    /// default is the uncoalesced per-item loop used by the deoptimised /
    /// SYCL builds.
    fn bulk_dequeue(&self, ctx: &DevCtx, n: u32, out: &mut Vec<u32>) {
        for _ in 0..n {
            match self.try_dequeue(ctx) {
                Some(v) => out.push(v),
                None => break,
            }
        }
    }

    /// Warp-coalesced enqueue (see `bulk_dequeue`).
    ///
    /// Admission contract: **all-or-nothing** — on `Err` nothing was
    /// enqueued. Callers rely on this to retry per item after a failed
    /// bulk (`PageAllocator::bulk_free`). The in-crate impls satisfy it
    /// exactly via a single atomic admission CAS; this default holds it
    /// for the quiescent/single-producer case by pre-checking capacity —
    /// an impl used by concurrent bulk producers should override with an
    /// atomic admission instead of inheriting the loop.
    fn bulk_enqueue(&self, ctx: &DevCtx, vs: &[u32]) -> Result<(), AllocError> {
        if self.len() as u64 + vs.len() as u64 > self.capacity() as u64 {
            return Err(AllocError::OutOfMemory);
        }
        for &v in vs {
            self.try_enqueue(ctx, v)?;
        }
        Ok(())
    }
}
