//! Virtualized queues: the Ouroboros contribution proper.
//!
//! A standard index queue must be sized for the worst case (every page of
//! the heap parked in one queue), which costs enormous static memory. The
//! virtualized queues instead store the ring in *chunk-sized segments
//! carved from the managed heap itself* — the allocator eats its own
//! tail. Two flavors, matching the paper's four virtualized drivers:
//!
//! * **array** (`VaQueue`): a fixed directory of segment slots, indexed
//!   `(pos / seg_cap) % dir_slots` — O(1) segment lookup, capacity
//!   bounded by the directory;
//! * **list** (`VlQueue`): segments linked through a `next` word in the
//!   segment header; lookup walks the list (charged per hop — the
//!   latency the paper attributes to list traversal), capacity bounded
//!   only by the admission count.
//!
//! Segment lifecycle: lazily installed by whichever side first touches a
//! generation (enqueuer or dequeuer), reference-counted during slot
//! access, retired by the consumer of the segment's last slot, and
//! released back to the heap for reuse when the next generation claims
//! the directory slot. Slot hand-off uses the same empty/occupied state
//! machine as the standard queue.
//!
//! Simplification vs the GPU original (documented in DESIGN.md §3): the
//! per-slot generation tags and refcounts live in host-side atomics
//! (modeling L2-resident metadata) while the *entries themselves* occupy
//! real heap chunk words; the original keeps everything in device memory
//! with epoch counters. The observable properties — queue storage scales
//! with occupancy, segments recycle through the heap, slot traffic hits
//! chunk memory — are preserved.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::simt::{DevCtx, HotSpot};

use super::error::AllocError;
use super::heap::Heap;
use super::params::SEG_HEADER_WORDS;
use super::queue::IdQueue;

const EMPTY: u32 = 0;
const SPIN_LIMIT: u32 = 10_000_000;
/// `retired` states.
const LIVE: u32 = 0;
const RETIRED: u32 = 1;
const RELEASING: u32 = 2;

struct Seg {
    /// Generation tag: `sseq + 1`, 0 = slot unclaimed.
    seq: AtomicU32,
    /// Backing chunk: `chunk + 1`, 0 = not yet installed.
    chunk: AtomicU32,
    /// Readers/writers currently touching this segment's words.
    refs: AtomicU32,
    retired: AtomicU32,
}

impl Default for Seg {
    fn default() -> Self {
        Seg {
            seq: AtomicU32::new(0),
            chunk: AtomicU32::new(0),
            refs: AtomicU32::new(0),
            retired: AtomicU32::new(LIVE),
        }
    }
}

pub struct VirtualQueue {
    heap: Arc<Heap>,
    segs: Vec<Seg>,
    seg_cap: u32,
    /// Admission capacity (entries).
    cap: u32,
    /// VL flavor: maintain next links + charge walk cost.
    linked: bool,
    front: AtomicU32,
    back: AtomicU32,
    count: AtomicU32,
    hot: HotSpot,
}

/// Fixed-directory (array) virtual queue.
pub struct VaQueue(pub VirtualQueue);
/// Linked-list virtual queue.
pub struct VlQueue(pub VirtualQueue);

impl VirtualQueue {
    fn new(heap: Arc<Heap>, ring_slots: u32, seg_cap: u32, cap: u32, linked: bool) -> Self {
        assert!(ring_slots >= 2 && seg_cap >= 1);
        // Capacity must keep generations from lapping an undrained slot.
        let max_cap = (ring_slots - 1) * seg_cap;
        VirtualQueue {
            heap,
            segs: (0..ring_slots).map(|_| Seg::default()).collect(),
            seg_cap,
            cap: cap.min(max_cap),
            linked,
            front: AtomicU32::new(0),
            back: AtomicU32::new(0),
            count: AtomicU32::new(0),
            hot: HotSpot::new(),
        }
    }

    #[inline]
    fn seg_of(&self, sseq: u32) -> &Seg {
        &self.segs[(sseq % self.segs.len() as u32) as usize]
    }

    /// Live segment count (racy; used for the VL walk charge).
    fn live_segs(&self) -> u32 {
        // ordering: cursor sample; walk-charge heuristic
        let f = self.front.load(Ordering::Relaxed) / self.seg_cap;
        let b = self.back.load(Ordering::Relaxed) / self.seg_cap;
        b.saturating_sub(f) + 1
    }

    /// Resolve (installing if needed) the backing chunk for generation
    /// `sseq`. Blocks while an older generation still drains.
    fn ensure_segment(&self, ctx: &DevCtx, sseq: u32) -> Result<u32, AllocError> {
        let s = self.seg_of(sseq);
        let tag = sseq + 1;
        // Virtualization indirection: resolving the segment pointer is
        // one extra dependent load per queue op (the price of not having
        // a flat static ring — Ouroboros' trade for the memory savings).
        ctx.charge_mem(1);
        if self.linked {
            // Pointer chase from the head of the list to this segment.
            self.charge_walk(ctx);
        }
        let mut attempt = 0u32;
        loop {
            // ordering: Acquire tag; pairs with install Release
            let cur = s.seq.load(Ordering::Acquire);
            if cur == tag {
                let ch = s.chunk.load(Ordering::Acquire);
                if ch != 0 {
                    return Ok(ch - 1);
                }
                // Installer is preparing the chunk; wait.
            } else if cur == 0 {
                // Claim the generation, then install.
                if s.seq
                    // ordering: AcqRel slot claim
                    .compare_exchange(0, tag, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    match self.install(ctx, s, sseq) {
                        Ok(c) => return Ok(c),
                        Err(e) => {
                            // ordering: Release rollback/reset before slot reuse
                            s.seq.store(0, Ordering::Release);
                            return Err(e);
                        }
                    }
                }
            } else if cur < tag {
                // Previous generation resident: help release it if it is
                // fully retired, otherwise wait for its consumers.
                self.try_release(ctx, s);
            }
            // else: future generation claimed it first — impossible under
            // the capacity bound; treat as wait.
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                return Err(AllocError::QueueCorrupt);
            }
        }
    }

    fn install(&self, ctx: &DevCtx, s: &Seg, sseq: u32) -> Result<u32, AllocError> {
        let c = self.heap.alloc_chunk(ctx)?;
        self.heap.claim_for_queue_storage(c);
        // Zero the slot words (EMPTY protocol) + header.
        let base = Heap::word_index(c, 0);
        for w in 0..SEG_HEADER_WORDS + self.seg_cap as usize {
            self.heap.write_word(ctx, base + w, 0);
        }
        if self.linked && sseq > 0 {
            // Maintain the device-resident next link from the previous
            // generation's segment (best effort: it may already be gone).
            let prev = self.seg_of(sseq - 1);
            // ordering: Acquire revalidate of predecessor tag
            if prev.seq.load(Ordering::Acquire) == sseq {
                let pch = prev.chunk.load(Ordering::Acquire);
                if pch != 0 {
                    self.heap.write_word(ctx, Heap::word_index(pch - 1, 0), c + 1);
                }
            }
        }
        // ordering: Release; segment live before chunk visible
        s.retired.store(LIVE, Ordering::Release);
        s.chunk.store(c + 1, Ordering::Release);
        Ok(c)
    }

    /// Release a retired segment once its last reader leaves.
    fn try_release(&self, ctx: &DevCtx, s: &Seg) {
        if s.retired
            // ordering: AcqRel; single releaser claims the retire
            .compare_exchange(RETIRED, RELEASING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return;
        }
        let mut attempt = 0;
        // ordering: Acquire; waits out pinned readers unpins
        while s.refs.load(Ordering::Acquire) != 0 {
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                panic!("virtual queue segment release stuck (refs leak)");
            }
        }
        // ordering: AcqRel; detach the chunk exactly once
        let ch = s.chunk.swap(0, Ordering::AcqRel);
        debug_assert_ne!(ch, 0);
        self.heap.release_chunk(ctx, ch - 1);
        s.retired.store(LIVE, Ordering::Release); // ordering: Release; live before chunk visible
        s.seq.store(0, Ordering::Release); // ordering: Release rollback/reset before slot reuse
    }

    fn charge_walk(&self, ctx: &DevCtx) {
        // One hop per live segment on average /2; at least one.
        ctx.charge_mem(1 + self.live_segs() as u64 / 2);
    }

    /// Word index of slot `idx` in `chunk`.
    #[inline]
    fn slot_word(&self, chunk: u32, idx: u32) -> usize {
        Heap::word_index(chunk, SEG_HEADER_WORDS + idx as usize)
    }

    /// Publish `v` at virtual position `pos`.
    fn publish(&self, ctx: &DevCtx, pos: u32, v: u32) -> Result<(), AllocError> {
        let (sseq, idx) = (pos / self.seg_cap, pos % self.seg_cap);
        let s = self.seg_of(sseq);
        let tag = sseq + 1;
        let mut attempt = 0u32;
        loop {
            let chunk = self.ensure_segment(ctx, sseq)?;
            // Pin the segment, revalidate, then write.
            // ordering: AcqRel pin; orders against revalidate/release
            s.refs.fetch_add(1, Ordering::AcqRel);
            if s.seq.load(Ordering::Acquire) == tag {
                let w = self.slot_word(chunk, idx);
                let r = self.heap.cas_word(ctx, w, EMPTY, v + 1, &self.hot);
                // ordering: AcqRel unpin; releaser spin observes
                s.refs.fetch_sub(1, Ordering::AcqRel);
                if r.is_ok() {
                    return Ok(());
                }
            } else {
                // ordering: AcqRel unpin; releaser spin observes
                s.refs.fetch_sub(1, Ordering::AcqRel);
            }
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                return Err(AllocError::QueueCorrupt);
            }
        }
    }

    /// Consume the value at virtual position `pos`.
    fn consume(&self, ctx: &DevCtx, pos: u32) -> Result<u32, AllocError> {
        let (sseq, idx) = (pos / self.seg_cap, pos % self.seg_cap);
        let s = self.seg_of(sseq);
        let tag = sseq + 1;
        let mut attempt = 0u32;
        loop {
            let chunk = self.ensure_segment(ctx, sseq)?;
            // ordering: AcqRel pin; orders against revalidate/release
            s.refs.fetch_add(1, Ordering::AcqRel);
            if s.seq.load(Ordering::Acquire) == tag {
                let w = self.slot_word(chunk, idx);
                let v = self.heap.swap_word(ctx, w, EMPTY, &self.hot);
                if v != EMPTY {
                    // ordering: AcqRel unpin; releaser spin observes
                    s.refs.fetch_sub(1, Ordering::AcqRel);
                    if idx == self.seg_cap - 1 {
                        // Consumed the segment's last slot: retire it; the
                        // next generation's installer frees the chunk.
                        // ordering: Release; retire mark for try_release CAS
                        s.retired.store(RETIRED, Ordering::Release);
                    }
                    return Ok(v - 1);
                }
            }
            s.refs.fetch_sub(1, Ordering::AcqRel); // ordering: AcqRel unpin; releaser spin observes
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
            if attempt > SPIN_LIMIT {
                return Err(AllocError::QueueCorrupt);
            }
        }
    }

    fn enqueue_impl(&self, ctx: &DevCtx, v: u32) -> Result<(), AllocError> {
        let _g = ctx.contend(&self.hot);
        let prev = ctx.fetch_add(&self.count, 1, &self.hot) as i32;
        if prev >= self.cap as i32 {
            ctx.fetch_sub(&self.count, 1, &self.hot);
            return Err(AllocError::OutOfMemory);
        }
        let pos = ctx.fetch_add(&self.back, 1, &self.hot);
        self.publish(ctx, pos, v)
    }

    /// Non-consuming read of the front slot (chunk-allocator peek path).
    fn peek_impl(&self, ctx: &DevCtx) -> Option<u32> {
        if (ctx.load(&self.count) as i32) <= 0 {
            return None;
        }
        let pos = self.front.load(Ordering::Acquire); // ordering: Acquire head sample for peek
        let (sseq, idx) = (pos / self.seg_cap, pos % self.seg_cap);
        let s = self.seg_of(sseq);
        let tag = sseq + 1;
        if s.seq.load(Ordering::Acquire) != tag { // ordering: Acquire revalidate under/for pin
            return None;
        }
        s.refs.fetch_add(1, Ordering::AcqRel);
        // ordering: Acquire revalidate under/for pin
        let out = if s.seq.load(Ordering::Acquire) == tag {
            let ch = s.chunk.load(Ordering::Acquire);
            if ch != 0 {
                let w = Heap::word_index(ch - 1, SEG_HEADER_WORDS + idx as usize);
                let v = self.heap.read_word_hot(ctx, w, &self.hot);
                (v != EMPTY).then(|| v - 1)
            } else {
                None
            }
        } else {
            None
        };
        s.refs.fetch_sub(1, Ordering::AcqRel); // ordering: AcqRel unpin; releaser spin observes
        out
    }

    fn dequeue_impl(&self, ctx: &DevCtx) -> Option<u32> {
        let _g = ctx.contend(&self.hot);
        let prev = ctx.fetch_sub(&self.count, 1, &self.hot) as i32;
        if prev <= 0 {
            ctx.fetch_add(&self.count, 1, &self.hot);
            return None;
        }
        let pos = ctx.fetch_add(&self.front, 1, &self.hot);
        Some(self.consume(ctx, pos).expect("virtual queue corrupted"))
    }

    fn bulk_dequeue_impl(&self, ctx: &DevCtx, n: u32, out: &mut Vec<u32>) {
        if n == 0 {
            return;
        }
        let _g = ctx.contend(&self.hot);
        let take = loop {
            let c = ctx.load(&self.count) as i32;
            let take = (c.max(0) as u32).min(n);
            if take == 0 {
                return;
            }
            if ctx
                .cas(&self.count, c as u32, (c - take as i32) as u32, &self.hot)
                .is_ok()
            {
                break take;
            }
        };
        let pos0 = ctx.fetch_add(&self.front, take, &self.hot);
        for i in 0..take {
            out.push(
                self.consume(ctx, pos0.wrapping_add(i))
                    .expect("virtual queue corrupted"),
            );
        }
    }

    fn bulk_enqueue_impl(&self, ctx: &DevCtx, vs: &[u32]) -> Result<(), AllocError> {
        if vs.is_empty() {
            return Ok(());
        }
        let _g = ctx.contend(&self.hot);
        let k = vs.len() as u32;
        loop {
            let c = ctx.load(&self.count) as i32;
            if c.max(0) as u32 + k > self.cap {
                return Err(AllocError::OutOfMemory);
            }
            if ctx
                .cas(&self.count, c as u32, (c + k as i32) as u32, &self.hot)
                .is_ok()
            {
                break;
            }
        }
        let pos0 = ctx.fetch_add(&self.back, k, &self.hot);
        for (i, &v) in vs.iter().enumerate() {
            self.publish(ctx, pos0.wrapping_add(i as u32), v)?;
        }
        Ok(())
    }

    fn metadata_bytes_impl(&self) -> u64 {
        // Directory + counters (host metadata) plus the *live* storage
        // segments borrowed from the heap — the footprint that, unlike
        // the standard queue, scales with occupancy instead of worst
        // case.
        let live_chunks = self
            .segs
            .iter()
            // ordering: Relaxed scan; metadata gauge
            .filter(|s| s.chunk.load(Ordering::Relaxed) != 0)
            .count() as u64;
        self.segs.len() as u64 * 16 + 12 + live_chunks * super::params::CHUNK_SIZE as u64
    }

    fn len_impl(&self) -> u32 {
        // ordering: transient count sample; len heuristic
        (self.count.load(Ordering::Relaxed) as i32).max(0) as u32
    }
}

impl VaQueue {
    /// `dir_slots` directory entries of `seg_cap`-entry segments.
    pub fn new(heap: Arc<Heap>, dir_slots: u32, seg_cap: u32) -> Self {
        let cap = (dir_slots - 1) * seg_cap;
        VaQueue(VirtualQueue::new(heap, dir_slots, seg_cap, cap, false))
    }
}

impl VlQueue {
    /// Capacity-bounded linked queue; the ring of generation slots is
    /// sized from the capacity.
    pub fn new(heap: Arc<Heap>, capacity: u32, seg_cap: u32) -> Self {
        let ring = capacity.div_ceil(seg_cap) + 2;
        VlQueue(VirtualQueue::new(heap, ring, seg_cap, capacity, true))
    }
}

macro_rules! delegate_idqueue {
    ($ty:ty) => {
        impl IdQueue for $ty {
            fn try_enqueue(&self, ctx: &DevCtx, v: u32) -> Result<(), AllocError> {
                self.0.enqueue_impl(ctx, v)
            }
            fn try_dequeue(&self, ctx: &DevCtx) -> Option<u32> {
                self.0.dequeue_impl(ctx)
            }
            fn peek(&self, ctx: &DevCtx) -> Option<u32> {
                self.0.peek_impl(ctx)
            }
            fn hot(&self) -> &HotSpot {
                &self.0.hot
            }
            fn len(&self) -> u32 {
                self.0.len_impl()
            }
            fn capacity(&self) -> u32 {
                self.0.cap
            }
            fn metadata_bytes(&self) -> u64 {
                self.0.metadata_bytes_impl()
            }
            fn bulk_dequeue(&self, ctx: &DevCtx, n: u32, out: &mut Vec<u32>) {
                self.0.bulk_dequeue_impl(ctx, n, out)
            }
            fn bulk_enqueue(&self, ctx: &DevCtx, vs: &[u32]) -> Result<(), AllocError> {
                self.0.bulk_enqueue_impl(ctx, vs)
            }
        }
    };
}

delegate_idqueue!(VaQueue);
delegate_idqueue!(VlQueue);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, Cuda};
    use crate::ouroboros::params::HeapConfig;

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    fn heap() -> Arc<Heap> {
        Arc::new(Heap::new(HeapConfig::test_small()))
    }

    #[test]
    fn va_roundtrip_within_one_segment() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = VaQueue::new(heap(), 4, 8);
        for v in 0..5 {
            q.try_enqueue(&c, v).unwrap();
        }
        assert_eq!(q.len(), 5);
        for v in 0..5 {
            assert_eq!(q.try_dequeue(&c), Some(v));
        }
        assert_eq!(q.try_dequeue(&c), None);
    }

    #[test]
    fn va_crosses_segments_and_recycles_chunks() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let q = VaQueue::new(h.clone(), 4, 8); // cap 24
        // Push/pop far beyond one segment and beyond the directory size.
        for round in 0..20u32 {
            for i in 0..8 {
                q.try_enqueue(&c, round * 100 + i).unwrap();
            }
            for i in 0..8 {
                assert_eq!(q.try_dequeue(&c), Some(round * 100 + i));
            }
        }
        // Storage stayed bounded: retired segments recycle, so live
        // storage never exceeds the directory size (lazy release keeps
        // up to dir_slots resident).
        let live = h.live_chunks();
        assert!(live <= 4, "live storage chunks {live}");
    }

    #[test]
    fn va_capacity_bounded_by_directory() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = VaQueue::new(heap(), 3, 4); // cap = (3-1)*4 = 8
        for v in 0..8 {
            q.try_enqueue(&c, v).unwrap();
        }
        assert_eq!(q.try_enqueue(&c, 99), Err(AllocError::OutOfMemory));
        assert_eq!(q.len(), 8);
    }

    #[test]
    fn vl_roundtrip_and_walk() {
        let b = Cuda::new();
        let c = ctx(&b);
        let q = VlQueue::new(heap(), 64, 8);
        for v in 100..140 {
            q.try_enqueue(&c, v).unwrap();
        }
        for v in 100..140 {
            assert_eq!(q.try_dequeue(&c), Some(v));
        }
        assert_eq!(q.try_dequeue(&c), None);
    }

    #[test]
    fn vl_maintains_next_links() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let q = VlQueue::new(h.clone(), 64, 4);
        // Fill 3 segments without draining.
        for v in 0..12 {
            q.try_enqueue(&c, v).unwrap();
        }
        // Walk the device-resident links from segment 0.
        let s0 = q.0.seg_of(0).chunk.load(Ordering::Acquire) - 1;
        let n1 = h.read_word(&c, Heap::word_index(s0, 0));
        assert_ne!(n1, 0, "segment 0 must link to segment 1");
        let n2 = h.read_word(&c, Heap::word_index(n1 - 1, 0));
        assert_ne!(n2, 0, "segment 1 must link to segment 2");
    }

    #[test]
    fn virtual_metadata_scales_with_occupancy_not_capacity() {
        let b = Cuda::new();
        let c = ctx(&b);
        let h = heap();
        let q = VaQueue::new(h.clone(), 16, 64); // cap 960
        let empty_md = q.metadata_bytes();
        for v in 0..200 {
            q.try_enqueue(&c, v).unwrap();
        }
        let full_md = q.metadata_bytes();
        assert!(full_md > empty_md);
        // Standard queue of same capacity would burn 4 B per slot up
        // front; the virtual queue's host metadata is tiny.
        assert!(empty_md < 960 * 4 / 2);
    }

    #[test]
    fn concurrent_virtual_churn_conserves_values() {
        use std::sync::atomic::AtomicU64;
        let q = std::sync::Arc::new(VaQueue::new(heap(), 8, 16));
        let enq = AtomicU64::new(0);
        let deq = AtomicU64::new(0);
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let q = q.clone();
                let (enq, deq) = (&enq, &deq);
                s.spawn(move || {
                    let b = Cuda::new();
                    let c = DevCtx::new(&b, 1000.0, t);
                    for i in 0..300u32 {
                        let v = t * 1000 + i;
                        while q.try_enqueue(&c, v).is_err() {
                            std::thread::yield_now();
                        }
                        enq.fetch_add(v as u64, Ordering::Relaxed);
                        if let Some(got) = q.try_dequeue(&c) {
                            deq.fetch_add(got as u64, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let b = Cuda::new();
        let c = ctx(&b);
        while let Some(v) = q.try_dequeue(&c) {
            deq.fetch_add(v as u64, Ordering::Relaxed);
        }
        assert_eq!(enq.load(Ordering::Relaxed), deq.load(Ordering::Relaxed));
        assert_eq!(q.len(), 0);
    }
}
