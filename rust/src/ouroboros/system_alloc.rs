//! Baseline device allocator modeled on the 2009-era CUDA `malloc`
//! (paper §1: gained in 2009 "but is often considered slow and
//! unreliable").
//!
//! Design mirrors what is publicly known of early device-side malloc: a
//! single global free-list protected by one device-wide lock word,
//! first-fit search, immediate coalescing of adjacent free blocks. Every
//! operation serializes on the lock — which is exactly why the
//! dynamic-allocator literature (and this paper) exists. Used as the
//! comparison baseline in `benches/baseline_system.rs` and the
//! `ouroboros-tpu ablate --what baseline` table.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use crate::simt::{DevCtx, HotSpot};

use super::error::AllocError;

/// Block header overhead (size + free flag), bytes — charged to every
/// allocation like the real thing.
const HEADER: u32 = 16;
/// Device-lock acquire/release cost in lock-word RMWs.
const LOCK_RMWS: u64 = 2;

struct Block {
    off: u32,
    len: u32,
    free: bool,
}

/// Single-lock first-fit heap. The free-list itself is host-side (we
/// model the *serialization*, which is the property of interest); the
/// lock word and cost accounting go through the device context.
pub struct SystemAllocator {
    heap_bytes: u32,
    lock: AtomicU32,
    hot: HotSpot,
    blocks: Mutex<Vec<Block>>,
    pub lock_contentions: AtomicU32,
}

impl SystemAllocator {
    pub fn new(heap_bytes: u32) -> Self {
        SystemAllocator {
            heap_bytes,
            lock: AtomicU32::new(0),
            hot: HotSpot::new(),
            blocks: Mutex::new(vec![Block { off: 0, len: heap_bytes, free: true }]),
            lock_contentions: AtomicU32::new(0),
        }
    }

    fn acquire(&self, ctx: &DevCtx) {
        let mut attempt = 0;
        loop {
            // Device-wide spinlock on one word: every caller serializes.
            for _ in 0..LOCK_RMWS {
                let _ = ctx.fetch_add(&self.lock, 0, &self.hot);
            }
            if self
                .lock
                // ordering: AcqRel lock CAS; win orders the section
                .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return;
            }
            self.lock_contentions.fetch_add(1, Ordering::Relaxed); // ordering: stat counter
            ctx.backoff(&self.hot, attempt.min(8));
            attempt += 1;
        }
    }

    fn release(&self, ctx: &DevCtx) {
        let _ = ctx.fetch_add(&self.lock, 0, &self.hot);
        self.lock.store(0, Ordering::Release); // ordering: Release unlock; publishes the section
    }

    pub fn malloc(&self, ctx: &DevCtx, size: u32) -> Result<u32, AllocError> {
        if size == 0 {
            return Err(AllocError::ZeroSize);
        }
        let need = size + HEADER;
        self.acquire(ctx);
        let mut blocks = self.blocks.lock().unwrap();
        // First-fit walk — every cycle of it happens *inside* the global
        // lock, so it charges the device-wide serial ledger (the "slow"
        // part of 2009-era device malloc).
        let mut found = None;
        for (i, b) in blocks.iter().enumerate() {
            ctx.charge_hot_read(2, &self.hot);
            if b.free && b.len >= need {
                found = Some(i);
                break;
            }
        }
        let Some(i) = found else {
            drop(blocks);
            self.release(ctx);
            return Err(AllocError::OutOfMemory);
        };
        let off = blocks[i].off;
        let rest = blocks[i].len - need;
        blocks[i].len = need;
        blocks[i].free = false;
        if rest > 0 {
            let insert_off = off + need;
            blocks.insert(i + 1, Block { off: insert_off, len: rest, free: true });
            ctx.charge_hot_read(2, &self.hot);
        }
        drop(blocks);
        self.release(ctx);
        Ok(off + HEADER)
    }

    pub fn free(&self, ctx: &DevCtx, addr: u32) -> Result<(), AllocError> {
        if addr < HEADER || addr >= self.heap_bytes {
            return Err(AllocError::InvalidFree(addr));
        }
        let off = addr - HEADER;
        self.acquire(ctx);
        let mut blocks = self.blocks.lock().unwrap();
        let mut idx = None;
        for (i, b) in blocks.iter().enumerate() {
            ctx.charge_hot_read(2, &self.hot);
            if b.off == off {
                idx = Some(i);
                break;
            }
        }
        let Some(i) = idx else {
            drop(blocks);
            self.release(ctx);
            return Err(AllocError::InvalidFree(addr));
        };
        if blocks[i].free {
            drop(blocks);
            self.release(ctx);
            return Err(AllocError::InvalidFree(addr));
        }
        blocks[i].free = true;
        // Coalesce with right and left neighbors.
        if i + 1 < blocks.len() && blocks[i + 1].free {
            blocks[i].len += blocks[i + 1].len;
            blocks.remove(i + 1);
            ctx.charge_hot_read(2, &self.hot);
        }
        if i > 0 && blocks[i - 1].free {
            blocks[i - 1].len += blocks[i].len;
            blocks.remove(i);
            ctx.charge_hot_read(2, &self.hot);
        }
        drop(blocks);
        self.release(ctx);
        Ok(())
    }

    /// Number of blocks on the list (fragmentation signal).
    pub fn block_count(&self) -> usize {
        self.blocks.lock().unwrap().len()
    }

    /// Free bytes remaining.
    pub fn free_bytes(&self) -> u64 {
        self.blocks
            .lock()
            .unwrap()
            .iter()
            .filter(|b| b.free)
            .map(|b| b.len as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{Backend, Cuda};

    fn ctx<'a>(b: &'a dyn Backend) -> DevCtx<'a> {
        DevCtx::new(b, 1000.0, 0)
    }

    #[test]
    fn alloc_free_roundtrip_and_coalesce() {
        let b = Cuda::new();
        let c = ctx(&b);
        let sys = SystemAllocator::new(1 << 20);
        let a1 = sys.malloc(&c, 100).unwrap();
        let a2 = sys.malloc(&c, 200).unwrap();
        let a3 = sys.malloc(&c, 300).unwrap();
        assert!(a1 < a2 && a2 < a3);
        sys.free(&c, a2).unwrap();
        sys.free(&c, a1).unwrap();
        sys.free(&c, a3).unwrap();
        // Full coalescing back to one block.
        assert_eq!(sys.block_count(), 1);
        assert_eq!(sys.free_bytes(), 1 << 20);
    }

    #[test]
    fn first_fit_reuses_holes() {
        let b = Cuda::new();
        let c = ctx(&b);
        let sys = SystemAllocator::new(1 << 16);
        let a1 = sys.malloc(&c, 1000).unwrap();
        let _a2 = sys.malloc(&c, 1000).unwrap();
        sys.free(&c, a1).unwrap();
        // Same-size realloc lands in the freed hole.
        let a3 = sys.malloc(&c, 1000).unwrap();
        assert_eq!(a3, a1);
    }

    #[test]
    fn oom_and_double_free() {
        let b = Cuda::new();
        let c = ctx(&b);
        let sys = SystemAllocator::new(4096);
        let a = sys.malloc(&c, 2000).unwrap();
        assert_eq!(sys.malloc(&c, 4000), Err(AllocError::OutOfMemory));
        sys.free(&c, a).unwrap();
        assert!(matches!(sys.free(&c, a), Err(AllocError::InvalidFree(_))));
        assert!(matches!(sys.free(&c, 3), Err(AllocError::InvalidFree(_))));
    }

    #[test]
    fn every_op_pays_the_global_lock() {
        let b = Cuda::new();
        let c = ctx(&b);
        let sys = SystemAllocator::new(1 << 20);
        let before = c.events().hot_serial_cycles;
        let a = sys.malloc(&c, 64).unwrap();
        sys.free(&c, a).unwrap();
        assert!(
            c.events().hot_serial_cycles > before,
            "lock traffic must hit the serialization ledger"
        );
    }

    #[test]
    fn concurrent_integrity() {
        let sys = std::sync::Arc::new(SystemAllocator::new(1 << 22));
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let sys = sys.clone();
                s.spawn(move || {
                    let b = Cuda::new();
                    let c = DevCtx::new(&b, 1000.0, t);
                    let mut mine = Vec::new();
                    for i in 0..100u32 {
                        mine.push(sys.malloc(&c, 64 + (i % 512)).unwrap());
                        if i % 2 == 1 {
                            sys.free(&c, mine.swap_remove(0)).unwrap();
                        }
                    }
                    for a in mine {
                        sys.free(&c, a).unwrap();
                    }
                });
            }
        });
        assert_eq!(sys.block_count(), 1);
    }
}
