//! The Ouroboros dynamic memory manager — six variants (page, chunk, and
//! the virtualized array/list versions of each), implemented with real
//! lock-free atomics over a simulated device heap.
//!
//! Layering (bottom-up): [`params`] geometry → [`heap`] chunk carving +
//! reuse → [`index_queue`]/[`virtual_queue`] index circulation →
//! [`page_alloc`]/[`chunk_alloc`] allocation policies → [`allocator`]
//! the unified `DeviceAllocator` contract + warp-collective paths.

pub mod addr;
pub mod allocator;
pub mod chunk;
pub mod chunk_alloc;
pub mod error;
pub mod heap;
pub mod index_queue;
pub mod page_alloc;
pub mod params;
pub mod queue;
pub mod system_alloc;
pub mod virtual_queue;

pub use addr::GlobalAddr;
pub use allocator::{build_allocator, warp_free, warp_malloc, DeviceAllocator, Variant};
pub use error::AllocError;
pub use heap::Heap;
pub use params::HeapConfig;
pub use queue::IdQueue;
