//! The group- and device-tagged global address namespace.
//!
//! A single simulated device's heap lives in a 32-bit byte-address
//! space. The allocation service's `DeviceGroup` topology owns several
//! devices, each with its own [`super::heap::Heap`], so service clients
//! see **global** addresses: the owning device's group index in the
//! high bits, the device-local heap byte address in the low bits. The
//! federation tier (`coordinator/federation.rs`) stacks one more level
//! on top: a **federation group tag** above the device field, so frees
//! route across whole `AllocService` groups.
//!
//! ```text
//!  31   30 29          26 25                         0
//! +-------+--------------+---------------------------+
//! | group |  device id   |  local heap byte address  |
//! +-------+--------------+---------------------------+
//! ```
//!
//! The split gives every device a 64 MiB window ([`DEVICE_SPAN`]) —
//! twice the default 32 MiB heap — up to [`MAX_DEVICES`] members per
//! group, and up to [`MAX_GROUPS`] federated groups. Group 0 is
//! bit-identical to the pre-federation address space (the two group
//! bits are zero), and within it device 0's global addresses are
//! numerically identical to its local addresses — so both the
//! single-group and the single-device topologies keep their historical
//! encodings bit for bit.
//!
//! Everything below the service speaks local addresses (the allocator
//! variants, the heap, the warp paths); a service encodes the device
//! tag on the way out of a completed alloc and decodes it on the way
//! into a submitted free. Services are **group-blind**: every address a
//! service sees has group 0, and the federation router is the only
//! layer that tags ([`GlobalAddr::with_group`]) and strips
//! ([`GlobalAddr::strip_group`]) the group field. The `InvalidFree`
//! fast-reject therefore bounds-checks the group bits too — a
//! group-tagged address leaking into a bare service is garbage there,
//! not an alias of some member's heap.

use std::fmt;

use super::params::{page_size, CHUNK_SIZE};

/// Bit position of the device id inside a global address.
pub const DEVICE_SHIFT: u32 = 26;
/// Bytes of local address space per group device (64 MiB).
pub const DEVICE_SPAN: u32 = 1 << DEVICE_SHIFT;
/// Bit position of the federation group tag.
pub const GROUP_SHIFT: u32 = 30;
/// Bytes of address space per federation group (1 GiB: 16 devices).
pub const GROUP_SPAN: u32 = 1 << GROUP_SHIFT;
/// Maximum devices a single service group can address (16).
pub const MAX_DEVICES: u32 = 1 << (GROUP_SHIFT - DEVICE_SHIFT);
/// Maximum federated service groups (4).
pub const MAX_GROUPS: u32 = 1 << (32 - GROUP_SHIFT);

/// A tagged allocation address handed out by the allocation service:
/// federation group in bits 30+, group device id in bits 26..30,
/// device-local heap byte address below. Opaque to clients — its only
/// contract is that [`GlobalAddr::group`] / [`GlobalAddr::device`] /
/// [`GlobalAddr::local`] round-trip what the service (and federation
/// router) encoded.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GlobalAddr(u32);

impl GlobalAddr {
    /// Tag a device-local address with its owning device's group index
    /// (federation group 0 — the service-level constructor; the
    /// federation router adds its tag with [`GlobalAddr::with_group`]).
    #[inline]
    pub fn new(device: u32, local: u32) -> Self {
        debug_assert!(device < MAX_DEVICES, "device id {device} out of range");
        debug_assert!(local < DEVICE_SPAN, "local address {local:#x} overflows device window");
        GlobalAddr((device << DEVICE_SHIFT) | local)
    }

    /// Reinterpret a raw u32 as a global address (no validation — the
    /// service's submit path is where garbage gets rejected).
    #[inline]
    pub fn from_raw(raw: u32) -> Self {
        GlobalAddr(raw)
    }

    /// The raw encoded word (what `AllocError::InvalidFree` carries).
    #[inline]
    pub fn raw(self) -> u32 {
        self.0
    }

    /// Federation group tag (0 for every address a bare service mints).
    #[inline]
    pub fn group(self) -> u32 {
        self.0 >> GROUP_SHIFT
    }

    /// Owning device's index within its group.
    #[inline]
    pub fn device(self) -> u32 {
        (self.0 >> DEVICE_SHIFT) & (MAX_DEVICES - 1)
    }

    /// Device-local heap byte address.
    #[inline]
    pub fn local(self) -> u32 {
        self.0 & (DEVICE_SPAN - 1)
    }

    /// Stamp a group-0 address with a federation group tag — how the
    /// federation router rewrites a member service's addresses on the
    /// way out to clients. Group 0 is the identity, so a single-group
    /// federation keeps the pre-federation address space bit for bit.
    #[inline]
    pub fn with_group(self, group: u32) -> Self {
        debug_assert!(group < MAX_GROUPS, "group tag {group} out of range");
        debug_assert_eq!(self.group(), 0, "address already group-tagged");
        GlobalAddr((group << GROUP_SHIFT) | self.0)
    }

    /// The group-local (group-0) view of this address — what the
    /// federation router hands the owning service after routing on
    /// [`GlobalAddr::group`].
    #[inline]
    pub fn strip_group(self) -> Self {
        GlobalAddr(self.0 & (GROUP_SPAN - 1))
    }

    /// Whether the tag names a member of a `members`-device service
    /// group — the first half of every service-side free fast-reject,
    /// and the guard migration/forwarding paths use before indexing the
    /// group. Services are group-blind, so any non-zero federation
    /// group tag fails here: a tagged address that skipped the
    /// federation router must be rejected, never aliased onto a member
    /// whose device bits happen to match.
    #[inline]
    pub fn device_in(self, members: usize) -> bool {
        self.group() == 0 && (self.device() as usize) < members
    }

    /// Device-local chunk index of this address. A lease span (a
    /// whole-chunk allocation, class `NUM_QUEUES - 1`) is chunk-aligned,
    /// so every block carved from it shares this index — which is why
    /// the client-side lease registry can key cached block names by
    /// `(device, chunk)` and resolve any free in O(1).
    #[inline]
    pub fn chunk(self) -> u32 {
        self.local() / CHUNK_SIZE
    }

    /// Byte offset of this address within its chunk (0 for a lease
    /// span's base).
    #[inline]
    pub fn chunk_offset(self) -> u32 {
        self.local() % CHUNK_SIZE
    }

    /// The `i`-th class-`q` block carved from the chunk-aligned span
    /// based at this address — the name a lease-caching client hands
    /// out for a cached allocation.
    #[inline]
    pub fn block(self, q: usize, i: u32) -> Self {
        debug_assert_eq!(self.chunk_offset(), 0, "lease spans are chunk-aligned");
        debug_assert!(i * page_size(q) < CHUNK_SIZE, "block {i} overflows span");
        GlobalAddr(self.0 + i * page_size(q))
    }

    /// Index of `addr` among the class-`q` blocks of the chunk-aligned
    /// span based at this address, or `None` if `addr` is not exactly
    /// one of them (wrong device or group, outside the span, or
    /// misaligned for the class). The inverse of [`GlobalAddr::block`].
    #[inline]
    pub fn block_index(self, q: usize, addr: GlobalAddr) -> Option<u32> {
        if addr.group() != self.group() || addr.device() != self.device() {
            return None;
        }
        let delta = addr.local().checked_sub(self.local())?;
        if delta >= CHUNK_SIZE || delta % page_size(q) != 0 {
            return None;
        }
        Some(delta / page_size(q))
    }

    /// The same local address re-tagged onto another group member.
    /// Live-set migration mints the forwarding *value* this way when the
    /// destination page happens to share the source's local offset; it
    /// is also the cheapest way to build test fixtures that alias a
    /// local address across devices.
    #[inline]
    pub fn retag(self, device: u32) -> Self {
        GlobalAddr::new(device, self.local())
    }
}

impl fmt::Debug for GlobalAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.group() != 0 {
            write!(f, "g{}d{}+{:#x}", self.group(), self.device(), self.local())
        } else {
            write!(f, "d{}+{:#x}", self.device(), self.local())
        }
    }
}

impl fmt::Display for GlobalAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for (dev, local) in [(0u32, 0u32), (0, 0x3FF_FFFF), (1, 16), (7, 8192), (15, 0x123_4560)] {
            let g = GlobalAddr::new(dev, local);
            assert_eq!(g.group(), 0, "{g}");
            assert_eq!(g.device(), dev, "{g}");
            assert_eq!(g.local(), local, "{g}");
            assert_eq!(GlobalAddr::from_raw(g.raw()), g);
        }
    }

    #[test]
    fn group_tag_roundtrip() {
        for grp in 0..MAX_GROUPS {
            for (dev, local) in [(0u32, 0u32), (3, 8192), (15, DEVICE_SPAN - 1)] {
                let g = GlobalAddr::new(dev, local).with_group(grp);
                assert_eq!(g.group(), grp, "{g}");
                assert_eq!(g.device(), dev, "{g}");
                assert_eq!(g.local(), local, "{g}");
                assert_eq!(g.strip_group(), GlobalAddr::new(dev, local));
            }
        }
    }

    #[test]
    fn device_zero_is_identity() {
        // The single-device topology keeps the pre-group address space.
        for local in [0u32, 16, 1000, DEVICE_SPAN - 1] {
            assert_eq!(GlobalAddr::new(0, local).raw(), local);
        }
    }

    #[test]
    fn group_zero_is_identity() {
        // The single-group federation keeps the pre-federation space.
        for (dev, local) in [(0u32, 0u32), (2, 4096), (15, DEVICE_SPAN - 1)] {
            let g = GlobalAddr::new(dev, local);
            assert_eq!(g.with_group(0), g);
            assert_eq!(g.strip_group(), g);
        }
    }

    #[test]
    fn span_fits_default_heap() {
        // The default 32 MiB heap must fit the per-device window.
        let cfg = super::super::params::HeapConfig::default();
        assert!(cfg.heap_bytes() <= DEVICE_SPAN as u64);
        assert_eq!(MAX_DEVICES, 16);
        assert_eq!(MAX_GROUPS, 4);
        // The partition tiles the whole 32-bit space exactly.
        assert_eq!(
            (MAX_GROUPS as u64) * (MAX_DEVICES as u64) * (DEVICE_SPAN as u64),
            1u64 << 32
        );
    }

    #[test]
    fn display_decodes_tag() {
        let g = GlobalAddr::new(3, 0x40);
        assert_eq!(format!("{g}"), "d3+0x40");
        assert_eq!(format!("{g:?}"), "d3+0x40");
        let f = g.with_group(2);
        assert_eq!(format!("{f}"), "g2d3+0x40");
    }

    #[test]
    fn device_in_checks_group_bounds() {
        let g = GlobalAddr::new(2, 0x40);
        assert!(g.device_in(3));
        assert!(!g.device_in(2), "device 2 is not a member of a 2-group");
        assert!(!g.device_in(0));
        // Device 0 (the untagged space) is a member of any group.
        assert!(GlobalAddr::new(0, 16).device_in(1));
        // A federation-tagged address is NEVER a member of a bare
        // service's group, even when the device bits would fit.
        assert!(!g.with_group(1).device_in(3));
        assert!(!GlobalAddr::new(0, 16).with_group(3).device_in(1));
    }

    #[test]
    fn retag_moves_device_keeps_local() {
        let g = GlobalAddr::new(1, 0x1230);
        let m = g.retag(5);
        assert_eq!(m.device(), 5);
        assert_eq!(m.local(), g.local());
        assert_eq!(m.retag(1), g);
    }

    #[test]
    fn block_carve_roundtrip() {
        use super::super::params::{pages_per_chunk, CHUNK_SIZE};
        let span = GlobalAddr::new(2, 3 * CHUNK_SIZE);
        assert_eq!(span.chunk(), 3);
        assert_eq!(span.chunk_offset(), 0);
        for q in 0..super::super::params::NUM_QUEUES {
            for i in 0..pages_per_chunk(q) {
                let b = span.block(q, i);
                assert_eq!(b.device(), span.device());
                assert_eq!(b.chunk(), span.chunk(), "blocks stay in the span chunk");
                assert_eq!(span.block_index(q, b), Some(i), "q{q} block {i}");
            }
        }
    }

    #[test]
    fn block_index_rejects_foreign_names() {
        use super::super::params::CHUNK_SIZE;
        let span = GlobalAddr::new(1, 2 * CHUNK_SIZE);
        // Same offset math on another device or group is not a member.
        assert_eq!(span.block_index(6, span.block(6, 1).retag(2)), None);
        assert_eq!(span.block_index(6, span.block(6, 1).with_group(1)), None);
        // Below the span, past the span, and misaligned within it.
        assert_eq!(span.block_index(6, GlobalAddr::new(1, 2 * CHUNK_SIZE - 16)), None);
        assert_eq!(span.block_index(6, GlobalAddr::new(1, 3 * CHUNK_SIZE)), None);
        assert_eq!(span.block_index(6, GlobalAddr::new(1, 2 * CHUNK_SIZE + 100)), None);
        // Block 0 aliases the span base itself.
        assert_eq!(span.block_index(6, span), Some(0));
    }

    #[test]
    fn ordering_groups_by_device() {
        let a = GlobalAddr::new(0, DEVICE_SPAN - 1);
        let b = GlobalAddr::new(1, 0);
        assert!(a < b, "device 1 addresses sort after all of device 0");
        // And the federation tag sorts above the device tag.
        let c = GlobalAddr::new(15, DEVICE_SPAN - 1);
        let d = GlobalAddr::new(0, 0).with_group(1);
        assert!(c < d, "group 1 addresses sort after all of group 0");
    }
}
